"""Sharded atomic async checkpointing."""
from .store import CheckpointStore  # noqa: F401
