"""Sharded atomic async checkpointing."""
from .store import CheckpointError, CheckpointStore  # noqa: F401
