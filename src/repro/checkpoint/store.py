"""Sharded, atomic, async checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json            — tree structure, shapes, dtypes
            shard_<i>.npz            — flattened leaves (chunked)
         <dir>/step_<N>.tmp/ → atomic rename on commit

Design points for the 1000-node story:
  * each host writes only its leaves (here: single-host writes all, but the
    manifest carries a host→leaf map so the layout is multi-host ready),
  * write happens in a background thread (training continues; ``wait()``
    joins before the next save — bounded staleness of one),
  * atomic rename + "latest" pointer file makes partially-written
    checkpoints invisible to restore; restart auto-resumes from the newest
    complete step (fault tolerance: subjob chunk boundaries save here).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy cannot savez ml_dtypes arrays (bf16/f8): store them as raw uint
# views and record the logical dtype in the manifest
_EXT_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
               "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
               "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}

__all__ = ["CheckpointStore", "CheckpointError"]


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot be loaded (truncated/corrupt blob).

    Distinct from :class:`FileNotFoundError` (no checkpoint at all): a
    caller seeing this should fall back to an OLDER step rather than
    cold-start — the store's atomic-rename protocol makes this rare
    (a half-written step is never visible), but torn disks happen.
    """


class CheckpointStore:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        logical_dtypes = [str(a.dtype) for a in host_leaves]
        host_leaves = [
            a.view(_EXT_DTYPES[str(a.dtype)][1]) if str(a.dtype) in _EXT_DTYPES
            else a
            for a in host_leaves]
        treedef_str = str(treedef)

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "treedef": treedef_str,
                "shapes": [list(a.shape) for a in host_leaves],
                "dtypes": logical_dtypes,
                "hosts": {"0": list(range(len(host_leaves)))},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "latest.tmp"),
                       os.path.join(self.dir, "latest"))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def save_state(self, step: int, state: Any, *, blocking: bool = True) -> None:
        """Checkpoint an arbitrary picklable OBJECT graph (scheduler state).

        The array path (:meth:`save`) flattens a jax tree; scheduler crash
        recovery instead needs one pickled graph so shared object
        IDENTITIES (the same Variant held by a commitment, the running
        set, and the commit index) survive the round-trip.  Same
        atomicity: written to ``step_<N>.tmp`` and renamed into place, so
        a crash mid-write never leaves a half checkpoint visible;
        ``latest`` and GC are shared with the array path.
        """
        import pickle

        self.wait()
        blob = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "state.pkl"), "wb") as f:
                f.write(blob)
            manifest = {"step": step, "kind": "pickle",
                        "n_bytes": len(blob)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "latest.tmp"),
                       os.path.join(self.dir, "latest"))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def restore_state(self, step: Optional[int] = None) -> Tuple[Any, int]:
        """Load a :meth:`save_state` checkpoint (latest when ``step`` None)."""
        import pickle

        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest.get("kind") != "pickle":
            raise ValueError(
                f"step {step} is an array checkpoint; use restore()")
        blob_path = os.path.join(final, "state.pkl")
        try:
            with open(blob_path, "rb") as f:
                blob = f.read()
            expected = manifest.get("n_bytes")
            if expected is not None and len(blob) != expected:
                raise CheckpointError(
                    f"step {step}: state.pkl is {len(blob)} bytes, "
                    f"manifest says {expected} (truncated write?)")
            return pickle.loads(blob), step
        except (EOFError, pickle.UnpicklingError, AttributeError,
                ImportError, IndexError) as e:
            # pickle raises a zoo of exceptions on corrupt input; surface
            # one typed error so restart logic can fall back to an older
            # step instead of crashing on a bare EOFError
            raise CheckpointError(
                f"step {step}: corrupt checkpoint blob ({e})") from e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "latest")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s}", "manifest.json")):
                return s
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``template`` (shapes must match)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(final, "shard_0.npz"))
        leaves = []
        for i in range(manifest["n_leaves"]):
            a = data[f"leaf_{i}"]
            logical = manifest["dtypes"][i]
            if logical in _EXT_DTYPES:
                a = a.view(_EXT_DTYPES[logical][0])
            leaves.append(a)
        flat_t, treedef = jax.tree.flatten(template)
        assert len(flat_t) == len(leaves), "checkpoint/template mismatch"
        # cast through jax: numpy lacks native casts for ml_dtypes (bf16/f8)
        restored = [
            jax.numpy.asarray(l).astype(t.dtype).reshape(t.shape)
            if hasattr(t, "dtype") else l
            for l, t in zip(leaves, flat_t)
        ]
        return jax.tree.unflatten(treedef, restored), step
