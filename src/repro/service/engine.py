"""The long-lived `JasdaService`: open-loop auction rounds with SLOs.

The closed-loop simulator drains a pre-drawn workload; the service is
the production shape the ROADMAP's "heavy traffic" north star asks for:
an event-driven :class:`~repro.service.arrivals.ArrivalProcess` feeds a
PERSISTENT :class:`~repro.core.scheduler.JasdaScheduler`, rounds fire on
a fixed cadence through the pipelined prepare/settle path, and every
job's admit → announce → award → complete path is timestamped into
streaming SLO quantiles (:mod:`repro.service.metrics`).

The loop reuses the simulator's heap-event discipline verbatim
(``core/events.py``: same kinds, same equal-time ordering, same
:class:`ExecutionPlumbing` launch/complete model), so open-loop replays
inherit the byte-identity guarantees the closed-loop tests pin:

* a fixed-seed soak is deterministic — identical award log and
  :class:`ServiceStats` across two runs;
* a crash-restart from a periodic :class:`CheckpointStore` snapshot
  resumes mid-stream and replays byte-identically to the uncrashed run
  (the service object IS the checkpoint payload: scheduler + calibrator
  + arrival rng + event heap + executor + metrics in one pickle graph).

Back-pressure: each arrival passes through the configured
:class:`~repro.service.admission.AdmissionPolicy`; shed jobs get the
out-of-round ``LOSS_SHED`` feedback.  Health: the PR-7
:class:`~repro.runtime.monitor.HealthMonitor` is wired in — every round
heartbeats the live slices (completions post observed speed), silent
slices are revoked through ``scheduler.revoke_slice`` after
``max_missed`` intervals, and straggling slices get their declared speed
marked down once via ``scheduler.degrade_slice``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.events import (ARRIVE, CANCEL, COMPLETE, DEADLINE, REPARTITION,
                           TICK, EventHeap, ExecutionPlumbing)
from ..core.jobs import AgentConfig, JobAgent
from ..core.negotiation.messages import build_shed_feedback
from ..core.types import SliceSpec
from ..runtime.monitor import HealthConfig, HealthMonitor
from .admission import AcceptAll, AdmissionPolicy, BoundedQueue, \
    queue_bound_for_bucket
from .arrivals import ArrivalProcess, DeadlineExpired, JobArrival, JobCancel
from .metrics import ServiceMetrics, ServiceStats

__all__ = ["ServiceConfig", "JasdaService", "AwardRecord"]


@dataclass(frozen=True)
class ServiceConfig:
    """Knobs of one service deployment (frozen; rides the checkpoint)."""

    round_dt: float = 1.0  # auction cadence (a round every round_dt)
    t_end: float = 500.0  # default soak horizon for run()
    seed: int = 0  # executor noise stream (arrivals carry their own seed)
    runtime_cv: float = 0.1  # execution log-normal noise (as SimConfig)
    check_capacity: bool = True
    pipeline: bool = True  # double-buffer rounds (core/pipeline.py)
    # largest pow2 scoring bucket the deployment budgets one executable
    # for: BoundedQueue(None) resolves its depth cap from this
    # (admission.queue_bound_for_bucket)
    max_bucket_m: int = 512
    # bidding strategy for admitted jobs (None = GreedyChunking default)
    strategy: object = None
    keep_award_log: bool = True  # the soak ledger (determinism tests)
    # health policing (wired to runtime.monitor.HealthMonitor)
    heartbeat_interval: Optional[float] = None  # None → round_dt
    max_missed: int = 3
    straggler_ratio: float = 0.6
    # dynamic repartitioning (core/repartition.py): a RepartitionPolicy
    # ticked on the event heap every ``repartition_dt`` (None → round_dt),
    # strictly AFTER the round sharing its timestamp (between rounds).
    # None disables the subsystem; StaticInventory runs it but proposes
    # nothing — both byte-identical to the pre-repartition service.
    repartition: object = None
    repartition_dt: Optional[float] = None
    # preemption-aware recovery (core/repartition.py MigrationPlanner):
    # a MigrationConfig (or True for defaults) arms the revocation ladder
    # — dead slices are evacuated (migrate → preempt-with-credit →
    # revoke-lossy) instead of revoked outright.  None keeps the lossy
    # PR-7 path byte-identically.
    migration: object = None


@dataclass(frozen=True)
class AwardRecord:
    """One award-log row: enough to compare two soaks byte-for-byte."""

    round: int
    t: float
    variant_id: str
    job_id: str
    slice_id: str


class JasdaService:
    """A persistent auction serving an open-loop arrival stream.

    Drive with :meth:`run` (a soak to a horizon, optionally checkpointed)
    or :meth:`step_round` batches via repeated ``run`` calls on the same
    instance.  The instance is the checkpoint payload: restore with
    :meth:`restore` and call :meth:`run` again to resume mid-stream.
    """

    # pre-migration checkpoints lack the attribute; unpickled instances
    # fall back to the lossy revocation path
    migration = None

    def __init__(
        self,
        scheduler,
        arrivals: ArrivalProcess,
        *,
        config: Optional[ServiceConfig] = None,
        admission: Optional[AdmissionPolicy] = None,
        monitor: Optional[HealthMonitor] = None,
    ):
        self.cfg = config or ServiceConfig()
        self.scheduler = scheduler
        self.arrivals = arrivals
        self.admission = admission or AcceptAll()
        if (isinstance(self.admission, BoundedQueue)
                and self.admission.max_queue is None):
            self.admission.max_queue = queue_bound_for_bucket(
                self.cfg.max_bucket_m)
        hb = (self.cfg.heartbeat_interval
              if self.cfg.heartbeat_interval is not None
              else self.cfg.round_dt)
        self.monitor = monitor or HealthMonitor(HealthConfig(
            heartbeat_interval=hb, max_missed=self.cfg.max_missed,
            straggler_ratio=self.cfg.straggler_ratio))
        self.heap = EventHeap()
        self.exec = ExecutionPlumbing(
            scheduler, self.heap, np.random.default_rng(self.cfg.seed),
            runtime_cv=self.cfg.runtime_cv,
            check_capacity=self.cfg.check_capacity)
        self.metrics = ServiceMetrics()
        self.award_log: List[AwardRecord] = []
        self.now = 0.0
        self.round_count = 0
        self.dead_slices: Dict[str, SliceSpec] = {}
        self._degraded: set = set()
        self._muted: set = set()  # fault hook: slices whose host went silent
        for sid in scheduler.slices:
            self.monitor.register(sid, 0.0)
        self.heap.push(0.0, TICK)
        self.migration = None
        if self.cfg.migration is not None:
            from ..core.repartition import MigrationConfig, MigrationPlanner

            mig_cfg = (self.cfg.migration
                       if isinstance(self.cfg.migration, MigrationConfig)
                       else None)
            self.migration = MigrationPlanner(scheduler, mig_cfg)
        self.repartition = None
        if self.cfg.repartition is not None:
            from ..core.repartition import RepartitionCoordinator

            self.repartition = RepartitionCoordinator(
                scheduler, self.cfg.repartition, migration=self.migration)
            # first opportunity at t=0 orders AFTER the first round
            # (REPARTITION > TICK at equal timestamps)
            self.heap.push(0.0, REPARTITION)

    # -- fault hooks (tests / chaos drivers) -------------------------------
    def mute_slice(self, slice_id: str) -> None:
        """Stop a slice's heartbeats (simulates a silent host); the
        monitor will declare it dead after ``max_missed`` intervals and
        the service revokes it."""
        self._muted.add(slice_id)

    def unmute_slice(self, slice_id: str) -> None:
        self._muted.discard(slice_id)

    # -- the loop ----------------------------------------------------------
    def run(self, t_end: Optional[float] = None, *, checkpoint=None,
            checkpoint_every: int = 50) -> ServiceStats:
        """Run the service loop until ``t_end`` (default config horizon).

        With ``checkpoint`` (a :class:`~repro.checkpoint.CheckpointStore`)
        the FULL service state is snapshotted before every
        ``checkpoint_every``-th round — speculation flushed first, so a
        snapshot never captures an in-flight round (the simulator's
        protocol).  Returns the final :class:`ServiceStats`.
        """
        cfg = self.cfg
        horizon = cfg.t_end if t_end is None else float(t_end)
        pipe = None
        if cfg.pipeline and hasattr(self.scheduler, "_prepare_round"):
            from ..core.pipeline import RoundPipeline

            pipe = RoundPipeline(self.scheduler)

        while self.heap:
            if checkpoint is not None and self.heap.peek()[1] == TICK:
                if self.round_count % max(1, checkpoint_every) == 0:
                    if pipe is not None:
                        pipe.flush()
                    checkpoint.save_state(self.round_count, self)
            t, kind, _seq, payload = self.heap.pop()
            if t > horizon:
                break
            self.now = t
            if kind == TICK:
                self._on_tick(t, horizon, pipe)
            elif kind == COMPLETE:
                self._on_complete(payload, t)
            elif kind == ARRIVE:
                self._on_arrival(payload, t)
            elif kind == CANCEL:
                self._on_cancel(payload.job_id, t, expired=False)
            elif kind == DEADLINE:
                self._on_cancel(payload.job_id, t, expired=True)
            elif kind == REPARTITION:
                self._on_repartition(t, horizon)

        if pipe is not None:
            pipe.flush()
        return self.stats()

    @classmethod
    def restore(cls, store, step: Optional[int] = None) -> "JasdaService":
        """Resume a checkpointed service (crash recovery).

        The restored object picks up mid-stream: the event heap still
        holds the round tick the snapshot was taken before, the arrival
        generator resumes its draw sequence, and a subsequent
        :meth:`run` replays byte-identically to the uncrashed service.
        """
        svc, _step = store.restore_state(step)
        if not isinstance(svc, cls):
            raise TypeError(
                f"checkpoint holds {type(svc).__name__}, not a {cls.__name__}")
        return svc

    # -- event handlers ----------------------------------------------------
    def _on_tick(self, now: float, horizon: float, pipe) -> None:
        cfg = self.cfg
        # stage the next round-interval of arrivals so they interleave
        # with this heap (an arrival at t ∈ (now, now+dt] pops before the
        # tick at now+dt: ARRIVE orders before TICK at equal timestamps)
        for ev in self.arrivals.take_until(min(now + cfg.round_dt, horizon)):
            if isinstance(ev, JobArrival):
                self.heap.push(ev.t, ARRIVE, ev)
            elif isinstance(ev, JobCancel):
                self.heap.push(ev.t, CANCEL, ev)
            elif isinstance(ev, DeadlineExpired):
                self.heap.push(ev.t, DEADLINE, ev)
        # health: heartbeat live slices (muted ones go silent), then police
        for sid in self.scheduler.slices:
            if sid not in self._muted:
                self.monitor.heartbeat(sid, now)
        self._police_slices(now)
        # the auction round (pipelined prepare/settle when available)
        self.metrics.n_rounds += 1
        self.round_count += 1
        nxt = now + cfg.round_dt
        if pipe is not None:
            rr = pipe.tick(now, next_time=nxt if nxt <= horizon else None)
        else:
            rr = self.scheduler.run_round(now)
        if rr is not None:
            # every live job saw this announcement; first-seen is the
            # announce timestamp of its decision path
            for job_id in self.scheduler.agents:
                self.metrics.announced(job_id, now)
            for v in rr.selected:
                self.metrics.awarded(v.job_id, now)
                if cfg.keep_award_log:
                    self.award_log.append(AwardRecord(
                        self.round_count, now, v.variant_id, v.job_id,
                        v.slice_id))
            self.exec.pending.extend(rr.selected)
        self.exec.launch_due(now, cfg.round_dt, self.dead_slices)
        if nxt <= horizon:
            self.heap.push(nxt, TICK)

    def _on_repartition(self, now: float, horizon: float) -> None:
        """Between-rounds repartition opportunity (periodic heap event).

        Coordinator mutations bump the scheduler epoch, so a pipelined
        speculative prep built against the old inventory is discarded by
        the normal validation protocol — no special flush here.
        """
        if self.repartition is not None:
            self.repartition.tick(now, self.exec)
            nxt = now + (self.cfg.repartition_dt
                         if self.cfg.repartition_dt is not None
                         else self.cfg.round_dt)
            if nxt <= horizon:
                self.heap.push(nxt, REPARTITION)

    def _on_arrival(self, ev: JobArrival, now: float) -> None:
        self.metrics.n_arrived += 1
        agent = JobAgent(ev.spec, AgentConfig(strategy=self.cfg.strategy))
        # the back-pressure boundary is the whole live bid pool: every
        # unfinished agent contributes pooled bid rows each round, so the
        # pow2-bucket budget bounds THIS set, not just never-awarded jobs
        queue = [a for a in self.scheduler.agents.values() if not a.finished]
        admit, to_shed = self.admission.on_arrival(agent, now, queue)
        for victim in to_shed:
            jid = victim.spec.job_id
            # a victim may already hold awards: cancel its queued chunks
            # (releasing their reservations); a chunk already running
            # finishes on its own and settles against a departed agent
            for v in self.exec.drop_pending_job(jid):
                self.scheduler.fail(v, now)
            if self.scheduler.shed_job(jid, now):
                self.metrics.n_shed += 1
                self.metrics.dropped(jid)
        if admit:
            self.scheduler.add_job(agent, now)
            self.metrics.admitted(ev.spec.job_id, now)
        else:
            # never entered the scheduler: notify the agent directly with
            # the same LOSS_SHED broadcast shed_job would have built
            agent.observe_feedback(
                build_shed_feedback(now, [ev.spec.job_id]))
            self.metrics.n_shed += 1

    def _on_complete(self, slice_id: str, now: float) -> None:
        done = self.exec.complete(slice_id, now)
        if done is None:
            return
        v, dur_actual = done
        # observed/declared speed feeds the straggler EWMA; >1 (early
        # finish) is fine, the EWMA is what's thresholded
        observed = float(np.clip(v.duration / max(dur_actual, 1e-9),
                                 0.0, 2.0))
        self.monitor.heartbeat(slice_id, now, observed_speed=observed)
        agent = self.scheduler.agents.get(v.job_id)
        if agent is not None and agent.finished:
            self.metrics.completed(v.job_id, now, agent.spec.total_work)
            # pool hygiene for the long-lived service: finished agents
            # leave the biddable pool; stray over-committed chunks are
            # cancelled (their reservations released)
            for leftover in self.exec.drop_pending_job(v.job_id):
                self.scheduler.fail(leftover, now)
            self.scheduler.remove_job(v.job_id)

    def _on_cancel(self, job_id: str, now: float, *, expired: bool) -> None:
        agent = self.scheduler.agents.get(job_id)
        if agent is None or agent.finished:
            return  # already done / already gone (shed or cancelled)
        # non-preemptive: a chunk already running finishes on its own (its
        # completion is harmless — the agent is gone by then); queued
        # not-yet-launched chunks are cancelled and their reservations
        # released
        for v in self.exec.drop_pending_job(job_id):
            self.scheduler.fail(v, now)
        self.scheduler.remove_job(job_id)
        if expired:
            self.metrics.n_expired += 1
        else:
            self.metrics.n_cancelled += 1
        self.metrics.dropped(job_id)

    # -- health policing ---------------------------------------------------
    def _police_slices(self, now: float) -> None:
        """PR-7's two monitor halves, finally connected to the loop."""
        for sid in self.monitor.dead_slices(now):
            if sid in self.scheduler.slices:
                spec = self.scheduler.slices[sid].spec
                if self.migration is not None:
                    # revocation ladder: migrate what fits elsewhere,
                    # credit checkpointed progress, lose only the rest
                    self.migration.evacuate(sid, now, self.exec)
                else:
                    self.exec.fail_running(sid, now)
                    self.scheduler.revoke_slice(sid, now)
                    self.exec.drop_pending(sid)
                self.dead_slices[sid] = spec
                self.metrics.n_revoked_slices += 1
            self.monitor.remove(sid)
        for sid in self.monitor.stragglers():
            if sid in self.scheduler.slices and sid not in self._degraded:
                # mark the declared speed down to the observed EWMA once:
                # planning and calibration now see the slice as it is
                factor = float(np.clip(self.monitor.speed(sid), 0.1, 1.0))
                self.scheduler.degrade_slice(sid, factor)
                self._degraded.add(sid)
                self.metrics.n_degraded_slices += 1

    # -- reporting ---------------------------------------------------------
    def stats(self) -> ServiceStats:
        live = [a for a in self.scheduler.agents.values() if not a.finished]
        queue_depth = sum(1 for a in live if a.n_wins == 0)
        backlog = float(sum(a.biddable_work for a in live))
        sched = self.scheduler
        return self.metrics.snapshot(
            self.now, queue_depth=queue_depth, backlog_work=backlog,
            n_preempted=getattr(sched, "n_preempted_total", 0),
            n_migrated=getattr(sched, "n_migrated_total", 0),
            n_lost_commitments=getattr(sched, "n_lost_total", 0),
            work_credited=getattr(sched, "work_credited_total", 0.0),
            loss_reasons=tuple(sorted(
                getattr(sched, "loss_reasons", {}).items())))
