"""Admission control: back-pressure before the bid pool outgrows a bucket.

Scoring dispatch pads pooled bids to pow2 M-buckets
(``kernels.jasda_score.ops.bucket_m``), so the natural back-pressure
point is the largest bucket the deployment budgets one executable for:
once the queued (never-awarded) jobs would push the pooled bid rows past
``max_bucket_m``, admitting more jobs only grows per-round latency
without growing throughput.  :func:`queue_bound_for_bucket` converts
that bucket budget into a queue-depth bound using a conservative
rows-per-job estimate (chunk-chain alternatives × announced windows).

Three policies, all deterministic given the arrival stream:

* :class:`AcceptAll` — the open-loop control; queue grows unboundedly
  under overload (the degradation the benchmark demonstrates).
* :class:`BoundedQueue` — cap on queued jobs with shed-lowest-score:
  when full, the lowest-priority candidate among {queue ∪ new arrival}
  is shed.  Priority is work-normalized (`spec.priority` per unit of
  remaining work — an SRPT-flavored rule: small jobs are retained
  preferentially because they convert queue slots into completions,
  which is exactly what the goodput SLO measures).
* :class:`TokenBucket` — a classic rate limiter on admissions; sheds
  new arrivals only, never queued jobs.

Shed jobs are notified through the ``LOSS_SHED`` out-of-round feedback
(``negotiation.messages.build_shed_feedback`` / ``scheduler.shed_job``).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

__all__ = [
    "AdmissionPolicy",
    "AcceptAll",
    "BoundedQueue",
    "TokenBucket",
    "queue_bound_for_bucket",
]

#: conservative pooled-rows-per-queued-job estimate: ~2 chunk-chain
#: alternatives × ~8 announced windows a queued job typically bids on
ROWS_PER_JOB_ESTIMATE = 16


def queue_bound_for_bucket(max_bucket_m: int,
                           rows_per_job: int = ROWS_PER_JOB_ESTIMATE) -> int:
    """Queue depth that keeps pooled bid rows within one pow2 bucket."""
    return max(4, int(max_bucket_m) // max(1, int(rows_per_job)))


class AdmissionPolicy:
    """Protocol: decide one arrival's fate given the current bid pool.

    ``queue`` holds ALL live (unfinished) agents — the bid pool whose
    pooled rows the scoring bucket must hold; every member bids each
    round, so this is the set back-pressure bounds.  Returns
    ``(admit_new, to_shed)``: whether the arriving agent enters, plus
    pool members to evict to make room.  Policies are plain picklable
    objects; any internal state (token level) rides the service
    checkpoint.
    """

    name = "base"

    def on_arrival(self, agent, now: float,
                   queue: Sequence) -> Tuple[bool, List]:
        raise NotImplementedError


class AcceptAll(AdmissionPolicy):
    """No back-pressure: every arrival is admitted (the control)."""

    name = "accept_all"

    def on_arrival(self, agent, now: float,
                   queue: Sequence) -> Tuple[bool, List]:
        return True, []


def _priority(agent) -> float:
    """Shed score: declared priority per unit of remaining work (SRPT-ish).

    Higher keeps the slot.  Remaining work uses the agent's live biddable
    pool, so a queued job that somehow made progress is worth more than
    its static spec suggests.
    """
    remaining = max(float(agent.biddable_work), 1e-9)
    return float(agent.spec.priority) / remaining


class BoundedQueue(AdmissionPolicy):
    """Cap the live bid pool at ``max_queue``; shed the lowest-priority job.

    ``max_queue=None`` lets the service engine resolve the bound from its
    configured pow2 bucket budget (``queue_bound_for_bucket``).  When the
    pool is full the arrival competes with its members on
    :func:`_priority` (SRPT-flavored: priority per unit of REMAINING
    work, so nearly-done jobs are effectively unevictable and big fresh
    jobs shed first): if some pool member scores lower it is evicted and
    the arrival admitted, otherwise the arrival itself is shed.  Ties
    break toward keeping the incumbent (stable under replay).
    """

    name = "bounded_queue"

    def __init__(self, max_queue: int = None):
        self.max_queue = max_queue

    def on_arrival(self, agent, now: float,
                   queue: Sequence) -> Tuple[bool, List]:
        bound = self.max_queue if self.max_queue is not None else 64
        if len(queue) < bound:
            return True, []
        new_p = _priority(agent)
        victim = min(queue, key=_priority)
        if _priority(victim) < new_p:
            return True, [victim]
        return False, []


class TokenBucket(AdmissionPolicy):
    """Admission rate limiter: ``rate`` tokens/unit time, ``burst`` cap.

    Deterministic in the arrival timestamps (no clock reads); refill is
    computed lazily from the inter-arrival gap.  Sheds new arrivals only.
    """

    name = "token_bucket"

    def __init__(self, rate: float, burst: float = 8.0):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last = 0.0

    def on_arrival(self, agent, now: float,
                   queue: Sequence) -> Tuple[bool, List]:
        self.tokens = min(self.burst,
                          self.tokens + (now - self._last) * self.rate)
        self._last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True, []
        return False, []
