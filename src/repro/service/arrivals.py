"""Seeded open-loop arrival processes for the streaming service.

The closed-loop simulator pre-draws every job before the run starts
(``make_workload``); production traffic does not work that way.  An
:class:`ArrivalProcess` is a LAZY, seeded stream: the service pulls the
events that fall inside each round interval (``take_until``) and pushes
them onto its :class:`~repro.core.events.EventHeap`, so jobs arrive (and
cancel, and expire) while rounds are in flight.  The stream is a pure
function of its seed — two pulls with the same seed and the same
``take_until`` cut points yield byte-identical event sequences — and the
process object pickles with its generator state, so a service checkpoint
resumes the stream mid-draw without replaying it.

Three processes cover the paper-adjacent load shapes:

* :class:`PoissonArrivals` — memoryless open-loop load at a fixed rate.
* :class:`BurstArrivals` — a 2-state MMPP (Markov-modulated Poisson):
  exponential dwell times switch between a quiet rate and a burst rate.
* :class:`DiurnalArrivals` — sinusoidal rate modulation via Lewis–Shedler
  thinning (a day/night traffic trace).

Each arrival may carry side events drawn from the same generator: a QoS
deadline spawns a :class:`DeadlineExpired` event at the deadline, and a
``cancel_fraction`` coin spawns a :class:`JobCancel` mid-flight — both
delivered through the service's heap with the arrival-stream ordering.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple, Union

import numpy as np

from ..core.trp import fmp_standard
from ..core.types import JobSpec

__all__ = [
    "JobArrival",
    "JobCancel",
    "DeadlineExpired",
    "ArrivalProcess",
    "PoissonArrivals",
    "BurstArrivals",
    "DiurnalArrivals",
]

_GB = 1 << 30


@dataclass(frozen=True)
class JobArrival:
    """A new job enters the system at ``t``."""

    t: float
    spec: JobSpec


@dataclass(frozen=True)
class JobCancel:
    """The submitter withdraws the job at ``t`` (mid-flight)."""

    t: float
    job_id: str


@dataclass(frozen=True)
class DeadlineExpired:
    """The job's QoS deadline passes at ``t``; unfinished work is void."""

    t: float
    job_id: str


ArrivalEvent = Union[JobArrival, JobCancel, DeadlineExpired]


class ArrivalProcess:
    """Base class: seeded lazy stream of typed arrival-side events.

    Subclasses implement :meth:`_next_arrival` (the point process);
    everything else — job synthesis, side events, the monotone
    ``take_until`` cursor — is shared.  ``t_end`` truncates the stream:
    no ARRIVALS are drawn past it (side events of earlier arrivals may
    still land beyond it; the service's horizon cut discards those).
    """

    name = "base"

    def __init__(
        self,
        *,
        seed: int = 0,
        t_end: float = float("inf"),
        work_range: Tuple[float, float] = (10.0, 60.0),
        mem_range_gb: Tuple[float, float] = (2.0, 12.0),
        qos_fraction: float = 0.3,
        deadline_slack: Tuple[float, float] = (2.0, 6.0),
        cancel_fraction: float = 0.0,
        prefix: str = "S",
    ):
        self.seed = seed
        self.t_end = float(t_end)
        self.work_range = work_range
        self.mem_range_gb = mem_range_gb
        self.qos_fraction = qos_fraction
        self.deadline_slack = deadline_slack
        self.cancel_fraction = cancel_fraction
        self.prefix = prefix
        self.rng = np.random.default_rng(seed)
        self._n = 0  # jobs emitted (names stay dense per seed)
        self._stage_seq = 0  # deterministic equal-time ordering in staged
        self._last_t = 0.0  # time of the previous arrival
        self._next_t: Optional[float] = None  # drawn-ahead arrival time
        self._exhausted = False
        # side events (cancel/deadline) drawn alongside their arrival but
        # timestamped later; drained by take_until as their times pass
        self._staged: List[Tuple[float, int, ArrivalEvent]] = []

    # -- the point process (subclass hook) --------------------------------
    def _next_arrival(self, prev_t: float) -> float:
        """Absolute time of the next arrival after ``prev_t``."""
        raise NotImplementedError

    # -- shared machinery --------------------------------------------------
    def _stage(self, t: float, event: ArrivalEvent) -> None:
        self._staged.append((t, self._stage_seq, event))
        self._stage_seq += 1

    def _draw_job(self, ta: float) -> None:
        """Synthesize one job at ``ta`` plus its side events.

        Same distribution family as ``make_workload`` (log-uniform work,
        uniform steady memory, warmup/steady/spike FMP, uniform deadline
        slack) so closed-loop and open-loop scenarios stay comparable.
        """
        rng = self.rng
        i = self._n
        self._n += 1
        lo, hi = self.work_range
        work = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        steady = rng.uniform(*self.mem_range_gb) * _GB
        fmp = fmp_standard(0.3 * steady, steady, 0.1 * steady, rel_sigma=0.03)
        deadline = None
        if rng.uniform() < self.qos_fraction:
            deadline = ta + work * rng.uniform(*self.deadline_slack)
        job_id = f"{self.prefix}{i:04d}"
        spec = JobSpec(
            job_id=job_id,
            arrival_time=ta,
            total_work=work,
            fmp=fmp,
            qos_deadline=deadline,
        )
        self._stage(ta, JobArrival(ta, spec))
        if deadline is not None:
            self._stage(deadline, DeadlineExpired(deadline, job_id))
        if self.cancel_fraction > 0 and rng.uniform() < self.cancel_fraction:
            tc = ta + work * rng.uniform(0.5, 3.0)
            self._stage(tc, JobCancel(tc, job_id))

    def take_until(self, t: float) -> List[ArrivalEvent]:
        """All events with timestamp ≤ ``t``, in deterministic order.

        Advances the stream cursor; calls must pass non-decreasing ``t``
        (the service pulls once per round).  Events are ordered by
        ``(timestamp, draw order)`` so replays are byte-identical per
        seed regardless of the cut points.
        """
        while not self._exhausted:
            if self._next_t is None:
                nt = self._next_arrival(self._last_t)
                if nt > self.t_end:
                    self._exhausted = True
                    break
                self._next_t = nt
            if self._next_t > t:
                break
            self._last_t = self._next_t
            self._next_t = None
            self._draw_job(self._last_t)
        due = sorted(e for e in self._staged if e[0] <= t)
        self._staged = [e for e in self._staged if e[0] > t]
        return [ev for _, _, ev in due]

    @property
    def n_emitted(self) -> int:
        return self._n


class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at a fixed ``rate`` (jobs per unit time)."""

    name = "poisson"

    def __init__(self, rate: float, **kw):
        super().__init__(**kw)
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)

    def _next_arrival(self, prev_t: float) -> float:
        return prev_t + self.rng.exponential(1.0 / self.rate)


class BurstArrivals(ArrivalProcess):
    """2-state MMPP: quiet/burst rates with exponential dwell times.

    The modulating chain starts quiet; rate switches are simulated
    exactly (an inter-arrival draw that crosses the switch point is
    re-drawn from the new state's rate starting at the switch), so the
    stream is a faithful Markov-modulated Poisson process, not a blend.
    """

    name = "burst"

    def __init__(
        self,
        rate_quiet: float,
        rate_burst: float,
        *,
        mean_dwell_quiet: float = 80.0,
        mean_dwell_burst: float = 20.0,
        **kw,
    ):
        super().__init__(**kw)
        if min(rate_quiet, rate_burst) <= 0:
            raise ValueError("both rates must be > 0")
        self.rate_quiet = float(rate_quiet)
        self.rate_burst = float(rate_burst)
        self.mean_dwell_quiet = float(mean_dwell_quiet)
        self.mean_dwell_burst = float(mean_dwell_burst)
        self._burst = False
        self._switch_t = self.rng.exponential(self.mean_dwell_quiet)

    def _next_arrival(self, prev_t: float) -> float:
        t = prev_t
        while True:
            rate = self.rate_burst if self._burst else self.rate_quiet
            candidate = t + self.rng.exponential(1.0 / rate)
            if candidate <= self._switch_t:
                return candidate
            # memorylessness: restart the draw at the switch point under
            # the new state's rate
            t = self._switch_t
            self._burst = not self._burst
            dwell = self.rng.exponential(
                self.mean_dwell_burst if self._burst else self.mean_dwell_quiet)
            self._switch_t = t + dwell


class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal day/night load via Lewis–Shedler thinning.

    Instantaneous rate ``λ(t) = peak_rate · (floor + (1−floor) · ½(1 +
    sin(2πt/period + phase)))`` — candidates are drawn at ``peak_rate``
    and accepted with probability ``λ(t)/peak_rate``, the standard exact
    simulation of an inhomogeneous Poisson process.
    """

    name = "diurnal"

    def __init__(
        self,
        peak_rate: float,
        *,
        period: float = 500.0,
        floor: float = 0.2,
        phase: float = 0.0,
        **kw,
    ):
        super().__init__(**kw)
        if peak_rate <= 0:
            raise ValueError(f"peak_rate must be > 0, got {peak_rate}")
        if not 0.0 <= floor <= 1.0:
            raise ValueError(f"floor must be in [0, 1], got {floor}")
        self.peak_rate = float(peak_rate)
        self.period = float(period)
        self.floor = float(floor)
        self.phase = float(phase)

    def _rate_at(self, t: float) -> float:
        mod = 0.5 * (1.0 + np.sin(2.0 * np.pi * t / self.period + self.phase))
        return self.peak_rate * (self.floor + (1.0 - self.floor) * mod)

    def _next_arrival(self, prev_t: float) -> float:
        t = prev_t
        while True:
            t += self.rng.exponential(1.0 / self.peak_rate)
            if self.rng.uniform() * self.peak_rate <= self._rate_at(t):
                return t
