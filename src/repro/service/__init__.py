"""Streaming service mode: a long-lived JASDA auction under open-loop load.

See :mod:`repro.service.engine` for the loop, :mod:`repro.service.arrivals`
for the seeded traffic models, :mod:`repro.service.admission` for
back-pressure, and :mod:`repro.service.metrics` for the streaming SLO
quantiles.
"""
from .admission import (AcceptAll, AdmissionPolicy, BoundedQueue, TokenBucket,
                        queue_bound_for_bucket)
from .arrivals import (ArrivalProcess, BurstArrivals, DeadlineExpired,
                       DiurnalArrivals, JobArrival, JobCancel, PoissonArrivals)
from .engine import AwardRecord, JasdaService, ServiceConfig
from .metrics import JobTimeline, P2Quantile, ServiceMetrics, ServiceStats

__all__ = [
    "AcceptAll",
    "AdmissionPolicy",
    "ArrivalProcess",
    "AwardRecord",
    "BoundedQueue",
    "BurstArrivals",
    "DeadlineExpired",
    "DiurnalArrivals",
    "JasdaService",
    "JobArrival",
    "JobCancel",
    "JobTimeline",
    "P2Quantile",
    "PoissonArrivals",
    "ServiceConfig",
    "ServiceMetrics",
    "ServiceStats",
    "TokenBucket",
    "queue_bound_for_bucket",
]
