"""Streaming SLO metrics for the service: O(1)-memory quantiles + counters.

A long-lived service cannot buffer every latency sample, so the p50/p95/
p99 decision-latency quantiles use the P² algorithm (Jain & Chlamtac,
CACM 1985): five markers per quantile, parabolic interpolation on every
observation, no buffers.  The estimator is deterministic given the
observation order — which the service's seeded event loop guarantees —
so two soaks with the same seed produce byte-identical
:class:`ServiceStats` snapshots (the determinism contract tested in
``tests/test_service.py``).

Per-job lifecycle timestamps (admit → first announce → first award →
complete) are kept only while the job is in flight; on completion the
latencies fold into the streaming estimators and the timeline is
dropped, so the metrics footprint stays bounded by the live queue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["P2Quantile", "JobTimeline", "ServiceMetrics", "ServiceStats"]


class P2Quantile:
    """P² streaming estimator of a single quantile (no sample buffer).

    Jain & Chlamtac's five-marker scheme: marker heights approximate the
    (0, q/2, q, (1+q)/2, 1) quantiles; desired positions advance with
    every observation and heights adjust by a piecewise-parabolic (PP)
    step, falling back to linear when the parabola would cross a
    neighbor.  Until five observations exist the exact order statistic is
    returned.  Picklable; deterministic in observation order.
    """

    __slots__ = ("q", "n", "_heights", "_pos", "_desired", "_incr")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = float(q)
        self.n = 0
        self._heights: List[float] = []
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._desired = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self._incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def observe(self, x: float) -> None:
        x = float(x)
        self.n += 1
        if len(self._heights) < 5:
            self._heights.append(x)
            self._heights.sort()
            return
        h = self._heights
        # locate the cell; clamp the extremes to the new observation
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self._pos[i] += 1.0
        for i in range(5):
            self._desired[i] += self._incr[i]
        # adjust the three interior markers
        for i in (1, 2, 3):
            d = self._desired[i] - self._pos[i]
            if (d >= 1.0 and self._pos[i + 1] - self._pos[i] > 1.0) or (
                    d <= -1.0 and self._pos[i - 1] - self._pos[i] < -1.0):
                s = 1.0 if d >= 0 else -1.0
                hp = self._parabolic(i, s)
                if not h[i - 1] < hp < h[i + 1]:
                    hp = self._linear(i, s)
                h[i] = hp
                self._pos[i] += s

    def _parabolic(self, i: int, s: float) -> float:
        h, p = self._heights, self._pos
        return h[i] + s / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1])
        )

    def _linear(self, i: int, s: float) -> float:
        h, p = self._heights, self._pos
        j = i + int(s)
        return h[i] + s * (h[j] - h[i]) / (p[j] - p[i])

    def value(self) -> float:
        """Current estimate (NaN before the first observation)."""
        if not self._heights:
            return float("nan")
        if len(self._heights) < 5 or self.n < 5:
            # exact small-sample order statistic (nearest-rank)
            h = sorted(self._heights)
            idx = min(len(h) - 1, max(0, round(self.q * (len(h) - 1))))
            return h[int(idx)]
        return self._heights[2]


@dataclass
class JobTimeline:
    """Lifecycle timestamps of one in-flight job (service bookkeeping)."""

    admit: float
    announce: Optional[float] = None  # first round the job could bid in
    award: Optional[float] = None  # first award
    complete: Optional[float] = None


@dataclass(frozen=True)
class ServiceStats:
    """Value-comparable snapshot of a service's counters and SLO metrics.

    Latency semantics: ``latency_*`` is admit → first award (the decision
    latency an external submitter observes); ``announce_award_*`` is
    first announce → first award (the pure auction-path latency the
    paper's responsiveness claim is about — it excludes time spent queued
    before the first round).  Goodput counts only COMPLETED jobs' work
    per unit elapsed time, so half-done jobs at the horizon do not
    inflate it.
    """

    t: float
    n_arrived: int
    n_admitted: int
    n_shed: int
    n_cancelled: int
    n_expired: int
    n_completed: int
    n_rounds: int
    n_awards: int
    n_revoked_slices: int
    n_degraded_slices: int
    queue_depth: int
    backlog_work: float
    completed_work: float
    goodput: float
    round_rate: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    announce_award_p50: float
    announce_award_p95: float
    announce_award_p99: float
    # disruption accounting (the revocation ladder's audit surface),
    # defaulted at the end so pre-migration snapshots stay comparable:
    # commitments preempted with credit / migrated across slices / lost
    # outright, granule-aligned work credited, and the per-reason loss
    # histogram as sorted (reason, count) pairs (value-comparable)
    n_preempted: int = 0
    n_migrated: int = 0
    n_lost_commitments: int = 0
    work_credited: float = 0.0
    loss_reasons: tuple = ()

    def summary(self) -> str:
        return (
            f"t={self.t:.0f} rounds={self.n_rounds} "
            f"arrived={self.n_arrived} admitted={self.n_admitted} "
            f"shed={self.n_shed} completed={self.n_completed} "
            f"queue={self.queue_depth} goodput={self.goodput:.3f} "
            f"p50={self.latency_p50:.1f} p99={self.latency_p99:.1f}"
        )


class ServiceMetrics:
    """Mutable metrics state the engine drives; snapshots to ServiceStats."""

    def __init__(self):
        self.n_arrived = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.n_cancelled = 0
        self.n_expired = 0
        self.n_completed = 0
        self.n_rounds = 0
        self.n_awards = 0
        self.n_revoked_slices = 0
        self.n_degraded_slices = 0
        self.completed_work = 0.0
        self.timelines: Dict[str, JobTimeline] = {}
        self._latency = {q: P2Quantile(q) for q in (0.5, 0.95, 0.99)}
        self._announce_award = {q: P2Quantile(q) for q in (0.5, 0.95, 0.99)}

    # -- lifecycle hooks ---------------------------------------------------
    def admitted(self, job_id: str, now: float) -> None:
        self.n_admitted += 1
        self.timelines[job_id] = JobTimeline(admit=now)

    def announced(self, job_id: str, now: float) -> None:
        tl = self.timelines.get(job_id)
        if tl is not None and tl.announce is None:
            tl.announce = now

    def awarded(self, job_id: str, now: float) -> bool:
        """Record an award; returns True on the job's FIRST award (the
        decision-latency sample)."""
        self.n_awards += 1
        tl = self.timelines.get(job_id)
        if tl is None or tl.award is not None:
            return False
        tl.award = now
        for est in self._latency.values():
            est.observe(now - tl.admit)
        base = tl.announce if tl.announce is not None else tl.admit
        for est in self._announce_award.values():
            est.observe(now - base)
        return True

    def completed(self, job_id: str, now: float, work: float) -> None:
        self.n_completed += 1
        self.completed_work += float(work)
        tl = self.timelines.pop(job_id, None)
        if tl is not None:
            tl.complete = now

    def dropped(self, job_id: str) -> None:
        """Forget a job that left without completing (shed/cancel/expire)."""
        self.timelines.pop(job_id, None)

    # -- snapshot ----------------------------------------------------------
    def snapshot(self, now: float, *, queue_depth: int,
                 backlog_work: float, n_preempted: int = 0,
                 n_migrated: int = 0, n_lost_commitments: int = 0,
                 work_credited: float = 0.0,
                 loss_reasons: tuple = ()) -> ServiceStats:
        elapsed = max(now, 1e-9)
        return ServiceStats(
            t=float(now),
            n_arrived=self.n_arrived,
            n_admitted=self.n_admitted,
            n_shed=self.n_shed,
            n_cancelled=self.n_cancelled,
            n_expired=self.n_expired,
            n_completed=self.n_completed,
            n_rounds=self.n_rounds,
            n_awards=self.n_awards,
            n_revoked_slices=self.n_revoked_slices,
            n_degraded_slices=self.n_degraded_slices,
            queue_depth=int(queue_depth),
            backlog_work=float(backlog_work),
            completed_work=float(self.completed_work),
            goodput=float(self.completed_work / elapsed),
            round_rate=float(self.n_rounds / elapsed),
            latency_p50=self._latency[0.5].value(),
            latency_p95=self._latency[0.95].value(),
            latency_p99=self._latency[0.99].value(),
            announce_award_p50=self._announce_award[0.5].value(),
            announce_award_p95=self._announce_award[0.95].value(),
            announce_award_p99=self._announce_award[0.99].value(),
            n_preempted=int(n_preempted),
            n_migrated=int(n_migrated),
            n_lost_commitments=int(n_lost_commitments),
            work_credited=float(work_credited),
            loss_reasons=tuple(loss_reasons),
        )
