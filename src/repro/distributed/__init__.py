"""Distribution layer: sharding rules, collectives, compression, pipeline."""
from .sharding import ShardingRules, named_sharding_tree, resolve_param_specs  # noqa: F401
