"""Sharding rules: logical axis names → mesh PartitionSpecs.

Parameters carry LOGICAL spec tuples ("fsdp" | "model" | None per dim,
models/params.py); activations are constrained by KIND strings inside the
model code.  This module resolves both against a concrete mesh:

  fsdp  → ``fsdp_axes``  (single-pod: ("data",); multi-pod: ("pod","data"))
  model → ("model",)

Activation kinds:
  btd   (B, S, D)        residual stream
  btf   (B, S, F)        mlp hidden          — F on model
  btm   (B, S, Dm)       ssm/rglru inner     — Dm on model
  bshk  (B, S, H, hd)    q/attn-out          — H or hd on model (attn_shard)
  btkk  (B, T, Hkv, hd)  k/v (+cache)        — kv heads if divisible; decode
                         caches may instead shard T on model (flash-decode,
                         ``shard_kv_seq``)
  btv   (B, S, Vp)       logits              — Vp on model
  gecd/gecf              MoE dispatch tensors

``batch_axes`` shards B; ``seq_axes`` optionally shards S (sequence
parallelism for long-context cells where B < mesh rows).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

__all__ = ["ShardingRules", "resolve_param_specs", "named_sharding_tree",
           "mesh_size", "auction_row_spec", "replicated_spec", "spec_sharded"]


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    fsdp_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    batch_axes: Tuple[str, ...] = ("data",)
    seq_axes: Tuple[str, ...] = ()  # sequence parallelism (activations)
    attn_shard: str = "heads"  # heads | headdim (must match the config)
    kv_heads_shardable: bool = True
    shard_kv_seq: bool = False  # decode KV cache: T on model axis
    shard_moe_expert: bool = True  # experts on model (else expert-FFN dim)

    # -- helpers -------------------------------------------------------------
    def _b(self):
        return self.batch_axes if self.batch_axes else None

    def _s(self):
        return self.seq_axes if self.seq_axes else None

    def _m(self):
        return self.model_axes if self.model_axes else None

    def spec(self, kind: str) -> PS:
        b, s, m = self._b(), self._s(), self._m()
        # sequence parallelism shares the model axis: only the residual
        # stream (btd) carries the seq sharding; TP'd interiors drop it
        # (GSPMD inserts the all-gather/reduce-scatter at the boundary)
        s_in = None if (s and m and set(s) & set(m)) else s
        if kind == "btd":
            return PS(b, s, None)
        if kind in ("btf", "btm"):
            return PS(b, s_in, m)
        if kind == "bshk":
            if self.attn_shard == "heads":
                return PS(b, s_in, m, None)
            return PS(b, s_in, None, m)
        if kind == "btkk":
            if self.shard_kv_seq:
                return PS(b, m, None, None)
            if self.attn_shard == "heads" and self.kv_heads_shardable:
                return PS(b, s_in, m, None)
            if self.attn_shard == "headdim":
                return PS(b, s_in, None, m)
            return PS(b, s_in, None, None)
        if kind == "btv":
            return PS(b, s_in, m)
        if kind == "bshk_seq":  # Ulysses interior: S on model, heads whole
            return PS(b, m, None, None)
        if kind == "btkk_full":  # Ulysses K/V: gathered heads + seq
            return PS(b, None, None, None)
        if kind == "xbtkk":  # stacked cross-attn K/V: (L, B, T, Hkv, hd)
            if self.attn_shard == "heads" and self.kv_heads_shardable:
                return PS(None, b, None, m, None)
            if self.attn_shard == "headdim":
                return PS(None, b, None, None, m)
            return PS(None, b, None, None, None)
        if kind == "gecd":
            return PS(b, m if self.shard_moe_expert else None, None, None)
        if kind == "gecf":
            return PS(b, m, None, None) if self.shard_moe_expert \
                else PS(b, None, None, m)
        raise ValueError(f"unknown activation kind {kind}")

    def act(self, x, kind: str):
        spec = guard_spec(self.spec(kind), x.shape, dict(self.mesh.shape))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    # -- parameter specs --------------------------------------------------------
    def resolve(self, logical: Tuple) -> PS:
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            elif name == "fsdp":
                out.append(self.fsdp_axes if self.fsdp_axes else None)
            elif name == "model":
                out.append(self.model_axes if self.model_axes else None)
            else:
                raise ValueError(f"unknown logical axis {name}")
        return PS(*out)


def guard_spec(spec: PS, shape, mesh_shape: dict) -> PS:
    """Drop spec entries whose mesh extent does not divide the dim.

    (e.g. the 1-token k/v write against a decode cache whose T is
    model-sharded) — avoids GSPMD padding surprises.  Pure function,
    unit-tested directly.
    """
    cleaned = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            cleaned.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            size *= mesh_shape[a]
        cleaned.append(entry if dim % size == 0 else None)
    return PS(*cleaned)


# ---------------------------------------------------------------------------
# Auction-round sharding (launch.mesh.make_auction_mesh consumers)
# ---------------------------------------------------------------------------


def mesh_size(mesh: Optional[Mesh]) -> int:
    """Total device count of a mesh (1 for None — the unsharded case)."""
    if mesh is None:
        return 1
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def auction_row_spec(mesh: Mesh, dim: int) -> PS:
    """Row-sharding spec for a leading auction dim (pooled bids / windows).

    Shards dim 0 over EVERY mesh axis, guarded by :func:`guard_spec`: when
    the mesh extent does not divide ``dim`` the entry is dropped and the
    spec degrades to replicated — the caller then takes the unsharded
    dispatch path instead of tripping GSPMD padding.  Bucketed round shapes
    (pow2 ≥ 256 bids, pow2 ≥ 8 windows) always divide a pow2 auction mesh,
    so in practice the guard only fires on hand-built odd meshes.
    """
    return guard_spec(PS(tuple(mesh.axis_names)), (dim,), dict(mesh.shape))


def replicated_spec() -> PS:
    """The replicated (no-partition) spec for broadcast operands."""
    return PS()


def spec_sharded(spec: PS) -> bool:
    """True when the spec actually partitions something."""
    return any(entry is not None for entry in tuple(spec))


def resolve_param_specs(logical_tree, rules: ShardingRules):
    """Logical spec tuples → PartitionSpec pytree."""
    return jax.tree.map(
        rules.resolve, logical_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PS),
    )
