"""Int8 gradient compression with error feedback (beyond-paper, DESIGN §5).

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links.  We compress per-block to int8 with a f32 scale before the
reduction and keep the quantization residual in an error-feedback buffer so
the bias vanishes over steps (Seide et al. 2014 / 1-bit Adam lineage).

Usage inside the train step (pure, jit-able):

    comp, err = compress(grads, err)        # int8 payload + carried error
    grads = decompress(comp)                 # dequantized f32 view
    # ... psum/all-reduce happens on the int8 payload via GSPMD when the
    # arrays are sharded on the pod axis; here we expose the quantize /
    # dequantize transform and the error feedback accounting.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error", "compress", "decompress", "compressed_allreduce"]

BLOCK = 2048


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_one(g: jnp.ndarray, e: jnp.ndarray):
    g = g.astype(jnp.float32) + e
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(g.shape)
    err = g - deq
    return {"q": q, "scale": scale, "shape": g.shape}, err


def compress(grads, err) -> Tuple[Any, Any]:
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    outs = [_quant_one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = tdef.unflatten([o[0] for o in outs])
    new_err = tdef.unflatten([o[1] for o in outs])
    return comp, new_err


def decompress(comp):
    def one(c):
        n = 1
        for d in c["shape"]:
            n *= d
        deq = (c["q"].astype(jnp.float32) * c["scale"]).reshape(-1)[:n]
        return deq.reshape(c["shape"])
    return jax.tree.map(one, comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_allreduce(grads, err, axis_name: str):
    """shard_map-side helper: quantize → psum(int32) → dequantize.

    int8 payloads are summed in int32 (no overflow for ≤ 2^23 replicas),
    then rescaled by the mean of the per-block scales — an approximation
    whose residual lands in the error-feedback buffer next step.
    """
    comp, new_err = compress(grads, err)

    def reduce_one(c):
        q32 = jax.lax.psum(c["q"].astype(jnp.int32), axis_name)
        scale = jax.lax.pmean(c["scale"], axis_name)
        n = 1
        for d in c["shape"]:
            n *= d
        deq = (q32.astype(jnp.float32) * scale).reshape(-1)[:n]
        nrep = jax.lax.psum(1, axis_name)
        return deq.reshape(c["shape"]) / nrep

    reduced = jax.tree.map(
        reduce_one, comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    return reduced, new_err
