"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs            (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
    collective = collective_bytes_per_device / link_bw        (~50 GB/s)

``cost_analysis()`` on the compiled executable reports PER-DEVICE flops and
bytes (the post-SPMD module is the per-device program — verified against
hand counts).  Collective bytes are parsed from the optimized HLO text:
per-device link traffic per op, ring-algorithm accounting:

    all-gather        out_bytes · (g−1)/g
    reduce-scatter    in_bytes  · (g−1)/g      (= out·(g−1))
    all-reduce        2 · bytes · (g−1)/g
    all-to-all        bytes · (g−1)/g
    collective-permute  bytes

MODEL_FLOPS (global): 6·N·tokens for training (2 fwd + 4 bwd), 2·N_active·tokens
for inference — attention FLOPs excluded by convention, so the reported
MODEL/HLO ratio also exposes attention + dispatch overheads.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip (v5e-class)
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclass
class CollectiveStats:
    bytes_on_link: float = 0.0
    by_kind: Dict[str, float] = field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, b: float) -> None:
        self.bytes_on_link += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.count += 1


def _op_link_bytes(kind: str, out_b: float, g: int) -> float:
    frac = (g - 1) / g
    if kind == "all-gather":
        return out_b * frac
    if kind == "all-reduce":
        return 2.0 * out_b * frac
    if kind == "reduce-scatter":
        return out_b * (g - 1)  # in = out·g ; moved = in·(g−1)/g
    if kind == "all-to-all":
        return out_b * frac
    if kind == "collective-permute":
        return out_b
    return 0.0


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*)?\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _parse_computations(hlo_text: str):
    """Split the module into computations; record collectives/whiles/consts."""
    comps = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m and ("->" in line or line.startswith("ENTRY")):
                cur = m.group(1)
                comps[cur] = {"colls": [], "whiles": [], "consts": []}
                if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                    entry = cur
            continue
        if line == "}" or line.startswith("}"):
            cur = None
            continue
        mw = _WHILE_RE.search(line)
        if mw:
            comps[cur]["whiles"].append((mw.group(1), mw.group(2)))
            continue
        mc = _COLL_RE.search(line)
        if mc:
            out_shape, kind = mc.group(1), mc.group(2).replace("-start", "")
            comps[cur]["colls"].append(
                (kind, _shape_bytes(out_shape), _group_size(line, 0)))
        for c in _CONST_RE.findall(line):
            comps[cur]["consts"].append(int(c))
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    """Trip count ≈ largest plausible loop-bound constant in the condition."""
    cond = comps.get(cond_name)
    if not cond:
        return 1
    cands = [c for c in cond["consts"] if 1 < c < 10**7]
    return max(cands) if cands else 1


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device link bytes over every collective, ×while-loop trip counts.

    XLA prints each while body once; a collective inside the layer scan
    (and inside the microbatch scan around it) executes trips× more often
    than its single HLO occurrence.  We reconstruct the loop nest from the
    condition/body references and multiply through.
    """
    comps, entry = _parse_computations(hlo_text)
    stats = CollectiveStats()
    if entry is None:
        # fall back: flat scan over all lines
        for comp in comps.values():
            for kind, out_b, g in comp["colls"]:
                g = g or n_devices
                if g > 1:
                    stats.add(kind, _op_link_bytes(kind, out_b, g))
        return stats

    seen = set()

    def visit(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        key = (name, mult)
        if key in seen:  # guard against pathological recursion
            return
        seen.add(key)
        for kind, out_b, g in comp["colls"]:
            g = g or n_devices
            if g > 1:
                stats.add(kind, _op_link_bytes(kind, out_b, g) * mult)
        for cond, body in comp["whiles"]:
            trips = _trip_count(comps, cond)
            visit(body, mult * trips)

    visit(entry, 1.0)
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    model_flops_global: float
    memory_stats: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective.bytes_on_link / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def model_vs_hlo(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): useful-compute fraction."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOP/s at the bound implied by the dominant term,
        as a fraction of the cluster's peak FLOP/s."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        achieved = self.model_flops_global / t  # FLOP/s if bound-limited
        return achieved / (self.chips * PEAK_FLOPS)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective.bytes_on_link,
            "collective_by_kind": self.collective.by_kind,
            "n_collectives": self.collective.count,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_global": self.model_flops_global,
            "model_vs_hlo": self.model_vs_hlo,
            "roofline_fraction": self.roofline_fraction,
            "memory": self.memory_stats,
        }


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference)."""
    n = cfg.active_param_count()
    tokens = shape.batch * (shape.seq if shape.kind in ("train", "prefill") else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens
