"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.jsonl.

Usage: python -m repro.launch.report results/final/dryrun.jsonl > tables.md
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def fmt_t(t):
    if t is None:
        return "-"
    if t >= 1.0:
        return f"{t:.2f}s"
    return f"{t*1e3:.2f}ms"


def load(path):
    rows = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return rows


def main(path):
    rows = load(path)
    archs, shapes = [], ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for (a, _, _) in rows:
        if a not in archs:
            archs.append(a)

    print("### §Dry-run — lower+compile per (arch × shape × mesh)\n")
    print("| arch | shape | mesh | status | mem/dev GiB (args+temps) | "
          "collectives (n) | lower+compile s |")
    print("|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            for m in ("single", "multi"):
                r = rows.get((a, s, m))
                if r is None:
                    print(f"| {a} | {s} | {m} | MISSING | | | |")
                    continue
                if "skipped" in r:
                    print(f"| {a} | {s} | {m} | skip (quadratic@524k) | | | |")
                    continue
                if "error" in r:
                    print(f"| {a} | {s} | {m} | ERROR | | | |")
                    continue
                mem = r.get("memory") or {}
                dev = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
                print(f"| {a} | {s} | {m} | ok | {dev/2**30:.2f} | "
                      f"{r.get('n_collectives','-')} | "
                      f"{r.get('t_lower_s',0)}+{r.get('t_compile_s',0)} |")
    print()

    print("### §Roofline — three terms per cell (single-pod, 256 chips)\n")
    print("| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
          "MODEL/HLO | roofline frac | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r = rows.get((a, s, "single"))
            if r is None or "error" in r:
                continue
            if "skipped" in r:
                print(f"| {a} | {s} | - | - | - | skipped | - | - | "
                      f"full attention is quadratic at 524k |")
                continue
            note = _note(r)
            print(f"| {a} | {s} | {fmt_t(r['t_compute_s'])} | "
                  f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} | "
                  f"{r['bottleneck']} | {r['model_vs_hlo']:.2f} | "
                  f"{r['roofline_fraction']:.3f} | {note} |")
    print()

    print("### Multi-pod deltas (512 chips; collective term change)\n")
    print("| arch | shape | t_coll single | t_coll multi | ratio |")
    print("|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r1 = rows.get((a, s, "single"))
            r2 = rows.get((a, s, "multi"))
            if not r1 or not r2 or "skipped" in r1 or "error" in r1 or \
               "skipped" in r2 or "error" in r2:
                continue
            t1, t2 = r1["t_collective_s"], r2["t_collective_s"]
            print(f"| {a} | {s} | {fmt_t(t1)} | {fmt_t(t2)} | "
                  f"{t2/max(t1,1e-12):.2f}x |")


def _note(r):
    b = r["bottleneck"]
    kinds = r.get("collective_by_kind", {})
    if b == "collective" and kinds:
        top = max(kinds, key=kinds.get)
        return f"dominant: {top} ({kinds[top]/2**30:.0f} GiB/dev)"
    if b == "compute":
        return "MXU-bound; raise MODEL/HLO via causal-aware attention"
    return "HBM-bound; params/cache streaming"


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/final/dryrun.jsonl")
