"""Soak launcher for the streaming auction service (repro.service).

Runs a long-lived :class:`JasdaService` on the standard heterogeneous
7-slice cluster under a chosen open-loop arrival process and admission
policy, and reports the final :class:`ServiceStats` (SLO quantiles,
goodput, shed counts).  Deterministic per ``--seed``: two identical
invocations print identical stats.

CPU/dev:
    python -m repro.launch.serve_auction --arrivals poisson --rate 0.5 \
        --t-end 240 --admission bounded --json
Crash-resume demo (run, then rerun with --resume to continue from the
newest checkpoint):
    python -m repro.launch.serve_auction --checkpoint-dir /tmp/svc_ckpt
    python -m repro.launch.serve_auction --checkpoint-dir /tmp/svc_ckpt \
        --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from ..checkpoint import CheckpointError, CheckpointStore
from ..core import JasdaScheduler, SliceSpec
from ..service import (AcceptAll, BoundedQueue, BurstArrivals,
                       DiurnalArrivals, JasdaService, PoissonArrivals,
                       ServiceConfig, TokenBucket)

_GB = 1 << 30


def _cluster():
    """The benchmarks' heterogeneous 7-slice cluster (~12 chips)."""
    return ([SliceSpec("s20", 20 * _GB, n_chips=4),
             SliceSpec("s10a", 10 * _GB, n_chips=2),
             SliceSpec("s10b", 10 * _GB, n_chips=2)]
            + [SliceSpec(f"s5{i}", 5 * _GB, n_chips=1) for i in range(4)])


def _arrivals(args):
    kw = dict(seed=args.seed, work_range=(args.work_min, args.work_max),
              qos_fraction=args.qos_fraction,
              deadline_slack=(args.slack_min, args.slack_max),
              cancel_fraction=args.cancel_fraction)
    if args.arrivals == "poisson":
        return PoissonArrivals(args.rate, **kw)
    if args.arrivals == "burst":
        return BurstArrivals(args.rate, args.burst_rate, **kw)
    if args.arrivals == "diurnal":
        return DiurnalArrivals(args.rate, period=args.period, **kw)
    raise SystemExit(f"unknown arrival process: {args.arrivals}")


def _admission(args):
    if args.admission == "accept-all":
        return AcceptAll()
    if args.admission == "bounded":
        return BoundedQueue(args.max_queue)  # None → engine resolves
    if args.admission == "token-bucket":
        return TokenBucket(args.token_rate, burst=args.token_burst)
    raise SystemExit(f"unknown admission policy: {args.admission}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arrivals", default="poisson",
                    choices=("poisson", "burst", "diurnal"))
    ap.add_argument("--rate", type=float, default=0.5,
                    help="arrival rate (poisson/burst quiet/diurnal peak)")
    ap.add_argument("--burst-rate", type=float, default=1.5,
                    help="MMPP burst-state rate (--arrivals burst)")
    ap.add_argument("--period", type=float, default=500.0,
                    help="diurnal period (--arrivals diurnal)")
    ap.add_argument("--work-min", type=float, default=8.0)
    ap.add_argument("--work-max", type=float, default=40.0)
    ap.add_argument("--qos-fraction", type=float, default=0.3)
    ap.add_argument("--slack-min", type=float, default=2.0)
    ap.add_argument("--slack-max", type=float, default=6.0)
    ap.add_argument("--cancel-fraction", type=float, default=0.0)
    ap.add_argument("--admission", default="accept-all",
                    choices=("accept-all", "bounded", "token-bucket"))
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded-queue pool cap (default: from bucket)")
    ap.add_argument("--token-rate", type=float, default=0.5)
    ap.add_argument("--token-burst", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--t-end", type=float, default=240.0)
    ap.add_argument("--round-dt", type=float, default=1.0)
    ap.add_argument("--max-bucket-m", type=int, default=512)
    ap.add_argument("--no-pipeline", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="periodically snapshot full service state here")
    ap.add_argument("--checkpoint-every", type=int, default=50,
                    help="rounds between snapshots (--checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --checkpoint-dir and continue")
    ap.add_argument("--json", action="store_true",
                    help="emit the final ServiceStats as one JSON line")
    args = ap.parse_args(argv)

    store = None
    if args.checkpoint_dir is not None:
        store = CheckpointStore(args.checkpoint_dir, keep=3)

    if args.resume:
        if store is None:
            raise SystemExit("--resume requires --checkpoint-dir")
        try:
            svc = JasdaService.restore(store)
        except FileNotFoundError:
            raise SystemExit(
                f"no checkpoint to resume in {args.checkpoint_dir}")
        except CheckpointError as e:
            raise SystemExit(f"checkpoint unreadable: {e}")
    else:
        cfg = ServiceConfig(
            round_dt=args.round_dt, t_end=args.t_end, seed=args.seed,
            pipeline=not args.no_pipeline, max_bucket_m=args.max_bucket_m)
        svc = JasdaService(JasdaScheduler(_cluster()), _arrivals(args),
                           config=cfg, admission=_admission(args))

    stats = svc.run(args.t_end, checkpoint=store,
                    checkpoint_every=args.checkpoint_every)
    if args.json:
        print(json.dumps(dataclasses.asdict(stats)))
    else:
        print(stats.summary())
        print(f"  announce->award p50={stats.announce_award_p50:.2f} "
              f"p95={stats.announce_award_p95:.2f} "
              f"p99={stats.announce_award_p99:.2f}")
        print(f"  revoked={stats.n_revoked_slices} "
              f"degraded={stats.n_degraded_slices} "
              f"expired={stats.n_expired} cancelled={stats.n_cancelled}")
    if stats.n_rounds == 0:
        print("error: service ran zero rounds (horizon before first tick?)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
