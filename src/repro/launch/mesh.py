"""Production mesh builders (assignment-fixed shapes).

Functions, not module-level constants: importing this module never touches
jax device state (critical — device count locks on first use).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_chips"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:   (pod=2, data=16, model=16) = 512 chips.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
