"""Production + auction mesh builders (assignment-fixed shapes).

Functions, not module-level constants: importing this module never touches
jax device state (critical — device count locks on first use).

``make_auction_mesh`` is the entry point the sharded auction round uses
(``SchedulerConfig.mesh`` / the ``mesh=`` knob on ``clear_round`` /
``pipelined_clear_rounds``): a 1-axis mesh named ``"bids"`` over a
power-of-two device count, degrading gracefully — never raising — when the
requested shape exceeds what the platform actually has.  On CPU,
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before the
first jax import) provides virtual devices for testing the sharded path
without hardware.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_auction_mesh", "mesh_chips",
           "AUCTION_AXIS"]

#: the single mesh axis the auction shards over: the pooled bid dim of the
#: scoring dispatch and the window dim of the batched WIS settle
AUCTION_AXIS = "bids"


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:   (pod=2, data=16, model=16) = 512 chips.

    Falls back to a 1-axis ``("data",)`` mesh over every local device when
    the fixed shape exceeds what the platform has (CI boxes, virtual-device
    CPU runs) — callers get a working mesh, never an exception.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_needed = 1
    for s in shape:
        n_needed *= s
    if jax.device_count() < n_needed:
        return jax.make_mesh((jax.local_device_count(),), ("data",))
    # axis_types only exists on newer jax; omit it where unavailable (the
    # default — auto sharding propagation — is what we want anyway)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_auction_mesh(n_shards: Optional[int] = None):
    """A 1-axis auction mesh over ``n_shards`` devices (axis ``"bids"``).

    ``n_shards=None`` takes every local device.  The shard count is clamped
    to the largest power of two ≤ min(requested, available) so pow2-bucketed
    round shapes (kernels/jasda_score ``bucket_m``, core/wis row buckets)
    always divide evenly across shards — the zero-retrace contract needs
    one executable per bucket per MESH SHAPE, not per pool size.  With one
    device (or ``n_shards=1``) the mesh is valid but degenerate; every
    ``mesh=`` consumer falls back to the unsharded dispatch path.
    """
    avail = jax.local_device_count()
    n = avail if n_shards is None else min(int(n_shards), avail)
    n = _pow2_floor(max(n, 1))
    return jax.make_mesh((n,), (AUCTION_AXIS,))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
