"""Training launcher: run any registered arch (reduced or full scale) under
the JASDA executor — the paper's interaction cycle drives the real run.

CPU/dev:   python -m repro.launch.train --arch qwen3_14b --reduced --steps 50
Cluster:   same entrypoint; the mesh/rules come from launch.mesh and the
           sharded train step from training.trainer (see dryrun.py for the
           exact jit construction used at production scale).
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointStore
from ..configs import get, reduced
from ..core import JasdaScheduler, SliceSpec
from ..core.executor import JasdaExecutor, TrainingJob
from ..core.scheduler import SchedulerConfig
from ..core.windows import WindowPolicy
from ..data import DataConfig, SyntheticTokens
from ..models import Model
from ..training import adamw, adafactor, make_train_step, warmup_cosine

GB = 1 << 30


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-jasda", action="store_true",
                    help="plain loop without the scheduler executor")
    args = ap.parse_args(argv)

    cfg = reduced(args.arch) if args.reduced else get(args.arch)[0]
    _, info = get(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params ({'reduced' if args.reduced else 'FULL'})")

    lr = warmup_cosine(3e-4, min(50, args.steps // 4 + 1), args.steps)
    opt = adamw(lr) if info.optimizer == "adamw" else adafactor(lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        memory_seq=cfg.encoder_seq or cfg.vision_seq,
        d_model=cfg.d_model if cfg.family in ("encdec", "vlm") else 0))
    store = CheckpointStore(args.ckpt_dir or tempfile.mkdtemp(prefix="ckpt_"))
    state = {"params": params, "opt": opt_state}
    losses = []

    def run_steps(s0, n):
        loss = None
        for i in range(s0, s0 + n):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state["params"], state["opt"], m = step_fn(
                state["params"], state["opt"], batch, jnp.int32(i))
            loss = float(m["loss"])
            losses.append(loss)
        return {"loss": loss}

    if args.no_jasda:
        run_steps(0, args.steps)
    else:
        sched = JasdaScheduler(
            [SliceSpec("lane0", 8 * GB, n_chips=1)],
            SchedulerConfig(window=WindowPolicy(horizon=3600.0, min_gap=0.3)))
        ex = JasdaExecutor(sched)
        job = TrainingJob(
            job_id=cfg.name, total_steps=args.steps, step_fn=run_steps,
            checkpoint_fn=lambda s: store.save(
                s, {"params": state["params"], "opt": state["opt"]}),
            param_bytes=n_params * 4.0, optimizer_bytes=n_params * 8.0,
            activation_bytes=args.batch * args.seq * cfg.d_model * 16.0,
            steps_per_sec=2.0)
        ex.register(job)
        ex.run(max_wall=86400.0)
        store.wait()
    print(f"done: loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"({len(losses)} steps, checkpoints at {store.steps()})")


if __name__ == "__main__":
    main()
