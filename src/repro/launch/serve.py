"""Serving launcher: continuous-batching engine for any registered arch.

CPU/dev: python -m repro.launch.serve --arch olmoe_1b_7b --reduced \
             --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from ..configs import get, reduced
from ..models import Model
from ..serving import Request, ServeConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for params init and synthetic prompts")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable result line")
    args = ap.parse_args(argv)

    cfg = reduced(args.arch) if args.reduced else get(args.arch)[0]
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("serve.py demo drives decoder-only archs; "
                         "enc-dec/vlm serving needs a memory input per "
                         "request (see serving.engine prefill hooks)")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(model, params, ServeConfig(
        batch_slots=args.slots, max_seq=args.max_seq))
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(Request(f"r{i:03d}", prompt, max_new_tokens=args.max_new))
        eng.submit(reqs[-1])
    t0 = time.perf_counter()
    eng.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    stuck = [r.rid for r in reqs if not r.done]
    if args.json:
        print(json.dumps({
            "arch": cfg.name, "seed": args.seed, "requests": len(reqs),
            "tokens": toks, "wall_s": round(wall, 4),
            "tok_per_s": round(toks / wall, 2) if wall > 0 else None,
            "unfinished": stuck,
        }))
    else:
        print(f"{cfg.name}: {len(reqs)} requests, {toks} tokens in {wall:.2f}s "
              f"({toks/wall:.1f} tok/s)")
    if stuck:
        print(f"error: {len(stuck)} request(s) never finished: "
              f"{', '.join(stuck)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
