"""Analytic FLOP/byte accounting per (arch × shape) cell.

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts each while-loop
body ONCE (verified by a controlled scan-vs-unroll experiment, see
EXPERIMENTS.md §Dry-run), so any scan-over-layers program under-reports by
~L×micro.  The roofline therefore uses these implementation-accurate
analytic counts (every einsum in the model code is enumerated below);
the raw cost_analysis numbers are recorded alongside as a cross-check
(they match the analytic per-body numbers after dividing by trip counts).

Conventions:
  * forward matmul FLOPs = 2·M·N·K; training = ×3 for fwd+bwd on
    embed/head (outside remat), ×4 for layer interiors (fwd + bwd(2) +
    remat recompute(1), since remat policy saves nothing).
  * attention scores/PV FLOPs follow the IMPLEMENTATION: the full/chunked
    XLA paths compute all S×T logits (no causal skip); the 'triangle' path
    halves them.  This is exactly the kind of waste MODEL/HLO exposes.
  * HBM bytes are order-accurate estimates: parameter traffic (per pass,
    per microbatch), optimizer state traffic, activation stream traffic,
    KV-cache traffic.  Dominant-term identification is robust to the ~2×
    modelling error; noted in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

__all__ = ["analytic_cost", "CellCost"]


@dataclass(frozen=True)
class CellCost:
    flops_global: float
    # parameter-side traffic (params/opt/grads): replicated under pure-DP,
    # else sharded /chips; stream traffic (activations/caches) always /chips
    param_traffic: float
    stream_traffic: float
    detail: dict

    def bytes_per_device(self, chips: int, *, params_replicated: bool) -> float:
        p = self.param_traffic if params_replicated else self.param_traffic / chips
        return p + self.stream_traffic / chips


def _attn_flops_per_tok(cfg, t_ctx: float, causal_save: bool = False) -> float:
    H, hd = cfg.n_heads, cfg.hd
    f = 4.0 * t_ctx * H * hd  # QK^T + PV
    return f * (0.5 if causal_save else 1.0)


def _proj_flops_per_tok(cfg) -> float:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return 2.0 * D * hd * (H + 2 * Hkv) + 2.0 * H * hd * D  # qkv + o


def _mlp_flops_per_tok(cfg) -> float:
    mats = 3 if cfg.gated_mlp else 2
    return 2.0 * mats * cfg.d_model * cfg.d_ff


def _moe_flops_per_tok(cfg, group_size: int = 512) -> float:
    D, E, Fe, k = cfg.d_model, cfg.n_experts, cfg.d_expert, cfg.top_k
    g = group_size
    cap = int(g * k / E * cfg.capacity_factor) + 1
    router = 2.0 * D * E
    dispatch = 2.0 * 2.0 * E * cap * D  # in + out one-hot einsums (per token)
    mats = 3 if cfg.gated_mlp else 2
    experts = 2.0 * mats * (E * cap / g) * D * Fe  # ≈ k·cf dense-expert cost
    return router + dispatch + experts


def _mamba_flops_per_tok(cfg) -> float:
    D, Dm, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    R, K = cfg.dt_rank_actual, cfg.ssm_conv
    return (2 * D * 2 * Dm + 2 * K * Dm + 2 * Dm * (R + 2 * N)
            + 2 * R * Dm + 12.0 * Dm * N + 2 * Dm * D)


def _rglru_flops_per_tok(cfg) -> float:
    D, Dr, K = cfg.d_model, cfg.lru_dim, cfg.ssm_conv
    bs = 256 if Dr >= 256 else Dr
    return (2 * D * 2 * Dr + 2 * K * Dr + 2 * 2 * Dr * bs + 10.0 * Dr
            + 2 * Dr * D)


def _layer_flops_per_tok(cfg, kind: str, t_ctx: float, *, causal_save=False,
                         t_mem: float = 0.0) -> float:
    if kind == "attn":
        return (_proj_flops_per_tok(cfg)
                + _attn_flops_per_tok(cfg, t_ctx, causal_save)
                + _mlp_flops_per_tok(cfg))
    if kind == "moe":
        return (_proj_flops_per_tok(cfg)
                + _attn_flops_per_tok(cfg, t_ctx, causal_save)
                + _moe_flops_per_tok(cfg))
    if kind == "mamba":
        return _mamba_flops_per_tok(cfg)
    if kind == "rglru":
        return _rglru_flops_per_tok(cfg) + _mlp_flops_per_tok(cfg)
    if kind == "cross":
        D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
        q_and_o = 2.0 * D * H * hd + 2.0 * H * hd * D
        return (q_and_o + _attn_flops_per_tok(cfg, t_mem)
                + _mlp_flops_per_tok(cfg))
    raise ValueError(kind)


def _layer_kinds(cfg):
    """(kind, count) across the full depth, incl. tail layers."""
    sb = cfg.superblock
    counts = {}
    for k in sb:
        counts[k] = counts.get(k, 0) + cfg.n_super
    for k in sb[: cfg.n_tail]:
        counts[k] = counts.get(k, 0) + 1
    return counts


def _fwd_flops_per_tok(cfg, t_ctx: float, *, causal_save=False) -> float:
    total = 0.0
    t_mem = cfg.encoder_seq if cfg.family == "encdec" else cfg.vision_seq
    for kind, n in _layer_kinds(cfg).items():
        # hybrid local attention: context bounded by the window
        t_eff = min(t_ctx, cfg.window) if (cfg.family == "hybrid" and kind == "attn") else t_ctx
        total += n * _layer_flops_per_tok(cfg, kind, t_eff,
                                          causal_save=causal_save, t_mem=t_mem)
    if cfg.family == "encdec":
        # decoder cross-attn stack (one per decoder layer)
        D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
        total += cfg.n_layers * (2.0 * D * H * hd + 2.0 * H * hd * D
                                 + _attn_flops_per_tok(cfg, t_mem))
    total += 2.0 * cfg.d_model * cfg.padded_vocab  # unembed
    return total


def _encoder_flops(cfg, batch: int) -> float:
    if cfg.family != "encdec":
        return 0.0
    per_tok = (_proj_flops_per_tok(cfg)
               + _attn_flops_per_tok(cfg, cfg.encoder_seq)
               + _mlp_flops_per_tok(cfg))
    return batch * cfg.encoder_seq * cfg.n_encoder_layers * per_tok


def _cross_kv_flops(cfg, batch: int) -> float:
    D, Hkv, hd = cfg.d_model, cfg.n_kv_heads, cfg.hd
    if cfg.family == "encdec":
        return batch * cfg.encoder_seq * cfg.n_layers * 2 * D * 2 * Hkv * hd
    if cfg.family == "vlm":
        return batch * cfg.vision_seq * cfg.n_super * 2 * D * 2 * Hkv * hd
    return 0.0


def analytic_cost(cfg, info, shape, *, attn_impl: str = "chunked") -> CellCost:
    """Global FLOPs + HBM bytes for one cell (both meshes are identical
    globally; per-device = global / chips)."""
    causal_save = attn_impl == "triangle"
    P = cfg.param_count()
    P_b = 2.0 * P  # bf16 residency
    tokens = shape.batch * shape.seq

    if shape.kind == "train":
        M = info.microbatches.get(shape.name, 1)
        fwd = tokens * _fwd_flops_per_tok(cfg, shape.seq, causal_save=causal_save)
        fwd += _encoder_flops(cfg, shape.batch) + _cross_kv_flops(cfg, shape.batch)
        flops = 4.0 * fwd  # fwd + remat-recompute + bwd(2×)
        # opt update flops negligible (O(P))
        act_stream = 6.0 * tokens * cfg.d_model * 2.0 * (
            cfg.n_layers + cfg.n_encoder_layers)
        opt_traffic = {"adamw": 4 * 4.0 * P,  # m,v read+write f32
                       "adafactor": 0.1 * P}[info.optimizer]
        grads = 2 * 4.0 * P  # f32 accumulate read+write (amortized)
        param_traffic = 3.0 * M * P_b + opt_traffic + grads
        detail = {"microbatches": M, "fwd_flops": fwd}
        return CellCost(flops_global=flops, param_traffic=param_traffic,
                        stream_traffic=act_stream, detail=detail)
    elif shape.kind == "prefill":
        fwd = tokens * _fwd_flops_per_tok(cfg, shape.seq, causal_save=causal_save)
        fwd += _encoder_flops(cfg, shape.batch) + _cross_kv_flops(cfg, shape.batch)
        flops = fwd
        kv_write = _cache_bytes(cfg, shape)
        act_stream = 4.0 * tokens * cfg.d_model * 2.0 * cfg.n_layers
        detail = {"kv_cache_bytes": kv_write}
        return CellCost(flops_global=flops, param_traffic=P_b,
                        stream_traffic=act_stream + kv_write, detail=detail)
    else:  # decode: one token per sequence
        tokens = shape.batch
        fwd = tokens * _fwd_flops_per_tok(cfg, shape.seq)
        fwd += _cross_kv_flops(cfg, 0)  # cross kv precomputed, an input
        flops = fwd
        cache = _cache_bytes(cfg, shape)
        detail = {"kv_cache_bytes": cache}
        # every decode step streams all (active) params + the whole cache
        return CellCost(flops_global=flops, param_traffic=P_b,
                        stream_traffic=cache, detail=detail)


def _cache_bytes(cfg, shape) -> float:
    """Bytes of the decode cache this cell reads/writes."""
    b = shape.batch
    t = shape.seq
    kinds = _layer_kinds(cfg)
    total = 0.0
    for kind, n in kinds.items():
        if kind in ("attn", "moe"):
            t_eff = min(t, cfg.window) if cfg.family == "hybrid" else t
            total += n * b * t_eff * cfg.n_kv_heads * cfg.hd * 2 * 2.0
        elif kind == "mamba":
            total += n * b * (cfg.d_inner * cfg.ssm_state * 4.0
                              + (cfg.ssm_conv - 1) * cfg.d_inner * 2.0)
        elif kind == "rglru":
            total += n * b * (cfg.lru_dim * 4.0
                              + (cfg.ssm_conv - 1) * cfg.lru_dim * 2.0)
        elif kind == "cross":
            t_mem = cfg.vision_seq or cfg.encoder_seq
            total += n * b * t_mem * cfg.n_kv_heads * cfg.hd * 2 * 2.0
    if cfg.family == "encdec":
        total += cfg.n_layers * b * cfg.encoder_seq * cfg.n_kv_heads * cfg.hd * 2 * 2.0
    return total
