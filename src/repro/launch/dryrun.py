import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); 512 placeholder host devices let jax.make_mesh build
the production meshes.  No arrays are ever allocated — all inputs are
sharded ShapeDtypeStructs.

Per cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves the cell fits)
  * compiled.cost_analysis()    — per-device FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO
and appends a JSON row to --out (incremental: reruns skip finished cells).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun.jsonl
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, SHAPES, get, input_specs
from ..distributed.sharding import ShardingRules, resolve_param_specs
from ..models.model import Model
from ..training.optimizer import adafactor, adamw
from ..training.schedule import warmup_cosine
from ..training.trainer import make_accum_steps, make_train_step
from .mesh import make_production_mesh, mesh_chips
from .costmodel import analytic_cost
from .roofline import Roofline, collective_bytes, model_flops

__all__ = ["run_cell", "build_rules", "main"]


def _fit_axes(batch: int, candidates, mesh) -> tuple:
    """Largest candidate axis tuple whose extent divides the batch."""
    for axes in candidates:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size and batch % size == 0:
            return axes
    return ()


def build_rules(cfg, info, shape, mesh, *, multi_pod: bool,
                overrides: Optional[dict] = None) -> ShardingRules:
    fsdp = ("pod", "data") if multi_pod else ("data",)
    full = fsdp + ("model",)
    if info.pure_dp and shape.kind in ("train", "prefill"):
        # tiny model: replicate params, batch over as much mesh as divides
        batch_axes = _fit_axes(shape.batch, [full, fsdp, ("data",)], mesh)
        kw = dict(mesh=mesh, fsdp_axes=(), model_axes=(),
                  batch_axes=batch_axes, attn_shard=cfg.attn_shard,
                  kv_heads_shardable=False, shard_kv_seq=False,
                  shard_moe_expert=False)
    else:
        batch_axes = _fit_axes(shape.batch, [fsdp, ("data",)], mesh)
        infer_repl = info.infer_replicate_fsdp and shape.kind != "train"
        kw = dict(
            mesh=mesh,
            fsdp_axes=() if infer_repl else fsdp,
            batch_axes=batch_axes,
            seq_axes=(("model",) if (info.seq_shard_train and
                                     shape.kind == "train") else ()),
            attn_shard=cfg.attn_shard,
            kv_heads_shardable=(cfg.n_kv_heads % cfg.model_axis_size == 0),
            shard_kv_seq=(info.decode_shard_kv_seq and shape.kind == "decode"),
            shard_moe_expert=(cfg.moe_shard == "expert"),
        )
    if overrides:
        kw.update(overrides)
    return ShardingRules(**kw)


def _attach(tree_sds, spec_tree, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        tree_sds, spec_tree)


def _opt_spec_tree(opt_name: str, param_specs_resolved, param_sds):
    """Optimizer-state PartitionSpecs mirroring the params."""
    from jax.sharding import PartitionSpec as PS
    if opt_name == "adamw":
        return {"m": param_specs_resolved, "v": param_specs_resolved}

    def fact(spec, sds):
        if len(sds.shape) >= 2:
            t = tuple(spec)
            t = t + (None,) * (len(sds.shape) - len(t))
            return {"vr": PS(*t[:-1]), "vc": PS(*(t[:-2] + t[-1:]))}
        return {"v": PS(*tuple(spec))}

    return {"stats": jax.tree.map(
        fact, param_specs_resolved, param_sds,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))}


def make_optimizer(name: str):
    lr = warmup_cosine(3e-4, 200, 10000)
    return adamw(lr) if name == "adamw" else adafactor(lr)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               rule_overrides: Optional[dict] = None,
               attn_impl: Optional[str] = None,
               microbatch_override: Optional[int] = None):
    cfg, info = get(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not info.long_context:
        return None  # recorded as an explicit skip by the caller
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = build_rules(cfg, info, shape, mesh, multi_pod=multi_pod,
                        overrides=rule_overrides)
    model = Model(cfg)
    pspecs = resolve_param_specs(model.specs(), rules)
    param_sds = _attach(
        jax.eval_shape(model.init, jax.random.PRNGKey(0)), pspecs, mesh)
    kv_dtype = (info.kv_cache_dtype if shape.kind == "decode" else None)
    specs = input_specs(cfg, shape, rules, kv_dtype=kv_dtype)
    impl = attn_impl or (
        info.train_attn_impl if (shape.kind == "train" and
                                 info.train_attn_impl != "auto")
        else ("chunked" if shape.seq > 8192 else "auto"))

    if shape.kind == "train":
        opt = make_optimizer(info.optimizer)
        mb = microbatch_override or info.microbatches.get(shape_name, 1)
        # each microbatch must still cover the batch-sharded mesh rows
        n_rows = 1
        for a in rules.batch_axes:
            n_rows *= mesh.shape[a]
        if n_rows:
            mb = max(1, min(mb, shape.batch // n_rows))
        opt_sds = _attach(
            jax.eval_shape(opt.init, param_sds),
            _opt_spec_tree(info.optimizer, pspecs, param_sds), mesh)
        accum_dtype = {"float32": jnp.float32,
                       "bfloat16": jnp.bfloat16}[info.grad_accum_dtype]
        if info.external_accum:
            # production pattern for the giants: per-micro grad jit with a
            # DONATED accumulator + a separate apply jit (see trainer.py)
            micro_step, apply_step = make_accum_steps(
                model, opt, rules=rules, attn_impl=impl, remat=True,
                accum_dtype=accum_dtype, microbatches=mb)
            grad_sds = _attach(
                jax.tree.map(lambda p_: jax.ShapeDtypeStruct(
                    p_.shape, accum_dtype), param_sds),
                pspecs, mesh)
            micro_specs = jax.tree.map(
                lambda sds: jax.ShapeDtypeStruct(
                    (shape.batch // mb,) + sds.shape[1:], sds.dtype,
                    sharding=sds.sharding),
                specs)
            lowered = jax.jit(micro_step, donate_argnums=(1,)).lower(
                param_sds, grad_sds, micro_specs)
        else:
            step_fn = make_train_step(model, opt, rules=rules, microbatches=mb,
                                      attn_impl=impl, remat=True,
                                      accum_dtype=accum_dtype)
            # donate params + opt state (outputs alias inputs, as a real
            # train loop would run it)
            lowered = jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                param_sds, opt_sds, specs, jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch["tokens"],
                                 memory=batch.get("memory"),
                                 rules=rules, impl=impl)
        lowered = jax.jit(prefill_fn).lower(param_sds, specs)
    else:  # decode
        def decode_fn(params, token, index, cache, cross_stack=None):
            return model.decode_step(params, token, index, cache,
                                     cross_stack=cross_stack,
                                     rules=rules, impl=impl)
        args = [param_sds, specs["token"], specs["index"], specs["cache"]]
        if "cross_stack" in specs:
            args.append(specs["cross_stack"])
        # donate the cache: the serving loop aliases it in place
        lowered = jax.jit(decode_fn, donate_argnums=(3,)).lower(*args)
    return lowered, cfg, info, shape, mesh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rule_overrides: Optional[dict] = None,
             attn_impl: Optional[str] = None,
             microbatch_override: Optional[int] = None,
             verbose: bool = True) -> Optional[dict]:
    t0 = time.time()
    out = lower_cell(arch, shape_name, multi_pod=multi_pod,
                     rule_overrides=rule_overrides, attn_impl=attn_impl,
                     microbatch_override=microbatch_override)
    if out is None:
        row = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "skipped": "full attention at 524k seq is quadratic "
                          "(DESIGN §Arch-applicability)"}
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {row['skipped']}")
        return row
    lowered, cfg, info, shape, mesh = out
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    chips = mesh_chips(mesh)
    coll = collective_bytes(hlo, chips)
    if shape.kind == "train" and info.external_accum:
        # the lowered artifact is ONE micro-step; a full step runs M of them
        rules_now = build_rules(cfg, info, shape, mesh,
                                multi_pod=multi_pod, overrides=rule_overrides)
        n_rows = 1
        for a in rules_now.batch_axes:
            n_rows *= mesh.shape[a]
        m_base = microbatch_override or info.microbatches.get(shape_name, 1)
        m_eff = max(1, min(m_base, shape.batch // max(n_rows, 1)))
        coll.bytes_on_link *= m_eff
        coll.by_kind = {k: v * m_eff for k, v in coll.by_kind.items()}
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    # analytic FLOPs/bytes (implementation-accurate; see costmodel.py —
    # cost_analysis undercounts while bodies, recorded raw as cross-check)
    ac = analytic_cost(cfg, info, shape,
                       attn_impl=(attn_impl or
                                  ("chunked" if shape.seq > 8192 else "full")))
    params_replicated = info.pure_dp and shape.kind in ("train", "prefill")
    rl = Roofline(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        flops_per_device=ac.flops_global / chips,
        bytes_per_device=ac.bytes_per_device(
            chips, params_replicated=params_replicated),
        collective=coll,
        model_flops_global=model_flops(cfg, shape),
        memory_stats=mem_stats,
    )
    row = rl.row()
    row.update({"t_lower_s": round(t_lower, 1),
                "t_compile_s": round(t_compile, 1),
                "raw_cost_analysis": {
                    "flops_per_device_body_once": float(cost.get("flops", 0.0)),
                    "bytes_accessed_body_once": float(cost.get("bytes accessed", 0.0)),
                },
                "cost_detail": ac.detail})
    if verbose:
        dev_bytes = (mem_stats["argument_bytes"] or 0) + (mem_stats["temp_bytes"] or 0)
        print(f"[ok] {arch} × {shape_name} × {row['mesh']}: "
              f"mem/dev={dev_bytes/2**30:.2f}GiB "
              f"flops/dev={row['flops_per_device']:.3e} "
              f"t_comp={row['t_compute_s']*1e3:.2f}ms "
              f"t_mem={row['t_memory_s']*1e3:.2f}ms "
              f"t_coll={row['t_collective_s']*1e3:.2f}ms "
              f"bottleneck={row['bottleneck']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"     memory_analysis: {mem}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "multi" if mp else "single")
                if key in done:
                    print(f"[cached] {key}")
                    continue
                try:
                    row = run_cell(arch, shape, multi_pod=mp)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((key, str(e)))
                    row = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "error": str(e)[:2000]}
                if row is not None:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row) + "\n")
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for k, e in failures:
            print(" ", k, e[:200])
        sys.exit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
