"""Deterministic, seeded fault injection + recovery for auction rounds.

The paper embeds "feedback, calibration, and probabilistic safety directly
into the scheduling loop"; real MIG fleets additionally reconfigure and
revoke partitions online (arXiv:2511.18906), and the SJA predecessor
(arXiv:2509.19086) assumes jobs re-atomize when the cluster changes under
them.  This module is that missing failure surface, built so every run is
REPLAYABLE: a :class:`FaultPlan` is a frozen, seeded schedule of
:class:`FaultEvent` rows the simulator injects between and during rounds,
and every recovery path (commitment revocation, bid-collection retries,
the kernel degradation ladder, checkpointed crash restore) is driven only
by the plan + the simulation clock — never by wall time or consumable
global state — so a crash-at-round-k + restore replays byte-identically.

Fault taxonomy (``FaultEvent.kind``):

==========================  ==============================================
``slice_revoked``           the slice dies; running chunk fails, all its
                            commitments are revoked and re-enter bidding
                            (``JasdaScheduler.revoke_slice``), affected
                            agents get a ``slice_failed`` loss broadcast
``slice_degraded``          the slice keeps running at ``magnitude`` ×
                            its former speed (straggler injection)
``agent_silent``            the agent answers NOTHING for ``duration``
                            time units (silent bidder; dropped per round,
                            never retried — silence has no error signal)
``agent_error``             the agent's ``respond()`` RPC errors for
                            ``duration`` time units; the scheduler retries
                            with capped exponential backoff and drops the
                            agent for the round when retries exhaust
``device_dispatch_fail``    the next kernel dispatch on backend ``target``
                            raises ``KernelDispatchError``; sticky
                            ``BackendHealth`` walks the degradation ladder
                            (pallas → ref → numpy) and speculation is
                            invalidated at the fault epoch
``scheduler_crash``         the scheduler process dies mid-run; the
                            simulator restores the latest checkpoint and
                            replays (requires a ``CheckpointStore``)
==========================  ==============================================

Agent faults are TIME-WINDOWED, not count-consumed: whether job J is
silent at time t depends only on (plan, t), so a speculative preparation
built for round t — possibly discarded and rebuilt by the pipeline —
observes the identical fault state every time.  ``attempts`` on
``agent_error`` events bounds how many CONSECUTIVE retry attempts fail
within one collection (deterministic per attempt index), letting tests
exercise the succeeds-after-backoff path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "SLICE_REVOKED",
    "SLICE_DEGRADED",
    "AGENT_SILENT",
    "AGENT_ERROR",
    "DEVICE_DISPATCH_FAIL",
    "SCHEDULER_CRASH",
    "AgentFault",
    "AgentSilentError",
    "AgentRespondError",
    "FaultEvent",
    "FaultPlan",
    "FaultInjector",
]

SLICE_REVOKED = "slice_revoked"
SLICE_DEGRADED = "slice_degraded"
AGENT_SILENT = "agent_silent"
AGENT_ERROR = "agent_error"
DEVICE_DISPATCH_FAIL = "device_dispatch_fail"
SCHEDULER_CRASH = "scheduler_crash"

FAULT_KINDS = (
    SLICE_REVOKED,
    SLICE_DEGRADED,
    AGENT_SILENT,
    AGENT_ERROR,
    DEVICE_DISPATCH_FAIL,
    SCHEDULER_CRASH,
)


class AgentFault(Exception):
    """Base for bid-collection faults; ``retryable`` drives the backoff."""

    retryable = False


class AgentSilentError(AgentFault):
    """The agent missed the bid-collection deadline (no error signal).

    Not retryable: a silent bidder is dropped for the round immediately —
    retrying silence would stall the round for nothing.
    """

    retryable = False


class AgentRespondError(AgentFault):
    """The agent's ``respond()`` RPC errored; retryable with backoff."""

    retryable = True


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One scheduled fault.  ``target`` is a slice_id, job_id, or backend
    name depending on ``kind``; ``duration`` scopes time-windowed faults
    (agent silent/error windows, slice repair delay); ``magnitude`` is the
    kind-specific intensity (speed factor for ``slice_degraded``);
    ``attempts`` is how many consecutive retry attempts an ``agent_error``
    fails within one bid collection (0 = every attempt in the window)."""

    t: float
    kind: str
    target: str = ""
    duration: float = 0.0
    magnitude: float = 1.0
    attempts: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}")


@dataclass(frozen=True)
class FaultPlan:
    """A frozen, seeded schedule of fault events (sorted by time).

    Frozen so a plan can be embedded in configs, hashed into benchmark
    labels, and shipped to a restored run unchanged — the plan IS the
    replay key together with ``SimConfig.seed``.
    """

    seed: int = 0
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events", tuple(sorted(self.events, key=lambda e: e.t)))

    def for_kind(self, kind: str) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind == kind)

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        t_end: float,
        slice_ids: Iterable[str] = (),
        job_ids: Iterable[str] = (),
        revoke_rate: float = 0.0,
        degrade_rate: float = 0.0,
        silent_rate: float = 0.0,
        error_rate: float = 0.0,
        dispatch_fail_times: Iterable[float] = (),
        crash_times: Iterable[float] = (),
        repair_time: float = 50.0,
        fault_duration: float = 20.0,
        backend: str = "ref",
    ) -> "FaultPlan":
        """Seeded random plan: Poisson faults per target plus explicit
        dispatch-failure / crash times.  Deterministic per (seed, args)."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []

        def poisson(rate: float, gap: float):
            if rate <= 0.0:
                return
            t = float(rng.exponential(1.0 / rate))
            while t < t_end:
                yield t
                t += gap + float(rng.exponential(1.0 / rate))

        for sid in slice_ids:
            for t in poisson(revoke_rate, repair_time):
                events.append(FaultEvent(t, SLICE_REVOKED, sid,
                                         duration=repair_time))
            for t in poisson(degrade_rate, fault_duration):
                events.append(FaultEvent(
                    t, SLICE_DEGRADED, sid, duration=fault_duration,
                    magnitude=float(rng.uniform(0.3, 0.8))))
        for jid in job_ids:
            for t in poisson(silent_rate, fault_duration):
                events.append(FaultEvent(t, AGENT_SILENT, jid,
                                         duration=fault_duration))
            for t in poisson(error_rate, fault_duration):
                events.append(FaultEvent(
                    t, AGENT_ERROR, jid, duration=fault_duration,
                    attempts=int(rng.integers(1, 4))))
        for t in dispatch_fail_times:
            events.append(FaultEvent(float(t), DEVICE_DISPATCH_FAIL, backend))
        for t in crash_times:
            events.append(FaultEvent(float(t), SCHEDULER_CRASH))
        return cls(seed=seed, events=tuple(events))


class FaultInjector:
    """Runtime view of a :class:`FaultPlan`: the agent-fault gate.

    Holds NO consumable state for agent faults — the gate answers "is job
    J silent / erroring at time t, attempt k" purely from the plan's time
    windows, which is what keeps speculative (pipelined) bid collections
    byte-identical to serial ones.  Slice / device / crash events are
    delivered by the simulator's event heap instead (they mutate scheduler
    state and must happen exactly once per timeline position).

    Picklable (plain tuples/dicts only), so it rides the crash-recovery
    checkpoint unchanged.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._silent: Dict[str, List[Tuple[float, float]]] = {}
        self._error: Dict[str, List[Tuple[float, float, int]]] = {}
        for e in plan.events:
            if e.kind == AGENT_SILENT:
                self._silent.setdefault(e.target, []).append(
                    (e.t, e.t + e.duration))
            elif e.kind == AGENT_ERROR:
                self._error.setdefault(e.target, []).append(
                    (e.t, e.t + e.duration, int(e.attempts)))

    # -- the bid-collection gate (scheduler.fault_gate) -------------------
    def __call__(self, agent, now: float, attempt: int) -> None:
        """Raise the fault active for ``agent`` at ``now``, if any.

        Called by the scheduler BEFORE each ``respond()`` attempt; the
        attempt index makes "fails first k attempts" deterministic."""
        job_id = agent.spec.job_id
        for t0, t1 in self._silent.get(job_id, ()):
            if t0 <= now < t1:
                raise AgentSilentError(
                    f"{job_id} silent at t={now:g} (window [{t0:g},{t1:g}))")
        for t0, t1, attempts in self._error.get(job_id, ()):
            if t0 <= now < t1 and (attempts == 0 or attempt < attempts):
                raise AgentRespondError(
                    f"{job_id} respond() error at t={now:g} "
                    f"attempt {attempt} (window [{t0:g},{t1:g}))")

    # -- the event stream the simulator schedules -------------------------
    def scheduled_events(self) -> Tuple[FaultEvent, ...]:
        """Events the simulator must deliver through its heap (slice /
        device / crash); agent windows are handled by the gate alone."""
        return tuple(e for e in self.plan.events
                     if e.kind in (SLICE_REVOKED, SLICE_DEGRADED,
                                   DEVICE_DISPATCH_FAIL, SCHEDULER_CRASH))
