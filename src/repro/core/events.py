"""Shared discrete-event machinery: the heap discipline + execution plumbing.

Factored out of ``core/simulator.py`` so the closed-loop simulator and the
open-loop streaming service (``repro.service``) drive the SAME event loop:

* :class:`EventHeap` — a seeded, picklable min-heap of ``(t, kind, seq,
  payload)`` tuples with a monotone sequence number breaking timestamp
  ties deterministically.  Checkpointing the heap object inside the same
  pickle graph as the scheduler preserves payload identities (the
  ``Variant`` objects shared with the commit index), which is what makes
  crash-restore replays byte-identical.
* :class:`ExecutionPlumbing` — the synthetic executor: launches committed
  variants with stochastic ground-truth runtimes (log-normal noise around
  activation + work/(throughput × speed)), samples true memory
  trajectories for capacity-violation accounting, and assembles the
  ex-post observation fed back through ``scheduler.complete``.

Event-kind ordering at equal timestamps is part of the replay contract:
completions fire before the scheduler tick sharing their timestamp,
planned fault events fire after it, and the service-side cancel/deadline
events fire after the round that could still have used them.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import Variant

__all__ = [
    "EventHeap",
    "ExecutionPlumbing",
    "COMPLETE",
    "FAIL",
    "REPAIR",
    "ARRIVE",
    "TICK",
    "FAULT",
    "CANCEL",
    "DEADLINE",
    "REPARTITION",
]

# Ordering at equal timestamps: completions before scheduler ticks (the
# round at t observes everything that finished by t); arrivals before the
# tick (a job arriving at t bids in the round at t); planned faults and the
# open-loop cancel/deadline events strictly after the tick sharing their
# timestamp.  REPARTITION is last: a repartition opportunity at t runs
# strictly BETWEEN the round at t and the round at t+dt (the drain-first
# protocol in core/repartition.py assumes settled state).
COMPLETE, FAIL, REPAIR, ARRIVE, TICK, FAULT, CANCEL, DEADLINE, \
    REPARTITION = range(9)


class EventHeap:
    """Min-heap of ``(t, kind, seq, payload)`` with deterministic tie-break.

    ``seq`` is a monotone push counter: two events with equal ``(t, kind)``
    pop in push order, so replays are byte-identical per seed.  Picklable;
    the heap invariant is re-established on restore (defensive — the list
    is serialized in heap order anyway).
    """

    __slots__ = ("_heap", "_seq")

    def __init__(self):
        self._heap: List[Tuple[float, int, int, object]] = []
        self._seq = 0

    def push(self, t: float, kind: int, payload: object = None) -> None:
        heapq.heappush(self._heap, (t, kind, self._seq, payload))
        self._seq += 1

    def pop(self) -> Tuple[float, int, int, object]:
        return heapq.heappop(self._heap)

    def peek(self) -> Tuple[float, int, int, object]:
        return self._heap[0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __getstate__(self):
        return {"heap": list(self._heap), "seq": self._seq}

    def __setstate__(self, state):
        self._heap = list(state["heap"])
        heapq.heapify(self._heap)
        self._seq = state["seq"]


class ExecutionPlumbing:
    """Launch/complete plumbing shared by simulator and service.

    Owns the executor-side mutable state — ``running`` (slice → (variant,
    actual end)), ``pending`` (committed variants waiting for their start
    time) and the capacity-violation counter — and pushes COMPLETE events
    onto the shared :class:`EventHeap`.  The object is checkpointed as one
    node of the same pickle graph as the scheduler, so the Variant
    identities its ``running``/``pending`` sets share with the scheduler's
    commit index survive a crash-restore.
    """

    def __init__(
        self,
        scheduler,
        heap: EventHeap,
        rng: np.random.Generator,
        *,
        runtime_cv: float = 0.1,
        check_capacity: bool = True,
    ):
        self.scheduler = scheduler
        self.heap = heap
        self.rng = rng
        self.runtime_cv = runtime_cv
        self.check_capacity = check_capacity
        self.running: Dict[str, Tuple[Variant, float]] = {}
        self.pending: List[Variant] = []
        self.violations = 0

    # -- launch ------------------------------------------------------------
    def launch(self, v: Variant, t_now: float) -> None:
        """Start executing a committed variant whose t_start has arrived.

        Ground-truth runtime = activation + work / (throughput × speed) with
        log-normal noise — NOT the declared Δt̃ (which is a conservative
        quantile).  Early finishes release the committed tail back to the
        timeline (scheduler.complete), so honest-but-safe declarations cost
        little; overruns lose the tail work beyond the committed end.
        """
        scheduler = self.scheduler
        spec = scheduler.slices[v.slice_id].spec
        agent = scheduler.agents.get(v.job_id)
        thr = agent.throughput_on(spec.capacity_bytes, spec.n_chips) if agent else 1.0
        thr = max(thr * spec.speed, 1e-9)
        activation = float(v.payload.get("activation", 0.0))
        median = activation + v.payload["work"] / thr
        sigma = np.sqrt(np.log1p(self.runtime_cv**2))
        actual = float(median * np.exp(self.rng.normal(-0.5 * sigma**2, sigma)))
        # truncate to the committed interval: non-preemptive, but the slice is
        # reclaimed at the committed end regardless (overrun → lost tail work)
        actual_end = v.t_start + actual
        if self.check_capacity:
            traj = v.fmp.sample_trajectory(self.rng)
            if np.any(traj > scheduler.slices[v.slice_id].spec.capacity_bytes):
                self.violations += 1
        self.running[v.slice_id] = (v, actual_end)
        self.heap.push(max(actual_end, t_now), COMPLETE, v.slice_id)

    def launch_due(self, now: float, lookahead: float, dead_slices) -> None:
        """Launch pending variants whose start falls within ``lookahead``.

        Variants bound to a dead slice are silently dropped (lost with the
        slice); a variant whose slice is still busy stays pending.
        """
        still: List[Variant] = []
        for v in self.pending:
            if v.slice_id in dead_slices:
                continue  # lost with the slice
            if v.t_start <= now + lookahead and v.slice_id not in self.running:
                self.launch(v, now)
            else:
                still.append(v)
        self.pending = still

    # -- completion --------------------------------------------------------
    def complete(self, slice_id: str, now: float) -> Optional[Tuple[Variant, float]]:
        """Finish the variant running on ``slice_id``; returns (variant,
        actual duration) or None when the slice was already vacated (failed
        or revoked before its completion event popped).

        Observed feature values for ex-post verification come from the
        job's TRUE profile adjusted by realized runtime — independent of
        what was declared, so misreporting is measurable (Eq. 6).
        """
        if slice_id not in self.running:
            return None
        v, actual_end = self.running.pop(slice_id)
        dur_actual = actual_end - v.t_start
        truth = dict(v.payload.get("true_features", v.declared_features))
        observed = dict(truth)
        ratio = float(np.clip(v.duration / max(dur_actual, 1e-9), 0.0, 1.0))
        for k in ("jct", "progress"):
            if k in observed:
                observed[k] = float(np.clip(observed[k] * ratio, 0.0, 1.0))
        overrun = actual_end > v.t_end + 1e-9
        work = v.payload["work"] * (
            min(1.0, (v.t_end - v.t_start) / max(dur_actual, 1e-9)) if overrun else 1.0
        )
        self.scheduler.complete(
            v,
            observed,
            work_done=work,
            actual_end=min(actual_end, v.t_end),
        )
        return v, dur_actual

    # -- failure / cancellation -------------------------------------------
    def fail_running(self, slice_id: str, now: float) -> Optional[Variant]:
        """The slice died mid-execution: release its running variant."""
        if slice_id not in self.running:
            return None
        v, _ = self.running.pop(slice_id)
        self.scheduler.fail(v, now)
        return v

    def drop_pending(self, slice_id: str) -> List[Variant]:
        """Forget pending variants bound to a (now dead) slice."""
        dropped = [p for p in self.pending if p.slice_id == slice_id]
        self.pending = [p for p in self.pending if p.slice_id != slice_id]
        return dropped

    def drop_pending_job(self, job_id: str) -> List[Variant]:
        """Forget pending (not yet launched) variants of a job.

        The caller owns the scheduler-side cancellation (``scheduler.fail``
        releases the reservations); running variants are NOT touched —
        execution is non-preemptive.
        """
        dropped = [p for p in self.pending if p.job_id == job_id]
        self.pending = [p for p in self.pending if p.job_id != job_id]
        return dropped
