"""Incentives, calibration and ex-post verification (paper §4.2.1).

Ex-ante calibration (Eq. 5):      ĥ(v) ← γ h̃(v) + (1−γ) HistAvg(J)
Per-feature error (Eq. 6):        ε_i(v) = |φ_i(v) − φ_i^observed(v)|
Per-variant error:                ε(v) = Σ w_i ε_i(v),  w ≥ 0, Σw = 1
Expected error (Eq. 7):           E_v[ε] = mean over verified variants
Reliability (Eq. 8):              ρ_J = exp(−κ · E_v[ε])  ∈ (0, 1]
Feedback form:                    ĥ(v) ← ρ_J h̃(v) + (1−ρ_J) HistAvg(J)

The paper leaves the HistAvg family open ("simple or weighted"); we use an
EWMA with configurable half-life and ablate the choice in benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from .types import Variant

__all__ = ["CalibrationConfig", "Calibrator", "per_variant_error", "reliability"]


@dataclass(frozen=True)
class CalibrationConfig:
    gamma: float = 0.7  # γ in Eq. 5 (ignored when mode="reliability")
    kappa: float = 3.0  # κ in Eq. 8
    # EWMA half-life (in number of verified variants) for HistAvg.
    hist_half_life: float = 8.0
    # feature weights w_i for ε(v); uniform over observed features if None.
    error_weights: Optional[Mapping[str, float]] = None
    # "fixed"      : ĥ = γ h̃ + (1−γ) HistAvg          (Eq. 5)
    # "reliability": ĥ = ρ_J h̃ + (1−ρ_J) HistAvg      (feedback form)
    # "multiplicative": ĥ = ρ_J · (γ h̃ + (1−γ) HistAvg)
    mode: str = "reliability"
    # verified-error history window for E_v[ε] (None = full history, Eq. 7)
    error_window: Optional[int] = 64

    def __post_init__(self):
        if not (0.0 <= self.gamma <= 1.0):
            raise ValueError("gamma must be in [0,1]")
        if self.kappa <= 0:
            raise ValueError("kappa must be positive")
        if self.mode not in ("fixed", "reliability", "multiplicative"):
            raise ValueError(f"unknown mode {self.mode}")


def per_variant_error(
    declared: Mapping[str, float],
    observed: Mapping[str, float],
    weights: Optional[Mapping[str, float]] = None,
) -> float:
    """ε(v) = Σ_i w_i |φ_i − φ_i^obs| over features present in both maps.

    Convex by construction (weights normalized to sum 1), hence ε(v) ∈ [0,1]
    when features are in [0,1].
    """
    common = [k for k in declared.keys() if k in observed]
    if not common:
        return 0.0
    if weights is None:
        w = {k: 1.0 / len(common) for k in common}
    else:
        tot = sum(max(0.0, weights.get(k, 0.0)) for k in common)
        if tot <= 0:
            w = {k: 1.0 / len(common) for k in common}
        else:
            w = {k: max(0.0, weights.get(k, 0.0)) / tot for k in common}
    eps = 0.0
    for k in common:
        eps += w[k] * abs(float(declared[k]) - float(observed[k]))
    return float(min(1.0, max(0.0, eps)))


def reliability(expected_error: float, kappa: float) -> float:
    """Eq. 8: ρ_J = exp(−κ E[ε]) ∈ (0, 1]."""
    return float(math.exp(-kappa * max(0.0, expected_error)))


@dataclass
class _JobCal:
    hist_avg: float = 0.5
    n_verified: int = 0
    errors: list = field(default_factory=list)
    rho: float = 1.0
    # signed declaration bias: EWMA of mean(declared − observed) over the
    # common features.  Positive = systematic over-declaration.  This is
    # the gradient signal auction-style bid shading steers to zero
    # (negotiation.AdaptiveBidder); |bias| ≤ ε̄ always (triangle inequality).
    bias: float = 0.0

    def mean_error(self, window: Optional[int] = None) -> float:
        errs = self.errors if window is None else self.errors[-window:]
        return float(np.mean(errs)) if errs else 0.0


class Calibrator:
    """Per-job trust state + the two calibration passes of §4.2.1."""

    def __init__(self, config: CalibrationConfig = CalibrationConfig()):
        self.config = config
        self._jobs: Dict[str, _JobCal] = {}

    # -- access ------------------------------------------------------------
    def state(self, job_id: str) -> _JobCal:
        return self._jobs.setdefault(job_id, _JobCal())

    def rho(self, job_id: str) -> float:
        return self.state(job_id).rho

    def hist_avg(self, job_id: str) -> float:
        return self.state(job_id).hist_avg

    # -- ex-ante calibration (Eq. 5 / feedback form) -------------------------
    def calibrate(self, variant: Variant, h_declared: float) -> float:
        st = self.state(variant.job_id)
        cfg = self.config
        h = float(np.clip(h_declared, 0.0, 1.0))
        if cfg.mode == "fixed":
            return cfg.gamma * h + (1 - cfg.gamma) * st.hist_avg
        if cfg.mode == "reliability":
            return st.rho * h + (1 - st.rho) * st.hist_avg
        # multiplicative
        return st.rho * (cfg.gamma * h + (1 - cfg.gamma) * st.hist_avg)

    # -- ex-post verification (Eqs. 6–8) -------------------------------------
    def verify(
        self,
        variant: Variant,
        observed_features: Mapping[str, float],
        observed_utility: Optional[float] = None,
    ) -> float:
        """Ingest ground-truth measurements for an executed variant.

        Returns the per-variant error ε(v).  Updates HistAvg (EWMA over
        *verified* scores, per the paper: "moving average of previously
        verified scores") and ρ_J.
        """
        st = self.state(variant.job_id)
        cfg = self.config
        eps = per_variant_error(
            variant.declared_features, observed_features, cfg.error_weights
        )
        st.errors.append(eps)
        st.n_verified += 1

        # Signed declaration bias (EWMA, same half-life as HistAvg): the
        # direction of the error, so strategies can shade declarations
        # toward observations instead of merely knowing they are off.
        common = [k for k in variant.declared_features if k in observed_features]
        if common:
            signed = float(
                np.mean([
                    float(variant.declared_features[k]) - float(observed_features[k])
                    for k in common
                ])
            )
            decay_b = 0.5 ** (1.0 / max(cfg.hist_half_life, 1e-9))
            st.bias = decay_b * st.bias + (1 - decay_b) * signed

        # HistAvg update: EWMA of the *verified* (observed) utility.
        if observed_utility is None:
            # reconstruct from observed features with the declared weighting
            observed_utility = float(
                np.clip(np.mean(list(observed_features.values()) or [0.5]), 0, 1)
            )
        decay = 0.5 ** (1.0 / max(cfg.hist_half_life, 1e-9))
        st.hist_avg = decay * st.hist_avg + (1 - decay) * float(
            np.clip(observed_utility, 0.0, 1.0)
        )

        # E_v[ε] over the (windowed) verified history → ρ_J.
        errs = st.errors if cfg.error_window is None else st.errors[-cfg.error_window:]
        expected = float(np.mean(errs)) if errs else 0.0
        st.rho = reliability(expected, cfg.kappa)
        return eps

    # -- reporting / checkpointing -------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Full per-job calibration state, JSON-serializable.

        Round-trippable through :meth:`restore`: the ``errors`` history is
        included verbatim (it feeds the windowed E_v[ε] → ρ update), so a
        restored calibrator continues exactly where the snapshot was taken
        — simulator checkpoints preserve trust state across runs.
        """
        return {
            j: {
                "rho": float(st.rho),
                "hist_avg": float(st.hist_avg),
                "n_verified": int(st.n_verified),
                "mean_error": st.mean_error(),
                "bias": float(st.bias),
                # plain floats, IN VERIFICATION ORDER: the windowed
                # E_v[ε] → ρ update reads the tail, so order is state
                "errors": [float(e) for e in st.errors],
            }
            for j, st in self._jobs.items()
        }

    def restore(self, snapshot: Mapping[str, Mapping[str, float]]) -> "Calibrator":
        """Rebuild per-job state from a :meth:`snapshot` (returns self).

        Tolerates snapshots taken before the ``bias``/``errors`` fields
        existed (missing keys restore to their neutral defaults; ρ then
        evolves from the restored value as new verifications arrive).
        The error history restores in its original verification order even
        for jobs that never re-bid after the restore — a re-snapshot must
        be exactly the snapshot that was restored (pinned by tests).
        """
        self._jobs = {
            j: _JobCal(
                hist_avg=float(row["hist_avg"]),
                n_verified=int(row.get("n_verified", 0)),
                errors=[float(e) for e in row.get("errors", ())],
                rho=float(row["rho"]),
                bias=float(row.get("bias", 0.0)),
            )
            for j, row in snapshot.items()
        }
        return self
