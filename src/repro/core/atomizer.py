"""Job atomization (SJA substrate): splitting jobs into schedulable subjobs.

A subjob is a non-preemptive chunk of the parent job's remaining work that
fits an announced window.  The atomizer enforces the global minimum duration
τ_min (paper §4.1: anti-thrashing) and accounts for the fixed activation cost
of a chunk — on our TPU adaptation this is checkpoint-restore + compilation
warmup time, the analogue of the paper's "scheduling and activation costs".

Chunk candidates for a window of span T (from the job's perspective):
  * the largest chunk that fits T (greedy fill),
  * the remaining-work chunk if it completes within T (finishing early is
    preferable to holding the slice),
  * geometrically smaller chunks down to τ_min (gives the clearing DP
    packing alternatives — this is precisely the "multiple variants per
    window" freedom the paper adds over SJA).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from .trp import predict_duration

__all__ = ["AtomizerConfig", "chunk_candidates", "ChunkPlan"]


@dataclass(frozen=True)
class AtomizerConfig:
    tau_min: float = 2.0  # τ_min: global minimum subjob duration
    activation_cost: float = 0.25  # checkpoint-restore + warmup per chunk
    max_variants_per_window: int = 4  # V_max (paper §4.6)
    geometric_ratio: float = 0.5  # shrink factor between variant sizes
    duration_quantile: float = 0.9  # declared Δt̃ quantile (temporal safety)
    duration_cv: float = 0.1  # runtime coefficient of variation


@dataclass(frozen=True)
class ChunkPlan:
    """One candidate chunk: ``work`` units predicted to take ``duration``."""

    work: float
    duration: float  # Δt̃ including activation cost


def chunk_candidates(
    work_remaining: float,
    throughput: float,
    window_span: float,
    cfg: AtomizerConfig,
) -> List[ChunkPlan]:
    """Enumerate feasible chunk sizes for a window of ``window_span``.

    Durations are declared at the configured quantile of the log-normal
    runtime model (trp.predict_duration) plus the activation cost, so a
    committed chunk overruns its interval only with probability ~(1-q).
    Returns [] if even a τ_min chunk cannot fit (the job stays silent).
    """
    if work_remaining <= 0 or throughput <= 0:
        return []
    span = window_span
    usable = span - cfg.activation_cost
    if usable < cfg.tau_min:
        return []

    def dur_of(work: float) -> float:
        return (
            predict_duration(
                work,
                throughput,
                cv=cfg.duration_cv,
                quantile=cfg.duration_quantile,
            )
            + cfg.activation_cost
        )

    # Invert: the largest work whose declared duration fits the span.
    # predict_duration is linear in work, so invert directly.
    unit = dur_of(1.0) - cfg.activation_cost  # declared seconds per work unit
    max_work_fit = max(0.0, (span - cfg.activation_cost) / unit)
    candidates: List[float] = []

    finish_work = min(work_remaining, max_work_fit)
    if finish_work <= 0:
        return []
    candidates.append(finish_work)

    # Geometric ladder of smaller alternatives (packing freedom for the DP).
    w = finish_work * cfg.geometric_ratio
    while len(candidates) < cfg.max_variants_per_window:
        d = dur_of(w)
        if d - cfg.activation_cost < cfg.tau_min:
            break
        candidates.append(w)
        w *= cfg.geometric_ratio

    plans = []
    for w in candidates:
        d = dur_of(w)
        if d - cfg.activation_cost + 1e-12 < cfg.tau_min:
            if w >= work_remaining - 1e-12:
                # FINISHING chunk: a residual smaller than τ_min must still be
                # schedulable or job tails starve.  Pad the declared duration
                # to τ_min — the slice is held for the minimum span, which
                # preserves the anti-thrashing invariant.
                d = cfg.activation_cost + cfg.tau_min
            else:
                continue
        if d <= span + 1e-9:
            plans.append(ChunkPlan(work=w, duration=d))
    return plans
