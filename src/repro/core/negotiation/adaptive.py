"""``AdaptiveBidder``: feedback-driven chunk sizing, targeting and shading.

The strategy closes the negotiation loop on the agent side.  Every settled
round delivers a :class:`~repro.core.negotiation.messages.RoundFeedback`;
from it the bidder runs three independent online adaptations (all state in
the per-agent dict from :meth:`init_state`, never on the frozen strategy):

* **Chunk-scale adaptation.**  Being OUTSCORED in a contended window means
  the agent's large largest-fit chunks are losing whole-interval auctions
  to denser rivals.  The bidder shrinks its chunk scale (``shrink`` per
  losing round, floored at ``min_scale``) and switches its per-window
  variant budget from head *alternatives* to chain *depth* — more,
  smaller chunks tiled through the window, each an independently scored
  WIS candidate.  Rounds where every bid wins grow the scale back toward
  1 (fewer activations per unit work).  At scale 1.0 the bids are exactly
  :class:`~repro.core.negotiation.greedy.GreedyChunking`'s, so an
  uncontended AdaptiveBidder never pays an adaptation tax.
* **Window targeting.**  Per-slice EWMA of the announced cutoffs
  (minimum winning score) vs. an EWMA of the agent's own winning scores:
  a slice whose cutoff has stayed above ``skip_margin ×`` the agent's own
  level for ``skip_after`` consecutive outscored rounds is skipped until
  its cutoff relaxes — bids go where they can clear (win-rate, not
  wasted generation work).
* **Bid shading (§4.2.1).**  The feedback carries the calibrator's signed
  declaration bias (declared − observed EWMA).  A positive bias means the
  agent is over-declaring (e.g. a strategic ``misreport`` factor), ε is
  accumulating and ρ_J is sinking — so the bidder shades its declared φs
  down (``shade ← shade·(1 − η·bias)``), steering the bias to zero,
  keeping ρ_J ≈ 1 and its *calibrated* score ĥ competitive.  Auction-style
  shading: report what the verifier will confirm, not what clips highest.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from ..types import Variant
from .base import BiddingStrategy, chunk_chain_bids
from .messages import LOSS_OUTSCORED, RoundFeedback, WindowAnnouncement

__all__ = ["AdaptiveBidder"]


@dataclass(frozen=True)
class AdaptiveBidder(BiddingStrategy):
    """Online bid optimization from clearing feedback (see module doc)."""

    name = "adaptive"

    #: chunk-scale multiplier applied on a round with outscored losses
    shrink: float = 0.6
    #: chunk-scale recovery multiplier on a fully-winning round
    grow: float = 1.2
    #: floor for the chunk scale (fraction of remaining work per chunk)
    min_scale: float = 0.12
    #: learning rate of the declaration-shading update
    shade_eta: float = 0.8
    #: floor for the shading factor
    min_shade: float = 0.25
    #: |bias| below which shading holds still (honest agents never shade)
    bias_deadband: float = 0.05
    #: EWMA retention for learned cutoffs / own-score levels
    level_decay: float = 0.5
    #: consecutive outscored rounds on a slice before targeting skips it
    skip_after: int = 3
    #: skip a slice while its cutoff EWMA exceeds margin × own level
    skip_margin: float = 1.3

    def init_state(self, agent) -> Dict:
        return {
            "scale": 1.0,
            "shade": 1.0,
            "cutoff": {},  # slice_id -> cutoff EWMA
            "own": 0.0,  # EWMA of own winning scores
            "streak": {},  # slice_id -> consecutive outscored rounds
        }

    # -- bidding ---------------------------------------------------------------
    def bid(self, agent, state, announcement: WindowAnnouncement) -> List[List[Variant]]:
        scale = state["scale"]
        out: List[List[Variant]] = []
        for w in announcement.windows:
            if self._skip(state, w.slice_id):
                out.append([])
                continue
            out.append(
                chunk_chain_bids(
                    agent, w, announcement.now,
                    announcement.chips_for(w.slice_id),
                    shade=state["shade"],
                    chunk_scale=scale,
                    # at scale 1.0 the bids are byte-identical to
                    # GreedyChunking; once shrunk, the variant budget buys
                    # chain depth instead of head alternatives
                    alternatives=scale >= 1.0,
                )
            )
        return out

    def _skip(self, state, slice_id: str) -> bool:
        if state["streak"].get(slice_id, 0) < self.skip_after:
            return False
        cutoff = state["cutoff"].get(slice_id)
        own = state["own"]
        return cutoff is not None and own > 0.0 and cutoff > self.skip_margin * own

    # -- adaptation ------------------------------------------------------------
    def observe(self, agent, state, feedback: RoundFeedback) -> bool:
        jid = agent.spec.job_id
        awards = feedback.awards.get(jid, ())
        losses = feedback.losses.get(jid, ())
        before = (state["scale"], state["shade"], dict(state["cutoff"]),
                  state["own"], dict(state["streak"]))

        d = self.level_decay
        for w in feedback.windows:
            cut = feedback.cutoff_for(w)
            if cut > 0.0:
                prev = state["cutoff"].get(w.slice_id)
                state["cutoff"][w.slice_id] = (
                    cut if prev is None else d * prev + (1 - d) * cut
                )
        for a in awards:
            state["own"] = (
                a.score if state["own"] == 0.0
                else d * state["own"] + (1 - d) * a.score
            )

        # per-slice streaks: a win resets, an outscored loss extends
        won_slices = {a.window.slice_id for a in awards}
        out_slices = {l.window.slice_id for l in losses
                      if l.reason == LOSS_OUTSCORED}
        for sid in won_slices:
            state["streak"][sid] = 0
        for sid in out_slices - won_slices:
            state["streak"][sid] = state["streak"].get(sid, 0) + 1

        # chunk-scale: shrink under contention (genuine market defeats),
        # recover when winning without being outscored anywhere
        if out_slices:
            state["scale"] = max(self.min_scale, state["scale"] * self.shrink)
        elif awards:
            state["scale"] = min(1.0, state["scale"] * self.grow)

        # declaration shading against the signed calibration bias
        bias = feedback.calibration_bias.get(jid, 0.0)
        if abs(bias) > self.bias_deadband:
            state["shade"] = float(
                np.clip(state["shade"] * (1.0 - self.shade_eta * bias),
                        self.min_shade, 1.0)
            )

        after = (state["scale"], state["shade"], state["cutoff"],
                 state["own"], state["streak"])
        return (before[0] != after[0] or before[1] != after[1]
                or before[2] != after[2] or before[3] != after[3]
                or before[4] != after[4])
