"""Bid-side negotiation: typed round protocol + pluggable bidding backends.

The mirror image of ``repro.core.policy`` (which made the *clearing*
objective a first-class backend): this package makes the *bid* side of the
scheduler↔agent interaction a first-class, swappable API and closes the
feedback loop the paper's "embedded directly into the scheduling loop"
claim requires.

Public surface:

* :class:`WindowAnnouncement` / :class:`BidBundle` / :class:`Award` /
  :class:`LossReport` / :class:`RoundFeedback` — the typed messages of one
  negotiation round (announce → bid → clear → feedback).
* :class:`BiddingStrategy` — the backend protocol (owns variant
  generation, chunk sizing, window targeting, self-scoring and feedback
  consumption); :func:`chunk_chain_bids` is the shared generation core.
* :class:`GreedyChunking` — the default; byte-identical to the historical
  ``JobAgent`` generation (pinned by a frozen-reference property test).
* :class:`AdaptiveBidder` — online chunk-scale / window-targeting /
  bid-shading adaptation from :class:`RoundFeedback`.
* :class:`ConservativeSafety` — reliability-scaled θ safety margin.
* :func:`build_feedback` — the scheduler-side feedback constructor.

Quickstart::

    from repro.core import AgentConfig, JobAgent
    from repro.core.negotiation import AdaptiveBidder

    agent = JobAgent(spec, AgentConfig(strategy=AdaptiveBidder()))
    # the scheduler announces, collects BidBundles, clears, and publishes
    # RoundFeedback back to every agent after each round automatically
"""
from .messages import (  # noqa: F401
    Award,
    BidBundle,
    LossReport,
    RoundFeedback,
    WindowAnnouncement,
    build_feedback,
)
from .base import BiddingStrategy, chunk_chain_bids  # noqa: F401
from .greedy import GreedyChunking  # noqa: F401
from .adaptive import AdaptiveBidder  # noqa: F401
from .conservative import ConservativeSafety  # noqa: F401

__all__ = [
    "WindowAnnouncement",
    "BidBundle",
    "Award",
    "LossReport",
    "RoundFeedback",
    "build_feedback",
    "BiddingStrategy",
    "chunk_chain_bids",
    "GreedyChunking",
    "AdaptiveBidder",
    "ConservativeSafety",
]
