"""``GreedyChunking``: the historical bid generator as a strategy backend.

Byte-identical to the pre-negotiation ``JobAgent.generate_variants_round``
/ ``generate_variants_by_window`` path: for every announced window, build
the greedy chunk chain (largest-fit chunk per position plus the geometric
ladder of smaller alternatives) with the agent's own θ and honest-times-
misreport declarations.  The identity is pinned by a property test against
a frozen reference copy in tests/test_negotiation.py — do not "improve"
this backend; new behavior belongs in a new strategy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from ..types import Variant
from .base import BiddingStrategy, chunk_chain_bids
from .messages import WindowAnnouncement

__all__ = ["GreedyChunking"]


@dataclass(frozen=True)
class GreedyChunking(BiddingStrategy):
    """Stateless largest-fit chunk chains on every announced window."""

    name = "greedy_chunking"

    def bid(self, agent, state, announcement: WindowAnnouncement) -> List[List[Variant]]:
        return [
            chunk_chain_bids(
                agent, w, announcement.now, announcement.chips_for(w.slice_id)
            )
            for w in announcement.windows
        ]
