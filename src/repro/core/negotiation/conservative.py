"""``ConservativeSafety``: reliability-scaled probabilistic safety.

Paper §4.1 condition (a) bounds the capacity-violation risk of every bid:
Pr(max RAM > c_k | FMP) ≤ θ.  The bound is only as good as the FMP it is
evaluated against — and §4.2.1's verification loop *measures* how good
that is: a job whose declarations keep diverging from observations ends up
with low reliability ρ_J.  This strategy turns that measurement into an
agent-side safety policy: the effective bound tightens with falling
reliability,

    θ_eff = max(theta_floor, θ · ρ_J^power)

so a job whose profile has proven untrustworthy stops bidding marginal
windows (where p_exceed sits between θ_eff and θ) until its reliability
recovers, and every emitted variant carries θ_eff in ``Variant.theta`` —
the in-dispatch per-agent recheck (``Policy.per_agent_theta``) then
enforces the tightened bound end-to-end.  Chunking is the same greedy
chain as :class:`~repro.core.negotiation.greedy.GreedyChunking`; at
ρ = 1 the two are byte-identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from ..types import Variant
from .base import BiddingStrategy, chunk_chain_bids
from .messages import RoundFeedback, WindowAnnouncement

__all__ = ["ConservativeSafety"]


@dataclass(frozen=True)
class ConservativeSafety(BiddingStrategy):
    """Greedy chunking with a reliability-widened safety margin."""

    name = "conservative_safety"

    #: exponent on ρ: >1 tightens faster as reliability falls
    power: float = 1.0
    #: lower bound on the effective θ (never demand impossible certainty)
    theta_floor: float = 1e-5

    def init_state(self, agent) -> Dict:
        return {"rho": 1.0}

    def effective_theta(self, agent, state) -> float:
        rho = float(state.get("rho", 1.0))
        return max(self.theta_floor, agent.cfg.theta * rho ** self.power)

    def bid(self, agent, state, announcement: WindowAnnouncement) -> List[List[Variant]]:
        theta = self.effective_theta(agent, state)
        # an unchanged bound stays literally the agent's own θ so the
        # byte-identity with GreedyChunking holds at ρ = 1
        if theta == agent.cfg.theta:
            theta = None
        return [
            chunk_chain_bids(
                agent, w, announcement.now,
                announcement.chips_for(w.slice_id), theta=theta,
            )
            for w in announcement.windows
        ]

    def observe(self, agent, state, feedback: RoundFeedback) -> bool:
        rho = feedback.reliability.get(agent.spec.job_id)
        if rho is None or rho == state["rho"]:
            return False
        state["rho"] = float(rho)
        return True
