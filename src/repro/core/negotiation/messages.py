"""Typed messages of the scheduler↔agent negotiation protocol.

The paper's interaction cycle is a *bidirectional* negotiation: the
scheduler announces execution windows, jobs answer with scored subjob
variants, the clearing awards a subset — and feedback about the clearing
flows BACK to the bidders so they can adapt.  Before this module the cycle
was encoded as loose positional arguments (``windows, now, n_chips``) and
the feedback half did not exist at all.  Each leg is now a frozen value
object:

    WindowAnnouncement ──▶ BidBundle ──▶ (score + clear) ──▶ RoundFeedback
         (step 1)          (steps 2–3)       (step 4)        (step 5 + §4.2.1)

* :class:`WindowAnnouncement` — one round's full window set plus per-slice
  chip counts; what ``JobAgent.respond`` consumes.
* :class:`BidBundle` — one agent's answer, grouped per announced window
  (the grouping is what lets the round pipeline drop an invalidated
  window's bids without regenerating the rest).
* :class:`Award` / :class:`LossReport` — per-bid outcomes inside the
  feedback; losses carry a coarse *reason* so strategies can react
  differently to being outscored vs. colliding with their own wins.
* :class:`RoundFeedback` — the broadcast published by
  ``JasdaScheduler._settle_round`` after every clear: per-window
  winning-score cutoffs, per-job awards/losses, and the §4.2.1 calibration
  state (reliability ρ, mean error ε̄, signed declaration bias) each agent
  needs for online bid shading.

Messages are immutable and value-comparable; :func:`build_feedback` is the
single constructor the scheduler (and baselines/tests) use, so the
feedback contents stay consistent across entry points.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..types import OVERLAP_EPS, PoolView, RoundResult, Variant, Window, overlaps

__all__ = [
    "WindowAnnouncement",
    "BidBundle",
    "Award",
    "LossReport",
    "RoundFeedback",
    "build_feedback",
    "LOSS_OUTSCORED",
    "LOSS_WINDOW_EMPTY",
    "LOSS_SELF_CONFLICT",
    "LOSS_SLICE_FAILED",
    "LOSS_SHED",
    "LOSS_PREEMPTED",
    "MIGRATED",
    "build_shed_feedback",
    "build_migration_feedback",
]


@dataclass(frozen=True)
class WindowAnnouncement:
    """Step 1: the full window set of one auction round.

    ``chips`` maps slice_id → chip count (throughput model input); windows
    keep the announcement order (the WindowPolicy ordering).
    """

    now: float
    windows: Tuple[Window, ...]
    chips: Mapping[str, int] = field(default_factory=dict)

    def chips_for(self, slice_id: str) -> int:
        return int(self.chips.get(slice_id, 1)) if self.chips else 1


@dataclass(frozen=True)
class BidBundle:
    """Steps 2–3: one agent's bids, grouped per announced window.

    ``by_window[k]`` holds the bids targeting ``announcement.windows[k]``
    (possibly empty — condition (a)/(b) failures keep the job silent on
    that window).  An agent may bid the same remaining work on several
    windows; cross-window exclusivity is enforced at clearing time.
    """

    job_id: str
    by_window: Tuple[Tuple[Variant, ...], ...]

    @property
    def variants(self) -> Tuple[Variant, ...]:
        """The flattened pool contribution, in window order."""
        return tuple(v for group in self.by_window for v in group)

    def __len__(self) -> int:
        return sum(len(g) for g in self.by_window)


@dataclass(frozen=True)
class Award:
    """One winning bid: the cleared variant, its window and commit score."""

    variant_id: str
    window: Window
    score: float


#: LossReport.reason values
LOSS_OUTSCORED = "outscored"  # window cleared, rivals' bids won instead
LOSS_WINDOW_EMPTY = "window_empty"  # the whole window cleared empty (→ dead)
# overlaps one of the job's OWN wins: a chain-position alternative yielding
# to the sibling the WIS picked, or a cross-slice duplicate revoked by
# conflict resolution.  NOT a market defeat — adaptive strategies must not
# react to it the way they react to being outscored.
LOSS_SELF_CONFLICT = "self_conflict"
# the slice backing an ALREADY-WON commitment died before execution: the
# win is revoked, the work re-enters the job's biddable pool, and the
# scheduler broadcasts this reason out-of-round (scheduler.revoke_slice).
# Like self_conflict it is NOT a market defeat — the bid price was fine;
# adaptive strategies should re-bid, not shade.
LOSS_SLICE_FAILED = "slice_failed"
# admission control shed the job before it could bid (open-loop service
# back-pressure: the pending pool would have exceeded the largest pow2
# scoring bucket, or a token-bucket rate limit fired).  Broadcast
# out-of-round (scheduler.shed_job / the service engine); the report's
# window is a zero-duration placeholder and its variant_id is the job id —
# no variant was ever generated.  NOT a market defeat: the job never
# priced anything.
LOSS_SHED = "shed"
# the revocation ladder interrupted a RUNNING commitment but credited the
# completed preempt_granularity granules (scheduler.preempt): only the
# residual work re-enters the biddable pool.  Broadcast out-of-round by the
# MigrationPlanner.  Like slice_failed it is NOT a market defeat — the bid
# price was fine; adaptive strategies should re-bid the residual, not shade.
LOSS_PREEMPTED = "preempted"
# the revocation ladder RE-PLACED a commitment's residual work on a
# compatible surviving slice (scheduler.migrate_commitment): the loss row
# retires the old variant id and a paired Award row carries the new
# placement, so bidders' cutoff/calibration state stays honest without the
# work ever leaving the schedule.  NOT a market defeat.
MIGRATED = "migrated"


@dataclass(frozen=True)
class LossReport:
    """One losing bid with a coarse reason and the window's score cutoff.

    ``cutoff`` is the lowest winning score in the bid's window (0.0 when
    the window cleared empty) — the auction-style price signal an adaptive
    bidder shades against.
    """

    variant_id: str
    window: Window
    reason: str
    cutoff: float = 0.0


@dataclass(frozen=True)
class RoundFeedback:
    """Step 5 + §4.2.1: what the clearing tells the bidders afterwards.

    One broadcast per settled round; agents read their own rows (keyed by
    job_id).  ``cutoffs`` maps ``Window.key`` → minimum winning score
    (0.0 for windows that cleared empty).  The calibration maps carry the
    scheduler's CURRENT trust state for every agent in the round —
    reliability ρ_J (Eq. 8), the windowed mean error ε̄, and the signed
    declaration bias (declared − observed EWMA) that bid-shading
    strategies steer to zero.
    """

    t: float
    windows: Tuple[Window, ...]
    cutoffs: Mapping[Tuple[str, float], float]
    awards: Mapping[str, Tuple[Award, ...]]
    losses: Mapping[str, Tuple[LossReport, ...]]
    reliability: Mapping[str, float]
    calibration_error: Mapping[str, float]
    calibration_bias: Mapping[str, float]
    n_selected: int = 0
    n_conflicts: int = 0

    def cutoff_for(self, window: Window) -> float:
        return float(self.cutoffs.get(window.key, 0.0))


def build_feedback(
    now: float,
    windows: Sequence[Window],
    agents: Sequence,
    bids: Sequence[Sequence[Sequence[Variant]]],
    rr: RoundResult,
    calibrator=None,
    *,
    view: Optional[PoolView] = None,
    win_idx=None,
) -> RoundFeedback:
    """Assemble the :class:`RoundFeedback` for one settled round.

    ``bids[a][k]`` are agent a's bids on window k (the RoundPrep layout).
    Variant ids are unique within a round (jobs._make_variant), so the
    winner sets key on them.  ``calibrator`` is the scheduler's
    :class:`~repro.core.calibration.Calibrator` (None in stateless tests:
    the calibration maps come back empty-trust ρ=1).

    When the caller supplies the round's ``view`` (the fitting pool's
    :class:`~repro.core.types.PoolView`) and ``win_idx``, AND the clearing
    reported per-window pool indices (``rr.selected_idx``), the award/loss
    classification runs on numpy columns instead of walking the variant
    objects: agents' bids occupy contiguous pool segments (the RoundPrep
    pooling order), winners are a boolean column, self-conflict detection
    is one pairwise interval matrix per agent.  Classification is
    equivalence-tested against the object walk; any shape mismatch (bids
    dropped by assign_bids, a custom backend without ``selected_idx``)
    falls back to the walk.
    """
    windows = list(windows)
    if (view is not None and win_idx is not None
            and len(rr.selected_idx) == len(windows)
            and len(view) == sum(len(g) for per in bids for g in per)):
        return _build_feedback_vectorized(
            now, windows, agents, bids, rr, calibrator, view, win_idx)
    # per-window winner ids + commit scores, and the cutoff price signal
    won_score: Dict[str, float] = {}
    winners_per_window: List[set] = []
    cutoffs: Dict[Tuple[str, float], float] = {}
    for k, result in enumerate(rr.results):
        ids = set()
        for v, s in zip(result.selected, result.scores):
            ids.add(v.variant_id)
            won_score[v.variant_id] = float(s)
        winners_per_window.append(ids)
        cutoffs[windows[k].key] = float(min(result.scores)) if result.scores else 0.0

    awards: Dict[str, Tuple[Award, ...]] = {}
    losses: Dict[str, Tuple[LossReport, ...]] = {}
    reliability: Dict[str, float] = {}
    calibration_error: Dict[str, float] = {}
    calibration_bias: Dict[str, float] = {}
    for agent, per_window in zip(agents, bids):
        job_id = agent.spec.job_id
        my_awards: List[Award] = []
        my_wins: List[Variant] = []
        lost: List[Tuple[Variant, Window, int]] = []
        for k, group in enumerate(per_window):
            if k >= len(windows):
                break
            for v in group:
                if v.variant_id in winners_per_window[k]:
                    my_awards.append(
                        Award(v.variant_id, windows[k], won_score[v.variant_id])
                    )
                    my_wins.append(v)
                else:
                    lost.append((v, windows[k], k))
        my_losses: List[LossReport] = []
        for v, w, k in lost:
            if not winners_per_window[k]:
                reason = LOSS_WINDOW_EMPTY
            elif any(overlaps(v, win) for win in my_wins):
                # same epsilon-tolerant predicate the clearing itself used,
                # so the classification matches the conflict resolution
                reason = LOSS_SELF_CONFLICT
            else:
                reason = LOSS_OUTSCORED
            my_losses.append(
                LossReport(v.variant_id, w, reason, cutoffs.get(w.key, 0.0))
            )
        if my_awards:
            awards[job_id] = tuple(my_awards)
        if my_losses:
            losses[job_id] = tuple(my_losses)
        if calibrator is not None:
            st = calibrator.state(job_id)
            reliability[job_id] = float(st.rho)
            # the same windowed E_v[ε] that drives ρ (Eq. 7/8), not the
            # full-history mean — the two diverge for long-lived jobs
            calibration_error[job_id] = float(
                st.mean_error(calibrator.config.error_window)
            )
            calibration_bias[job_id] = float(st.bias)
        else:
            reliability[job_id] = 1.0
            calibration_error[job_id] = 0.0
            calibration_bias[job_id] = 0.0
    return RoundFeedback(
        t=now,
        windows=tuple(windows),
        cutoffs=cutoffs,
        awards=awards,
        losses=losses,
        reliability=reliability,
        calibration_error=calibration_error,
        calibration_bias=calibration_bias,
        n_selected=len(rr.selected),
        n_conflicts=rr.n_conflicts,
    )


def build_shed_feedback(now: float, job_ids: Sequence[str],
                        calibrator=None) -> RoundFeedback:
    """Out-of-round feedback for admission-control sheds (``LOSS_SHED``).

    Mirrors the out-of-round broadcast ``scheduler.revoke_slice`` builds
    for ``slice_failed``: one :class:`LossReport` per shed job, empty
    window set (no round ran), a zero-duration placeholder window and the
    job id standing in for the never-generated variant id.  Shared by
    ``JasdaScheduler.shed_job`` (queued jobs evicted under back-pressure)
    and the service engine (arrivals rejected before admission).
    """
    losses: Dict[str, Tuple[LossReport, ...]] = {}
    reliability: Dict[str, float] = {}
    cal_err: Dict[str, float] = {}
    cal_bias: Dict[str, float] = {}
    for job_id in job_ids:
        losses[job_id] = (
            LossReport(job_id, Window("", 0.0, now, 0.0), LOSS_SHED),)
        if calibrator is not None:
            st = calibrator.state(job_id)
            reliability[job_id] = float(st.rho)
            cal_err[job_id] = float(
                st.mean_error(calibrator.config.error_window))
            cal_bias[job_id] = float(st.bias)
        else:
            reliability[job_id] = 1.0
            cal_err[job_id] = 0.0
            cal_bias[job_id] = 0.0
    return RoundFeedback(
        t=now, windows=(), cutoffs={}, awards={}, losses=losses,
        reliability=reliability, calibration_error=cal_err,
        calibration_bias=cal_bias,
    )


def build_migration_feedback(now: float, migrations: Sequence = (),
                             preemptions: Sequence = (),
                             calibrator=None) -> RoundFeedback:
    """Out-of-round feedback for the revocation ladder's first two rungs.

    ``migrations`` rows are ``(job_id, old_variant_id, new_variant_id,
    old_window, new_window, score)``: each emits a ``MIGRATED`` loss
    retiring the old placement plus an :class:`Award` for the new one (the
    commit score carries over — migration is not a re-auction).
    ``preemptions`` rows are ``(job_id, variant_id, window)``: one
    ``LOSS_PREEMPTED`` report each, the residual work having re-entered
    the job's biddable pool.  Mirrors :func:`build_shed_feedback`: empty
    window set (no round ran), calibration state snapshotted per job.
    """
    awards: Dict[str, List[Award]] = {}
    losses: Dict[str, List[LossReport]] = {}
    for job_id, old_vid, new_vid, old_w, new_w, score in migrations:
        losses.setdefault(job_id, []).append(
            LossReport(old_vid, old_w, MIGRATED))
        awards.setdefault(job_id, []).append(
            Award(new_vid, new_w, float(score)))
    for job_id, vid, w in preemptions:
        losses.setdefault(job_id, []).append(
            LossReport(vid, w, LOSS_PREEMPTED))
    reliability: Dict[str, float] = {}
    cal_err: Dict[str, float] = {}
    cal_bias: Dict[str, float] = {}
    for job_id in sorted(set(awards) | set(losses)):
        if calibrator is not None:
            st = calibrator.state(job_id)
            reliability[job_id] = float(st.rho)
            cal_err[job_id] = float(
                st.mean_error(calibrator.config.error_window))
            cal_bias[job_id] = float(st.bias)
        else:
            reliability[job_id] = 1.0
            cal_err[job_id] = 0.0
            cal_bias[job_id] = 0.0
    return RoundFeedback(
        t=now, windows=(), cutoffs={},
        awards={j: tuple(a) for j, a in awards.items()},
        losses={j: tuple(l) for j, l in losses.items()},
        reliability=reliability, calibration_error=cal_err,
        calibration_bias=cal_bias,
    )


def _build_feedback_vectorized(
    now: float,
    windows: List[Window],
    agents: Sequence,
    bids: Sequence[Sequence[Sequence[Variant]]],
    rr: RoundResult,
    calibrator,
    view: PoolView,
    win_idx,
) -> RoundFeedback:
    """PoolView-column award/loss classification (the fast path).

    Pool layout invariant (RoundPrep): bids are pooled agent-major,
    window-major within an agent, and the caller verified nothing was
    dropped by window assignment — so each agent owns one contiguous
    segment of the pool and ``win_idx`` equals each bid's group index.
    Output (tuples, ordering, reasons, cutoffs) is identical to the object
    walk above, which remains the reference (equivalence-tested).
    """
    m = len(view)
    win_k = np.asarray(win_idx, np.intp)
    sel_mask = np.zeros(m, bool)
    score_of = np.zeros(m, np.float64)
    winner_count = np.zeros(len(windows), np.intp)
    cutoffs: Dict[Tuple[str, float], float] = {}
    for k, (sel_idx, result) in enumerate(zip(rr.selected_idx, rr.results)):
        if sel_idx:
            ia = np.asarray(sel_idx, np.intp)
            sel_mask[ia] = True
            score_of[ia] = np.asarray(result.scores, np.float64)
        winner_count[k] = len(sel_idx)
        cutoffs[windows[k].key] = float(min(result.scores)) if result.scores else 0.0

    ts, te = view.t_start, view.t_end
    vids = view.variant_ids
    awards: Dict[str, Tuple[Award, ...]] = {}
    losses: Dict[str, Tuple[LossReport, ...]] = {}
    reliability: Dict[str, float] = {}
    calibration_error: Dict[str, float] = {}
    calibration_bias: Dict[str, float] = {}
    lo = 0
    for agent, per_window in zip(agents, bids):
        job_id = agent.spec.job_id
        n = sum(len(g) for g in per_window)
        seg = np.arange(lo, lo + n)
        lo += n
        if n:
            seg_sel = sel_mask[seg]
            my_sel = seg[seg_sel]
            if len(my_sel):
                awards[job_id] = tuple(
                    Award(vids[i], windows[win_k[i]], float(score_of[i]))
                    for i in my_sel
                )
            loss_idx = seg[~seg_sel]
            if len(loss_idx):
                empty = winner_count[win_k[loss_idx]] == 0
                if len(my_sel):
                    ws, we = ts[my_sel], te[my_sel]
                    ls, le = ts[loss_idx], te[loss_idx]
                    olap = np.any(
                        (ls[:, None] < we[None, :] - OVERLAP_EPS)
                        & (ws[None, :] < le[:, None] - OVERLAP_EPS),
                        axis=1,
                    )
                else:
                    olap = np.zeros(len(loss_idx), bool)
                my_losses = []
                for i, is_empty, is_olap in zip(loss_idx, empty, olap):
                    reason = (LOSS_WINDOW_EMPTY if is_empty
                              else LOSS_SELF_CONFLICT if is_olap
                              else LOSS_OUTSCORED)
                    w = windows[win_k[i]]
                    my_losses.append(
                        LossReport(vids[i], w, reason, cutoffs.get(w.key, 0.0)))
                losses[job_id] = tuple(my_losses)
        if calibrator is not None:
            st = calibrator.state(job_id)
            reliability[job_id] = float(st.rho)
            calibration_error[job_id] = float(
                st.mean_error(calibrator.config.error_window)
            )
            calibration_bias[job_id] = float(st.bias)
        else:
            reliability[job_id] = 1.0
            calibration_error[job_id] = 0.0
            calibration_bias[job_id] = 0.0
    return RoundFeedback(
        t=now,
        windows=tuple(windows),
        cutoffs=cutoffs,
        awards=awards,
        losses=losses,
        reliability=reliability,
        calibration_error=calibration_error,
        calibration_bias=calibration_bias,
        n_selected=len(rr.selected),
        n_conflicts=rr.n_conflicts,
    )
