"""The ``BiddingStrategy`` protocol: the bid side of a round as an API.

The paper's jobs "actively generate and score feasible subjobs in response
to scheduler-announced execution windows" — variant generation, chunk
sizing, window targeting and self-scoring are *decisions*, and before this
module they were hardcoded inside ``JobAgent``.  ``BiddingStrategy`` is
the bid-side mirror of :class:`~repro.core.policy.base.ClearingPolicy`: a
frozen, swappable backend that owns those decisions, while ``JobAgent``
slims to a state-holder (progress, commitments, safety cache, truthful
feature computation) that delegates through ``AgentConfig.strategy``.

Shipped backends (one module each):

* :class:`~repro.core.negotiation.greedy.GreedyChunking` — the default;
  byte-identical to the historical ``generate_variants_round`` chunk
  chain (pinned against a frozen reference in tests/test_negotiation.py).
* :class:`~repro.core.negotiation.adaptive.AdaptiveBidder` — consumes
  :class:`~repro.core.negotiation.messages.RoundFeedback` (per-window
  winning-score cutoffs, loss reasons, realized calibration bias) to
  adapt chunk size, window targeting and declaration shading online.
* :class:`~repro.core.negotiation.conservative.ConservativeSafety` —
  widens the θ safety margin as a function of calibration reliability ρ,
  making probabilistic safety an agent-side policy.

Replayability contract (the round pipeline relies on it): ``bid`` must be
a pure function of ``(agent state, strategy state, announcement)`` except
for the ``agent.n_bids`` counter, which the pipeline snapshots and rolls
back.  ALL adaptation happens in ``observe``, which runs at settle time —
strictly after any speculative ``bid`` for the next round was taken — and
returns True when the mutation could change future bids, so the scheduler
bumps its state epoch and provably invalidates stale speculation.
"""
from __future__ import annotations

import abc
from typing import Any, List, Optional, Sequence

from ..atomizer import chunk_candidates
from ..types import TIME_EPS, Variant, Window
from .messages import RoundFeedback, WindowAnnouncement

__all__ = ["BiddingStrategy", "chunk_chain_bids"]


class BiddingStrategy(abc.ABC):
    """Owns one agent's bid-side decisions (see module docstring).

    Implementations must be frozen dataclasses (hashable, comparable) so
    an ``AgentConfig`` embedding one stays a value object; per-agent
    mutable adaptation state lives in the object returned by
    :meth:`init_state` (held by the agent), never on the strategy itself —
    one strategy instance may serve a whole population.
    """

    #: short stable identifier used in logs / benchmark rows
    name: str = "abstract"

    def init_state(self, agent) -> Any:
        """Fresh per-agent adaptation state (None for stateless backends)."""
        return None

    @abc.abstractmethod
    def bid(
        self, agent, state, announcement: WindowAnnouncement
    ) -> List[List[Variant]]:
        """Answer one announcement: bids grouped per announced window.

        Must align with ``announcement.windows`` (empty group = silent on
        that window) and must not mutate ``state`` (see the replayability
        contract in the module docstring).
        """

    def observe(self, agent, state, feedback: RoundFeedback) -> bool:
        """Ingest one round's feedback; return True if ``state`` changed
        in a way that could alter future bids.  Default: stateless no-op."""
        return False


def chunk_chain_bids(
    agent,
    window: Window,
    now: float,
    n_chips: int = 1,
    *,
    theta: Optional[float] = None,
    shade: float = 1.0,
    chunk_scale: float = 1.0,
    alternatives: bool = True,
    n_start_offsets: Optional[int] = None,
) -> List[Variant]:
    """The shared chunk-chain generator every shipped strategy builds on.

    Builds a CHAIN of sequential chunks through the window (the paper's
    worked example: J_A fills w* with two tiling variants) plus smaller
    overlapping alternatives at each chain position.  Alternatives at one
    position mutually overlap, so the WIS clearing picks at most one per
    position; chain positions carve work from disjoint portions, so any
    selected combination commits ≤ biddable work.

    With the default knobs this is the historical ``JobAgent.
    generate_variants`` body verbatim (byte-identical, pinned by the
    frozen-reference property test).  The knobs are the strategy surface:

    * ``theta`` — safety bound for condition (a) and the per-variant
      ``Variant.theta`` stamp (None = the agent's own ``cfg.theta``);
      :class:`ConservativeSafety` passes its ρ-widened bound here.
    * ``shade`` — multiplicative declaration shading on the declared φs
      (:class:`AdaptiveBidder`'s calibration-bias steering).
    * ``chunk_scale`` ∈ (0, 1] — cap each chain chunk at this fraction of
      the remaining work, trading per-chunk progress for chain depth
      (more, smaller chunks packed through the window).
    * ``alternatives`` — offer the geometric ladder of smaller chunks at
      each chain position (True = historical behavior); adaptive bidders
      turn it off so the per-window variant budget buys chain depth
      instead of head alternatives.
    * ``n_start_offsets`` — start-time alternatives per chain position
      (None = the agent's own ``AgentConfig.n_start_offsets``; default 1 =
      historical behavior, byte-identical).  With n > 1, the position's
      carrier chunk is re-offered at n−1 later starts, evenly spaced
      within the SHORTEST alternative offered at the position — every
      offset copy therefore overlaps every sibling (WIS keeps at most one
      per position, preserving the chain's ≤-biddable-work invariant)
      while giving the packing freedom to dodge a rival's interval edge.
    """
    if agent.finished or agent.biddable_work <= TIME_EPS:
        return []
    thr = agent.throughput_on(window.capacity, n_chips)
    if thr <= 0:
        return []  # condition (b) fails → silent
    # condition (a): probabilistic safety against this slice's capacity
    if not agent.is_safe_on(window.capacity, theta):
        return []
    if n_start_offsets is None:
        n_start_offsets = getattr(agent.cfg, "n_start_offsets", 1)
    n_start_offsets = max(1, int(n_start_offsets))

    variants: List[Variant] = []
    remaining = agent.biddable_work
    t_cursor = window.t_min
    max_v = agent.atomizer.max_variants_per_window
    # smallest chunk worth asking for: τ_min of work at this throughput
    min_ask = agent.atomizer.tau_min * thr
    while remaining > TIME_EPS and t_cursor < window.t_end - TIME_EPS and len(variants) < max_v:
        span = window.t_end - t_cursor
        ask = remaining
        if chunk_scale < 1.0:
            ask = min(remaining, max(remaining * chunk_scale, min_ask))
        plans = chunk_candidates(ask, thr, span, agent.atomizer)
        if not plans:
            break
        offered = plans if alternatives else plans[:1]
        # emission order per position: carrier chunk, then its start-time
        # alternatives (the knob the agent explicitly asked for — they get
        # budget priority), then the smaller-chunk ladder.  With the
        # default n_start_offsets=1 this is exactly the historical
        # sequence, byte-identical.
        position = [(t_cursor, plans[0])]
        if n_start_offsets > 1:
            # the carrier shifted by o·(d_min/n) for o = 1..n−1.  Offsets
            # stay strictly inside the SHORTEST sibling's duration, so
            # every copy overlaps every alternative at this position
            # (mutual exclusivity under WIS: at most one committed per
            # position); the chain cursor still advances from the
            # unshifted carrier, so positions keep carving disjoint work.
            delta = min(p.duration for p in offered) / n_start_offsets
            position += [(t_cursor + o * delta, plans[0])
                         for o in range(1, n_start_offsets)]
        position += [(t_cursor, p) for p in offered[1:]]
        for t0, plan in position:
            if len(variants) >= max_v:
                break
            if t0 + plan.duration > window.t_end + TIME_EPS:
                continue
            if agent._overlaps_own(t0, plan.duration):
                continue  # job already committed elsewhere in this span
            variants.append(
                agent.make_variant(
                    window, t0, plan, now, len(variants),
                    shade=shade, theta=theta,
                )
            )
        largest = plans[0]
        remaining -= largest.work
        t_cursor += largest.duration
    if variants:
        agent.n_bids += 1
    return variants
