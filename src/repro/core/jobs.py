"""Job agents (paper §3.2–§3.3): autonomous variant generation and bidding.

Each JobAgent owns a JobSpec + mutable progress state and implements the
job side of the interaction cycle.  In the round model the scheduler
announces ALL open windows at once and the agent answers with one pooled
bid list (:meth:`JobAgent.generate_variants_round`); per-window generation
(:meth:`JobAgent.generate_variants`) remains the building block and the
legacy single-window API.  An agent may bid the same remaining work against
several windows in one round — cross-window exclusivity (a job never holds
two overlapping intervals, and never wins more work than it has) is enforced
at clearing time (clearing.clear_round), not at generation time.

Eligibility (paper §4.1):
  (a) probabilistic safety  Pr(max RAM > c_k | FMP) ≤ θ   (safe-by-construction)
  (b) slice-specific constraints (affinity / min-capacity / compatibility)

Local utility h̃(v) = Σ α φ(v) uses the job's OWN weighting of the paper's
features (φ_JCT, φ_QoS, φ_progress).  A ``misreport`` factor lets experiments
model strategic jobs (declaring inflated φs) — the §4.2.1 calibration layer
is what keeps them in check, and tests verify exactly that.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .atomizer import AtomizerConfig, chunk_candidates
from .scoring import JobFeatures
from .trp import PhaseFMP, is_safe
from .types import OVERLAP_EPS, JobSpec, JobState, Variant, Window

__all__ = ["JobAgent", "AgentConfig"]


@dataclass(frozen=True)
class AgentConfig:
    theta: float = 0.05  # θ: capacity-violation risk bound
    safety_method: str = "grid"  # grid | union (trp.py evaluators)
    # how the job weights its own features inside h̃ (Σ ≤ 1)
    alphas: Mapping[str, float] = field(
        default_factory=lambda: {"jct": 0.5, "qos": 0.3, "progress": 0.2}
    )
    # strategic misreporting factor: declared φ = clip(truth * misreport)
    misreport: float = 1.0
    # start-time alternatives within the window (beyond t_min itself)
    n_start_offsets: int = 1


class JobAgent:
    """The decision-capable agent wrapping one job."""

    def __init__(
        self,
        spec: JobSpec,
        cfg: AgentConfig = AgentConfig(),
        atomizer: AtomizerConfig = AtomizerConfig(),
    ):
        self.spec = spec
        self.cfg = cfg
        self.atomizer = atomizer
        self.state = JobState.WAITING
        self.work_done: float = 0.0
        self.n_bids = 0
        self.n_wins = 0
        # outstanding commitments: work already won but not yet executed, and
        # the time intervals it occupies (a job is a sequential subjob stream
        # — it must never hold two overlapping intervals, even across slices)
        self.outstanding_work: float = 0.0
        self.committed_intervals: list = []
        # safety verdicts are a function of (capacity,) only for a fixed FMP —
        # memoized so a round over many same-capacity windows checks once
        self._safety_cache: Dict[float, bool] = {}

    # -- progress ------------------------------------------------------------
    @property
    def work_remaining(self) -> float:
        return max(0.0, self.spec.total_work - self.work_done)

    @property
    def biddable_work(self) -> float:
        """Remaining work not yet covered by an outstanding commitment."""
        return max(0.0, self.work_remaining - self.outstanding_work)

    def mark_committed(self, variant: Variant) -> None:
        self.outstanding_work += variant.payload["work"]
        self.committed_intervals.append(variant.interval)

    def mark_settled(self, variant: Variant) -> None:
        """Commitment resolved (executed or failed): free the reservation."""
        self.outstanding_work = max(0.0, self.outstanding_work - variant.payload["work"])
        if variant.interval in self.committed_intervals:
            self.committed_intervals.remove(variant.interval)

    def _overlaps_own(self, t_start: float, duration: float) -> bool:
        t_end = t_start + duration
        for s, e in self.committed_intervals:
            if t_start < e - OVERLAP_EPS and s < t_end - OVERLAP_EPS:
                return True
        return False

    @property
    def finished(self) -> bool:
        return self.work_remaining <= 1e-9

    def record_progress(self, work: float) -> None:
        self.work_done += work
        if self.finished:
            self.state = JobState.FINISHED

    # -- throughput model ----------------------------------------------------
    def throughput_on(self, capacity: float, n_chips: int = 1) -> float:
        """Work units per second the job expects on a slice of this size.

        Simple linear-scaling model with a memory floor: a slice below
        ``min_capacity`` yields zero (condition (b): job stays silent).
        """
        if capacity < self.spec.min_capacity:
            return 0.0
        return float(n_chips)

    def _is_safe_on(self, capacity: float) -> bool:
        """Condition (a) memoized by capacity (the FMP is fixed per agent)."""
        hit = self._safety_cache.get(capacity)
        if hit is None:
            hit = is_safe(
                self.spec.fmp, capacity, self.cfg.theta, method=self.cfg.safety_method
            )
            self._safety_cache[capacity] = hit
        return hit

    # -- speculative-bid support (core/pipeline.py) ----------------------------
    def stats_snapshot(self) -> int:
        """The one counter speculative bid generation mutates: ``n_bids``.

        Variant ids are deterministic per (window, chain position) — see
        :meth:`_make_variant` — so generation itself is replayable.  Nothing
        else may be snapshotted here: the snapshot is taken BEFORE the
        in-flight round settles, and settle legitimately bumps ``n_wins`` —
        a wider rollback would erase it.
        """
        return self.n_bids

    def stats_restore(self, snap: int) -> None:
        self.n_bids = snap

    # -- the job side of one auction round (steps 2–3) -------------------------
    def generate_variants_round(
        self,
        windows: Sequence[Window],
        now: float,
        n_chips: Optional[Mapping[str, int]] = None,
    ) -> List[Variant]:
        """Bid against the FULL window set of a round in one call.

        Variants for different windows may claim the same remaining work (and
        overlapping time spans on different slices); the round clearing keeps
        at most one win per conflict.  ``n_chips`` maps slice_id → chip count.
        """
        out: List[Variant] = []
        for per_window in self.generate_variants_by_window(windows, now, n_chips):
            out.extend(per_window)
        return out

    def generate_variants_by_window(
        self,
        windows: Sequence[Window],
        now: float,
        n_chips: Optional[Mapping[str, int]] = None,
    ) -> List[List[Variant]]:
        """Round bidding with per-window grouping (aligned with ``windows``).

        The grouped form is what the round pipeline needs: when a
        speculatively-announced window is invalidated (it died in the round
        being settled), its bids are dropped wholesale without touching the
        other windows' bids.  Generation per window is independent — a bid
        built for window w fits only w (windows on one slice are disjoint
        gaps), so dropping a group reproduces exactly the pool a fresh
        announcement over the surviving windows would have produced.
        """
        if self.finished or self.biddable_work <= 1e-9:
            return [[] for _ in windows]
        out: List[List[Variant]] = []
        for w in windows:
            chips = n_chips.get(w.slice_id, 1) if n_chips else 1
            out.append(self.generate_variants(w, now, chips))
        return out

    # -- the job side of one JASDA iteration (steps 2–3, single window) --------
    def generate_variants(self, window: Window, now: float, n_chips: int = 1) -> List[Variant]:
        if self.finished or self.biddable_work <= 1e-9:
            return []
        thr = self.throughput_on(window.capacity, n_chips)
        if thr <= 0:
            return []  # condition (b) fails → silent
        # condition (a): probabilistic safety against this slice's capacity
        if not self._is_safe_on(window.capacity):
            return []

        # Build a CHAIN of sequential chunks through the window (the paper's
        # worked example: J_A fills w* with two tiling variants) plus smaller
        # overlapping alternatives at each chain position.  Alternatives at
        # one position mutually overlap, so the WIS clearing picks at most
        # one per position; chain positions carve work from disjoint
        # portions, so any selected combination commits ≤ biddable work.
        variants: List[Variant] = []
        remaining = self.biddable_work
        t_cursor = window.t_min
        max_v = self.atomizer.max_variants_per_window
        while remaining > 1e-9 and t_cursor < window.t_end - 1e-9 and len(variants) < max_v:
            span = window.t_end - t_cursor
            plans = chunk_candidates(remaining, thr, span, self.atomizer)
            if not plans:
                break
            for plan in plans:
                if len(variants) >= max_v:
                    break
                if t_cursor + plan.duration > window.t_end + 1e-9:
                    continue
                if self._overlaps_own(t_cursor, plan.duration):
                    continue  # job already committed elsewhere in this span
                variants.append(
                    self._make_variant(window, t_cursor, plan, now, len(variants))
                )
            largest = plans[0]
            remaining -= largest.work
            t_cursor += largest.duration
        if variants:
            self.n_bids += 1
        return variants

    def _make_variant(
        self, window: Window, t_start: float, plan, now: float, seq: int
    ) -> Variant:
        feats = self._features(plan.work, plan.duration, t_start, now)
        declared = {
            k: float(np.clip(v * self.cfg.misreport, 0.0, 1.0))
            for k, v in feats.items()
        }
        h = sum(self.cfg.alphas.get(k, 0.0) * v for k, v in declared.items())
        # Deterministic id: (window, chain position) — NOT a global counter.
        # Regenerating the same bid set (e.g. after a discarded speculative
        # round in the pipeline) must yield identical ids; uniqueness holds
        # within a round because a job bids each window at most once.
        vid = (f"{self.spec.job_id}/{window.slice_id}"
               f"@{window.t_min:.9g}#{seq}")
        return Variant(
            job_id=self.spec.job_id,
            slice_id=window.slice_id,
            t_start=t_start,
            duration=plan.duration,
            fmp=self.spec.fmp,
            local_utility=float(np.clip(h, 0.0, 1.0)),
            declared_features=declared,
            payload={
                "work": plan.work,
                "activation": self.atomizer.activation_cost,
                "true_features": feats,  # ground truth (≠ declared if misreporting)
            },
            variant_id=vid,
            # the agent's OWN risk bound rides along so the in-dispatch
            # safety recheck can verify per-agent θ (PackedRound.thetas)
            theta=self.cfg.theta,
        )

    # -- truthful feature values (what an honest job declares) ----------------
    def _features(self, work: float, duration: float, t_start: float, now: float) -> Dict[str, float]:
        """Honest φ values, spread over [0,1] so they discriminate.

        φ_JCT uses the chunk's *efficiency*: ideal compute time over committed
        span including queueing delay (chunks starting soon and running dense
        score high).  φ_QoS is the deadline-feasibility indicator.  φ_progress
        is the fraction of remaining work the chunk covers.
        """
        finish = t_start + duration
        wait = max(0.0, t_start - now)
        phi_jct = float(np.clip(duration / max(duration + wait, 1e-9), 0.0, 1.0))
        if self.spec.qos_deadline is None:
            phi_qos = 1.0
        else:
            rem_after = self.work_remaining - work
            est_completion = finish + rem_after  # thr≈1 chip ⇒ seconds ≈ work
            phi_qos = JobFeatures.qos(est_completion <= self.spec.qos_deadline)
        phi_prog = JobFeatures.progress(work, self.work_remaining)
        return {"jct": phi_jct, "qos": phi_qos, "progress": phi_prog}
