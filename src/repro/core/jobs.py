"""Job agents (paper §3.2–§3.3): the stateful half of the bid side.

Each JobAgent owns a JobSpec + mutable progress state (work done,
outstanding commitments, safety cache, bid/win statistics) and implements
the job side of the interaction cycle by DELEGATING every decision —
variant generation, chunk sizing, window targeting, self-scoring, feedback
consumption — to a pluggable :class:`~repro.core.negotiation.base.
BiddingStrategy` selected via ``AgentConfig.strategy`` (default:
:class:`~repro.core.negotiation.greedy.GreedyChunking`, byte-identical to
the historical hardcoded generation).

The typed round protocol (``repro.core.negotiation.messages``):

* :meth:`JobAgent.respond` consumes a ``WindowAnnouncement`` and returns a
  ``BidBundle`` (bids grouped per announced window);
* :meth:`JobAgent.observe_feedback` ingests the scheduler's
  ``RoundFeedback`` after every clear and reports whether the strategy
  adapted (the scheduler bumps its state epoch when it did, so the round
  pipeline's speculative preparations stay provably serial-equivalent).

``generate_variants_round`` / ``generate_variants_by_window`` /
``generate_variants`` survive as thin delegates over the same single code
path (the strategy), so every pre-negotiation caller keeps working.

Eligibility (paper §4.1):
  (a) probabilistic safety  Pr(max RAM > c_k | FMP) ≤ θ   (safe-by-construction)
  (b) slice-specific constraints (affinity / min-capacity / compatibility)

Local utility h̃(v) = Σ α φ(v) uses the job's OWN weighting of the paper's
features (φ_JCT, φ_QoS, φ_progress).  A ``misreport`` factor lets experiments
model strategic jobs (declaring inflated φs) — the §4.2.1 calibration layer
is what keeps them in check, and tests verify exactly that.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .atomizer import AtomizerConfig
from .negotiation import (BiddingStrategy, BidBundle, GreedyChunking,
                          RoundFeedback, WindowAnnouncement)
from .scoring import JobFeatures
from .trp import is_safe
from .types import OVERLAP_EPS, TIME_EPS, JobSpec, JobState, Variant, Window

__all__ = ["JobAgent", "AgentConfig"]


@dataclass(frozen=True)
class AgentConfig:
    theta: float = 0.05  # θ: capacity-violation risk bound
    safety_method: str = "grid"  # grid | union (trp.py evaluators)
    # how the job weights its own features inside h̃ (Σ ≤ 1)
    alphas: Mapping[str, float] = field(
        default_factory=lambda: {"jct": 0.5, "qos": 0.3, "progress": 0.2}
    )
    # strategic misreporting factor: declared φ = clip(truth * misreport)
    misreport: float = 1.0
    # start-time alternatives within the window (beyond t_min itself)
    n_start_offsets: int = 1
    # the bid-side decision backend (repro.core.negotiation.BiddingStrategy);
    # None = GreedyChunking (the historical generation, byte-identical)
    strategy: Optional[BiddingStrategy] = None


class JobAgent:
    """The decision-capable agent wrapping one job (state-holder half)."""

    def __init__(
        self,
        spec: JobSpec,
        cfg: AgentConfig = AgentConfig(),
        atomizer: AtomizerConfig = AtomizerConfig(),
    ):
        self.spec = spec
        self.cfg = cfg
        self.atomizer = atomizer
        self.strategy: BiddingStrategy = (
            cfg.strategy if cfg.strategy is not None else GreedyChunking()
        )
        self.strategy_state = self.strategy.init_state(self)
        self.state = JobState.WAITING
        self.work_done: float = 0.0
        self.n_bids = 0
        self.n_wins = 0
        self.score_won: float = 0.0  # total cleared (committed) score
        # outstanding commitments: work already won but not yet executed, and
        # the time intervals it occupies (a job is a sequential subjob stream
        # — it must never hold two overlapping intervals, even across slices)
        self.outstanding_work: float = 0.0
        self.committed_intervals: list = []
        # safety verdicts are a function of (capacity, θ) only for a fixed
        # FMP — memoized so a round over many same-capacity windows checks
        # once (θ in the key: strategies may tighten the agent's own bound)
        self._safety_cache: Dict[Tuple[float, float], bool] = {}

    # -- progress ------------------------------------------------------------
    @property
    def work_remaining(self) -> float:
        return max(0.0, self.spec.total_work - self.work_done)

    @property
    def biddable_work(self) -> float:
        """Remaining work not yet covered by an outstanding commitment."""
        return max(0.0, self.work_remaining - self.outstanding_work)

    def mark_committed(self, variant: Variant) -> None:
        self.outstanding_work += variant.payload["work"]
        self.committed_intervals.append(variant.interval)

    def mark_settled(self, variant: Variant) -> None:
        """Commitment resolved (executed or failed): free the reservation."""
        self.outstanding_work = max(0.0, self.outstanding_work - variant.payload["work"])
        if variant.interval in self.committed_intervals:
            self.committed_intervals.remove(variant.interval)

    def _overlaps_own(self, t_start: float, duration: float) -> bool:
        t_end = t_start + duration
        for s, e in self.committed_intervals:
            if t_start < e - OVERLAP_EPS and s < t_end - OVERLAP_EPS:
                return True
        return False

    @property
    def finished(self) -> bool:
        return self.work_remaining <= 1e-9

    def record_progress(self, work: float) -> None:
        self.work_done += work
        if self.finished:
            self.state = JobState.FINISHED

    # -- throughput model ----------------------------------------------------
    def throughput_on(self, capacity: float, n_chips: int = 1) -> float:
        """Work units per second the job expects on a slice of this size.

        Simple linear-scaling model with a memory floor: a slice below
        ``min_capacity`` yields zero (condition (b): job stays silent).
        """
        if capacity < self.spec.min_capacity:
            return 0.0
        return float(n_chips)

    #: safety-verdict memo bound: strategies with a drifting θ (e.g.
    #: ConservativeSafety, whose ρ changes with every verification) insert
    #: one entry per distinct bound — evict oldest-first past this size so
    #: a long-lived agent's cache cannot grow without limit
    _SAFETY_CACHE_MAX = 256

    def is_safe_on(self, capacity: float, theta: Optional[float] = None) -> bool:
        """Condition (a) memoized by (capacity, θ) — the FMP is fixed.

        ``theta=None`` checks the agent's own ``cfg.theta``; strategies
        (e.g. ConservativeSafety) may pass a tightened bound.  Within one
        round θ is fixed per strategy, so the memo still collapses a
        many-window announcement to one FMP evaluation per capacity.
        """
        if theta is None:
            theta = self.cfg.theta
        key = (capacity, theta)
        hit = self._safety_cache.get(key)
        if hit is None:
            hit = is_safe(
                self.spec.fmp, capacity, theta, method=self.cfg.safety_method
            )
            while len(self._safety_cache) >= self._SAFETY_CACHE_MAX:
                self._safety_cache.pop(next(iter(self._safety_cache)))
            self._safety_cache[key] = hit
        return hit

    # -- speculative-bid support (core/pipeline.py) ----------------------------
    def stats_snapshot(self) -> int:
        """The one counter speculative bid generation mutates: ``n_bids``.

        Variant ids are deterministic per (window, chain position) — see
        :meth:`make_variant` — so generation itself is replayable (the
        strategy ``bid`` contract forbids mutating strategy state).
        Nothing else may be snapshotted here: the snapshot is taken BEFORE
        the in-flight round settles, and settle legitimately bumps
        ``n_wins`` / the strategy state — a wider rollback would erase it.
        """
        return self.n_bids

    def stats_restore(self, snap: int) -> None:
        self.n_bids = snap

    # -- the job side of one auction round (typed protocol) --------------------
    def respond(self, announcement: WindowAnnouncement) -> BidBundle:
        """Steps 2–3: answer one announcement through the strategy.

        Returns the agent's :class:`BidBundle` (bids grouped per announced
        window, aligned with ``announcement.windows``).  A finished or
        fully-committed job answers with an empty bundle without invoking
        the strategy.
        """
        if self.finished or self.biddable_work <= TIME_EPS:
            groups: Sequence[Sequence[Variant]] = [
                () for _ in announcement.windows
            ]
        else:
            groups = self.strategy.bid(self, self.strategy_state, announcement)
        return BidBundle(
            job_id=self.spec.job_id,
            by_window=tuple(tuple(g) for g in groups),
        )

    def observe_feedback(self, feedback: RoundFeedback) -> bool:
        """Step 5 closing leg: ingest the clearing's feedback broadcast.

        Returns True when the strategy adapted in a way that could change
        future bids (the scheduler invalidates speculative rounds then).
        """
        return bool(
            self.strategy.observe(self, self.strategy_state, feedback)
        )

    # -- legacy generation API: thin delegates over respond() ------------------
    def generate_variants_round(
        self,
        windows: Sequence[Window],
        now: float,
        n_chips: Optional[Mapping[str, int]] = None,
    ) -> List[Variant]:
        """Bid against the FULL window set of a round in one call.

        Variants for different windows may claim the same remaining work (and
        overlapping time spans on different slices); the round clearing keeps
        at most one win per conflict.  ``n_chips`` maps slice_id → chip count.
        """
        out: List[Variant] = []
        for per_window in self.generate_variants_by_window(windows, now, n_chips):
            out.extend(per_window)
        return out

    def generate_variants_by_window(
        self,
        windows: Sequence[Window],
        now: float,
        n_chips: Optional[Mapping[str, int]] = None,
    ) -> List[List[Variant]]:
        """Round bidding with per-window grouping (aligned with ``windows``).

        The grouped form is what the round pipeline needs: when a
        speculatively-announced window is invalidated (it died in the round
        being settled), its bids are dropped wholesale without touching the
        other windows' bids.  Generation per window is independent — a bid
        built for window w fits only w (windows on one slice are disjoint
        gaps), so dropping a group reproduces exactly the pool a fresh
        announcement over the surviving windows would have produced.
        """
        bundle = self.respond(
            WindowAnnouncement(
                now=now, windows=tuple(windows), chips=dict(n_chips or {})
            )
        )
        return [list(g) for g in bundle.by_window]

    def generate_variants(self, window: Window, now: float, n_chips: int = 1) -> List[Variant]:
        """Single-window bidding (the legacy A3 API): a one-window round."""
        return self.generate_variants_by_window(
            [window], now, {window.slice_id: n_chips}
        )[0]

    # -- variant assembly (strategies drive this; truth stays here) ------------
    def make_variant(
        self,
        window: Window,
        t_start: float,
        plan,
        now: float,
        seq: int,
        *,
        shade: float = 1.0,
        theta: Optional[float] = None,
    ) -> Variant:
        """Build one bid: truthful φs, then the declaration the strategy asks
        for (misreport × shade, clipped) and the θ it bids under."""
        feats = self._features(plan.work, plan.duration, t_start, now)
        declared = {
            k: float(np.clip(v * self.cfg.misreport * shade, 0.0, 1.0))
            for k, v in feats.items()
        }
        h = sum(self.cfg.alphas.get(k, 0.0) * v for k, v in declared.items())
        # Deterministic id: (window, chain position) — NOT a global counter.
        # Regenerating the same bid set (e.g. after a discarded speculative
        # round in the pipeline) must yield identical ids; uniqueness holds
        # within a round because a job bids each window at most once.
        vid = (f"{self.spec.job_id}/{window.slice_id}"
               f"@{window.t_min:.9g}#{seq}")
        return Variant(
            job_id=self.spec.job_id,
            slice_id=window.slice_id,
            t_start=t_start,
            duration=plan.duration,
            fmp=self.spec.fmp,
            local_utility=float(np.clip(h, 0.0, 1.0)),
            declared_features=declared,
            payload={
                "work": plan.work,
                "activation": self.atomizer.activation_cost,
                "true_features": feats,  # ground truth (≠ declared if misreporting)
            },
            variant_id=vid,
            # the risk bound this bid was generated under rides along so the
            # in-dispatch safety recheck can verify per-agent θ
            # (PackedRound.thetas); strategies may tighten the agent's own θ
            theta=self.cfg.theta if theta is None else theta,
        )

    # kept as an alias: pre-negotiation code and the frozen reference tests
    # call the historical underscore name
    _make_variant = make_variant

    # -- truthful feature values (what an honest job declares) ----------------
    def _features(self, work: float, duration: float, t_start: float, now: float) -> Dict[str, float]:
        """Honest φ values, spread over [0,1] so they discriminate.

        φ_JCT uses the chunk's *efficiency*: ideal compute time over committed
        span including queueing delay (chunks starting soon and running dense
        score high).  φ_QoS is the deadline-feasibility indicator.  φ_progress
        is the fraction of remaining work the chunk covers.
        """
        finish = t_start + duration
        wait = max(0.0, t_start - now)
        phi_jct = float(np.clip(duration / max(duration + wait, 1e-9), 0.0, 1.0))
        if self.spec.qos_deadline is None:
            phi_qos = 1.0
        else:
            rem_after = self.work_remaining - work
            est_completion = finish + rem_after  # thr≈1 chip ⇒ seconds ≈ work
            phi_qos = JobFeatures.qos(est_completion <= self.spec.qos_deadline)
        phi_prog = JobFeatures.progress(work, self.work_remaining)
        return {"jct": phi_jct, "qos": phi_qos, "progress": phi_prog}
