"""``FairShare``: age/Jain-weighted clearing (temporal-fairness backend).

The paper's §4.3 age term already *scores* starved jobs higher; FairShare
additionally makes the CLEARING step fairness-aware, which matters when the
score gap is larger than β_age can close or when one job's bids dominate a
round:

* **age boost** — selection runs on ``s·(1 + age_weight·A_i(t))``: a starved
  job's bids out-rank slightly better-scored bids from recently-served jobs,
  in WIS selection and in conflict keep-priority alike.
* **win spreading** — after a first clearing pass, each job's k-th-best win
  (0-indexed) is discounted by ``1 + spread·k``, and bids beyond a job's
  win set carry the job's full-count discount; the round is then re-cleared
  once.  A job's BEST seat keeps its score, but marginal seats shrink and
  yield to jobs holding none, pushing the per-round win distribution toward
  a higher Jain index (diminishing-returns/proportional-fairness flavour).

Reported scores and totals stay the RAW auction values — the transform only
steers selection — so cleared totals remain comparable across backends.
Deterministic: two passes, no iteration to convergence.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from ..types import PoolView, RoundResult, Variant, Window
from ..wis import wis_select
from .base import ClearingPolicy, fixed_point_settle

__all__ = ["FairShare"]


@dataclass(frozen=True)
class FairShare(ClearingPolicy):
    """Age-boosted, win-spreading clearing.

    ``age_weight`` ≥ 0 scales the multiplicative age boost (0 disables);
    ``spread`` ≥ 0 scales the second-pass multi-win discount (0 disables,
    making FairShare a single age-weighted pass).
    """

    name = "fair_share"
    age_weight: float = 0.5
    spread: float = 0.25
    # the age-boost pass SELECTS on scores transformed by a host-known
    # per-bid multiplier, which the fused first pass applies in-dispatch
    # (prefetch_transform below) — so FairShare can consume the fused
    # score→clear path like the raw-score backends
    supports_prefetch = True

    def __post_init__(self):
        if self.age_weight < 0 or self.spread < 0:
            raise ValueError("age_weight and spread must be non-negative")

    def prefetch_transform(self, view, ages):
        """The age-boost multiplier ``1 + age_weight·A_i(t)``, float32.

        Quantized to float32 because the fused dispatch multiplies it with
        the float32 device scores; :meth:`settle` builds its selection
        scores from the SAME float32 product so the fused and host first
        passes agree bit-for-bit.
        """
        ages = ages or {}
        age = np.asarray(
            [float(np.clip(ages.get(j, 0.0), 0.0, 1.0)) for j in view.job_ids],
            np.float64,
        )
        return (1.0 + self.age_weight * age).astype(np.float32)

    def settle(
        self,
        windows: Sequence[Window],
        fit: Sequence[Variant],
        win_idx: Sequence[int],
        scores: np.ndarray,
        *,
        selector: Callable = wis_select,
        work_budget: Optional[Mapping[str, float]] = None,
        view: Optional[PoolView] = None,
        ages: Optional[Mapping[str, float]] = None,
        prefetch=None,
    ) -> RoundResult:
        common = dict(selector=selector, work_budget=work_budget, view=view)
        if not fit:
            return fixed_point_settle(windows, fit, win_idx, scores, **common)
        if view is None:
            view = PoolView.build(fit)
            common["view"] = view
        # float32 score×transform product, upcast: exactly the weights the
        # fused device dispatch gathers (see prefetch_transform), so the
        # prefetched first pass and a host sweep select identically
        transform = self.prefetch_transform(view, ages)
        eff = (np.asarray(scores, np.float32) * transform).astype(np.float64)
        first = fixed_point_settle(
            windows, fit, win_idx, scores, select_scores=eff,
            prefetch=prefetch, **common
        )
        if self.spread <= 0 or not first.selected:
            return first
        # positional discounts: the job's best win keeps its score, win k is
        # divided by 1 + spread·k, and its remaining bids (would-be extra
        # wins) carry the full-count discount
        pos = {id(v): i for i, v in enumerate(fit)}
        wins_by_job: dict = {}
        for v in first.selected:
            wins_by_job.setdefault(v.job_id, []).append(pos[id(v)])
        n_wins = Counter(v.job_id for v in first.selected)
        discount = np.asarray(
            [1.0 + self.spread * n_wins.get(j, 0) for j in view.job_ids],
            np.float64,
        )
        for job, win_idxs in wins_by_job.items():
            for k, i in enumerate(sorted(win_idxs, key=lambda i: -eff[i])):
                discount[i] = 1.0 + self.spread * k
        return fixed_point_settle(
            windows, fit, win_idx, scores, select_scores=eff / discount,
            **common,
        )
