"""Policy-driven clearing: pluggable backends + the unified ``Policy`` API.

Public surface:

* :class:`ClearingPolicy` — the backend protocol (owns per-window selection,
  cross-window conflict resolution, tie-breaking).
* :class:`GreedyWIS` / :class:`GlobalAssignment` / :class:`FairShare` — the
  three shipped backends (see their module docstrings).
* :class:`Policy` — one frozen, validated configuration composing scoring /
  window / age / calibration knobs, the clearing backend and the θ-recheck
  mode, with :meth:`Policy.utilization` / :meth:`Policy.fairness` /
  :meth:`Policy.responsive` presets.
* :func:`fixed_point_settle` — the shared WIS + conflict-resolution core
  custom backends can build on.

Quickstart::

    from repro.core import JasdaScheduler, SliceSpec
    from repro.core.policy import Policy

    sched = JasdaScheduler(slices, Policy.utilization())
    sched.run_round(now)
"""
from .base import ClearingPolicy, fixed_point_settle  # noqa: F401
from .greedy import GreedyWIS  # noqa: F401
from .assignment import GlobalAssignment  # noqa: F401
from .fairshare import FairShare  # noqa: F401
from .presets import Policy  # noqa: F401

__all__ = [
    "ClearingPolicy",
    "fixed_point_settle",
    "GreedyWIS",
    "GlobalAssignment",
    "FairShare",
    "Policy",
]
