"""``GlobalAssignment``: min-cost matching over conflicting cross-window wins.

Greedy conflict resolution (``GreedyWIS``) keeps each conflicting job's
best-scored win and revokes the rest.  That is locally optimal for the job
but can be globally wasteful: if J0's best win sits in a window where a
near-equal substitute bid exists, while its revoked win sat in a window
nobody else can fill, the greedy pass throws away the substitute's score.
ROADMAP open item: "a global assignment (min-cost matching over conflicting
wins) could recover more utility" — this backend is that recovery.

Mechanism: the first per-window WIS pass exposes each job's *conflict
clusters* (connected components of its mutually-overlapping cross-window
wins).  Each cluster is a one-of-N choice: which window does the job keep?
The backend searches assignments of conflicted jobs to windows:

* exhaustively when the joint choice space is small (≤ ``max_configs``);
* otherwise seeded by a Hungarian assignment
  (``scipy.optimize.linear_sum_assignment`` on the job × window win-score
  profit matrix) and refined by bounded coordinate descent.

Every candidate assignment is evaluated by replaying the shared fixed-point
settle with the job's kept win pinned (``prefer``), so displaced windows
re-clear to their best substitutes and work budgets stay enforced.  The
greedy configuration is always evaluated first, therefore the cleared total
is **never lower than greedy's** (asserted by tests and the
``policy_clearing`` benchmark gate).

Replay cost is attacked on three axes (the ``policy_clearing`` benchmark's
``overhead=`` gate tracks the ratio vs. plain greedy):

* the ban-free FIRST pass is prefer-independent, so it is computed once and
  seeded into every replay (``first_pass``) instead of re-running the full
  per-window WIS sweep per candidate configuration;
* with a batched :class:`~repro.core.wis.RoundSelector` the replays share
  one set of retained packed buffers (``packed``) — no per-config re-pack;
* the independent replays of the exhaustive search run in LOCKSTEP: each
  generation gathers every live configuration's dirty windows into ONE
  batched dispatch (one dispatch per config batch, not per window per
  config).  The coordinate-descent refinement stays serial — its trials
  feed on the best-so-far assignment, so they are not independent.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..types import PoolView, RoundResult, Variant, Window
from ..wis import RoundSelector, wis_select
from .base import (ClearingPolicy, _FixedPointState, _pool_members,
                   fixed_point_settle)

__all__ = ["GlobalAssignment"]


@dataclass(frozen=True)
class GlobalAssignment(ClearingPolicy):
    """Assignment-search clearing: never clears less than ``GreedyWIS``.

    ``max_configs`` caps exhaustive enumeration of the joint cluster-choice
    space; above it the Hungarian seed + ``descent_passes`` rounds of
    coordinate descent bound the number of fixed-point evaluations
    (``max_evals`` is the hard stop).
    """

    name = "global_assignment"
    max_configs: int = 64
    descent_passes: int = 2
    max_evals: int = 200
    # selection runs on the raw auction scores (fused first pass usable)
    supports_prefetch = True

    def settle(
        self,
        windows: Sequence[Window],
        fit: Sequence[Variant],
        win_idx: Sequence[int],
        scores: np.ndarray,
        *,
        selector: Callable = wis_select,
        work_budget: Optional[Mapping[str, float]] = None,
        view: Optional[PoolView] = None,
        ages: Optional[Mapping[str, float]] = None,
        prefetch=None,
    ) -> RoundResult:
        if view is None:
            view = PoolView.build(fit)
        windows = list(windows)
        rs = selector if isinstance(selector, RoundSelector) else None
        # shared replay state: the ban-free first pass is prefer-independent
        # and the packed buffers are score-independent, so every candidate
        # configuration replays from the same pair
        packed = None
        seed_pass: Optional[List[List[int]]] = None
        if prefetch is not None and fit:
            seed_pass, packed = prefetch.materialize(scores)
        elif rs is not None and fit:
            packed = rs.pack(_pool_members(len(windows), win_idx), view, scores)
        common = dict(selector=selector, work_budget=work_budget, view=view,
                      packed=packed)
        first_pass: List[List[int]] = []
        best = fixed_point_settle(windows, fit, win_idx, scores,
                                  first_pass_sink=first_pass,
                                  first_pass=seed_pass, **common)
        if best.n_conflicts == 0:
            return best  # greedy resolved nothing -> nothing to reassign

        clusters = self._conflict_clusters(first_pass, fit, win_idx)
        if not clusters:
            return best  # conflicts were budget-only: greedy order stands

        evals = 0
        members = packed.members if packed is not None else _pool_members(
            len(windows), win_idx)
        # replays compare on cheap state TOTALS (identical float-sum order
        # to packaged totals); only the winning state is packaged at the end
        best_total = best.total_score
        best_state: Optional[_FixedPointState] = None

        def to_prefer(choice: Sequence[Optional[int]]) -> Dict[str, tuple]:
            """Per-cluster choices → job_id → tuple of pinned pool indices."""
            prefer: Dict[str, tuple] = {}
            for (job, _), i in zip(clusters, choice):
                if i is not None:
                    prefer[job] = prefer.get(job, ()) + (i,)
            return prefer

        def run_state(choice) -> _FixedPointState:
            st = _FixedPointState(windows, fit, win_idx, scores, view,
                                  members, selector, packed, work_budget,
                                  to_prefer(choice))
            st.seed(first_pass)
            return st.run_to_fixed_point()

        def evaluate(choice: Sequence[Optional[int]]) -> bool:
            """Replay the fixed point under this assignment; keep if better.

            Returns False once the evaluation budget is spent.
            """
            nonlocal evals, best_total, best_state
            if evals >= self.max_evals:
                return False
            evals += 1
            st = run_state(choice)
            # strict improvement + deterministic first-seen tie-break
            total = st.total(scores)
            if total > best_total + 1e-12:
                best_total = total
                best_state = st
            return True

        def finish() -> RoundResult:
            return best_state.package(scores) if best_state is not None else best

        n_joint = 1
        for _, wins in clusters:
            n_joint *= len(wins)
            if n_joint > self.max_configs:
                break
        if n_joint <= self.max_configs:
            combos = list(itertools.product(*(wins for _, wins in clusters)))
            combos = combos[: max(0, self.max_evals - evals)]
            if rs is not None and len(combos) > 1:
                return self._lockstep_replays(
                    combos, to_prefer, best, windows, fit, win_idx, scores,
                    view, packed, first_pass, rs, work_budget)
            for combo in combos:
                if not evaluate(combo):
                    break  # evaluation budget spent
            return finish()

        # large joint space: Hungarian seed, then bounded coordinate descent
        current = self._hungarian_seed(clusters, scores, win_idx)
        evaluate(current)
        descent_mark = best_total
        for _ in range(self.descent_passes):
            improved = False
            for c, (_, wins) in enumerate(clusters):
                for i in wins:
                    if current[c] == i:
                        continue
                    trial = list(current)
                    trial[c] = i
                    if not evaluate(trial):
                        return finish()
                    if best_total > descent_mark + 1e-12:
                        descent_mark = best_total
                        current = trial
                        improved = True
            if not improved:
                break
        return finish()

    # -- lockstep config-batch replays (batched selector only) ----------------
    def _lockstep_replays(self, combos, to_prefer, best, windows, fit,
                          win_idx, scores, view, packed, first_pass, rs,
                          work_budget) -> RoundResult:
        """Run the exhaustive candidate configurations in lockstep.

        Every configuration's fixed point is independent, so each
        GENERATION gathers all live configurations' dirty windows into one
        batched dispatch (rows share the packed buffers; bans differ per
        configuration).  Results are byte-identical to the serial loop —
        states never interact — and the winner is chosen in enumeration
        order with the same strict-improvement tie-break.
        """
        members = packed.members
        states = []
        for combo in combos:
            st = _FixedPointState(windows, fit, win_idx, scores, view,
                                  members, rs, packed, work_budget,
                                  to_prefer(combo))
            st.seed(first_pass)
            st.resolve()
            states.append(st)
        while True:
            requests = []
            owners = []
            for st in states:
                if not st.active:
                    continue
                for k in st.take_dirty():
                    requests.append((k, st.banned))
                    owners.append((st, k))
            if not requests:
                break
            for (st, k), sel in zip(owners, rs.select_rows(packed, requests)):
                st.selected[k] = sel
            for st in states:
                if st.active:
                    st.resolve()
        best_total = best.total_score
        best_state = None
        for st in states:  # enumeration order: first-seen tie-break
            total = st.total(scores)
            if total > best_total + 1e-12:
                best_total = total
                best_state = st
        return best_state.package(scores) if best_state is not None else best

    # -- conflict structure ---------------------------------------------------
    @staticmethod
    def _conflict_clusters(
        first_pass: Sequence[Sequence[int]],
        fit: Sequence[Variant],
        win_idx: Sequence[int],
    ) -> List[Tuple[str, List[int]]]:
        """Per-job connected components of cross-window overlapping wins.

        ``first_pass`` is the ban-free per-window WIS selection captured by
        the baseline ``fixed_point_settle`` call (``first_pass_sink``) — the
        same wins the greedy pass starts revoking from, at no extra WIS
        cost.  Components of size ≥ 2 are the one-of-N choices the
        assignment search ranges over; budget conflicts are left to the
        fixed-point core.
        """
        from ..clearing import _overlap

        wins_by_job: Dict[str, List[int]] = {}
        for sel in first_pass:
            for i in sel:
                wins_by_job.setdefault(fit[i].job_id, []).append(i)

        clusters: List[Tuple[str, List[int]]] = []
        for job in sorted(wins_by_job):
            wins = sorted(wins_by_job[job])
            if len(wins) < 2:
                continue
            # union-find over the overlap graph (cross-window edges only)
            parent = {i: i for i in wins}

            def find(x):
                while parent[x] != x:
                    parent[x] = parent[parent[x]]
                    x = parent[x]
                return x

            for a, b in itertools.combinations(wins, 2):
                if win_idx[a] != win_idx[b] and _overlap(fit[a], fit[b]):
                    parent[find(a)] = find(b)
            comps: Dict[int, List[int]] = {}
            for i in wins:
                comps.setdefault(find(i), []).append(i)
            for comp in comps.values():
                if len(comp) >= 2:
                    clusters.append((job, sorted(comp)))
        return clusters

    @staticmethod
    def _hungarian_seed(
        clusters: Sequence[Tuple[str, List[int]]],
        scores: np.ndarray,
        win_idx: Sequence[int],
    ) -> List[int]:
        """Per-cluster choices from a cluster↔window matching (search seed).

        ``scipy.optimize.linear_sum_assignment`` on the (cluster × window)
        profit matrix — profit = the cluster's best win-score in that
        window — yields one globally consistent keep-assignment.  It
        approximates the true objective (it ignores substitute recovery in
        displaced windows; coordinate descent refines that), and clusters
        the matching leaves unassigned fall back to their greedy choice
        (best score first, the same order the fixed point would use).
        """
        fallback = [
            max(wins, key=lambda i: (scores[i], -i)) for _, wins in clusters
        ]
        try:
            from scipy.optimize import linear_sum_assignment
        except Exception:  # pragma: no cover - scipy is a baked-in dep
            return fallback
        wset = sorted({int(win_idx[i]) for _, wins in clusters for i in wins})
        if not clusters or not wset:
            return fallback
        wpos = {w: c for c, w in enumerate(wset)}
        profit = np.full((len(clusters), len(wset)), -1e9)
        best_win: Dict[Tuple[int, int], int] = {}
        for r, (_, wins) in enumerate(clusters):
            for i in wins:
                c = wpos[int(win_idx[i])]
                if scores[i] > profit[r, c]:
                    profit[r, c] = scores[i]
                    best_win[(r, c)] = i
        rows, cols = linear_sum_assignment(profit, maximize=True)
        seed = list(fallback)
        for r, c in zip(rows, cols):
            if profit[r, c] > -1e8:
                seed[r] = best_win[(r, c)]
        return seed
