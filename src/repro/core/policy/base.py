"""The ``ClearingPolicy`` protocol: the round-clearing objective as an API.

The paper's scheduler performs *policy-driven clearing that balances
utilization, fairness, and temporal responsiveness*; fragmentation-aware
MIG schedulers (arXiv:2512.16099, arXiv:2511.18906) show that the CHOICE of
clearing objective is exactly where those trade-offs are won.  This module
makes the objective a first-class, swappable backend instead of a strategy
baked into free functions:

* a :class:`ClearingPolicy` owns the post-scores half of an auction round —
  per-window selection, cross-window conflict resolution, and tie-breaking
  (Algorithm 1 line 12 + step 12b);
* :func:`fixed_point_settle` is the shared machinery every shipped backend
  builds on: optimal WIS per window plus an iterated conflict-resolution
  loop, parameterized by (a) the scores used for SELECTION (which may be a
  fairness-transformed copy of the reported auction scores) and (b) a
  per-job keep-preference used when revoking conflicting wins (which is how
  a global assignment overrides the greedy keep-best rule).

Shipped backends (one module each):

* :class:`~repro.core.policy.greedy.GreedyWIS` — the default; byte-identical
  to the PR-1/PR-2 semantics (keep best-scored win, re-clear to fixed point).
* :class:`~repro.core.policy.assignment.GlobalAssignment` — searches
  assignments of conflicting jobs to windows (Hungarian seed + exhaustive /
  coordinate-descent refinement) and never clears less total score than
  greedy.
* :class:`~repro.core.policy.fairshare.FairShare` — age/Jain-weighted
  selection: starved jobs are boosted and multi-win jobs discounted so wins
  spread across jobs.

State mutation (commit, ages, calibration) stays the scheduler's job; a
backend is pure given its inputs, which is what lets the round pipeline
replay speculative rounds under ANY policy.
"""
from __future__ import annotations

import abc
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..types import PoolView, RoundResult, Variant, Window
from ..wis import wis_select

__all__ = ["ClearingPolicy", "fixed_point_settle"]


class ClearingPolicy(abc.ABC):
    """Owns one auction round's clearing objective (selection + conflicts).

    Implementations must be frozen dataclasses (hashable, comparable) so a
    :class:`~repro.core.policy.presets.Policy` embedding one stays a value
    object.  ``settle`` must be pure given its inputs — the round pipeline
    relies on replayability.
    """

    #: short stable identifier used in logs / benchmark rows
    name: str = "abstract"

    @abc.abstractmethod
    def settle(
        self,
        windows: Sequence[Window],
        fit: Sequence[Variant],
        win_idx: Sequence[int],
        scores: np.ndarray,
        *,
        selector: Callable = wis_select,
        work_budget: Optional[Mapping[str, float]] = None,
        view: Optional[PoolView] = None,
        ages: Optional[Mapping[str, float]] = None,
    ) -> RoundResult:
        """Clear the scored pool: WIS per window + cross-window resolution.

        ``scores`` are the auction scores reported in the result (commit
        scores, totals); a backend may SELECT on a transformed copy but must
        report the raw values so totals stay comparable across backends.
        ``ages`` maps job_id → A_i(t) ∈ [0,1] for fairness-aware backends.
        """

    def clear_round(
        self,
        windows: Sequence[Window],
        variants: Sequence[Variant],
        scoring,
        **kw,
    ) -> RoundResult:
        """Full-round convenience: score the pool, then settle through self.

        Accepts the same keyword arguments as :func:`repro.core.clearing.
        clear_round` (ages, calibrate, score_impl, recheck_theta,
        per_agent_theta, work_budget, ...).
        """
        from ..clearing import clear_round as _clear_round

        return _clear_round(windows, variants, scoring, clearing=self, **kw)


def _empty_round(windows: Sequence[Window]) -> RoundResult:
    from ..clearing import _empty_round as _impl

    return _impl(windows)


def fixed_point_settle(
    windows: Sequence[Window],
    fit: Sequence[Variant],
    win_idx: Sequence[int],
    scores: np.ndarray,
    *,
    selector: Callable = wis_select,
    work_budget: Optional[Mapping[str, float]] = None,
    view: Optional[PoolView] = None,
    select_scores: Optional[np.ndarray] = None,
    prefer: Optional[Mapping[str, int]] = None,
    first_pass_sink: Optional[List[List[int]]] = None,
) -> RoundResult:
    """WIS per window + iterated cross-window conflict resolution.

    The shared clearing core (Algorithm 1 line 12 and step 12b): each window
    is cleared optimally over its unbanned candidates, then per-job win
    lists across windows are scanned for conflicts — a job holding
    overlapping intervals on two slices, or (with ``work_budget``) more
    total work than it has — and conflicting wins are revoked.  Windows that
    lose a winner are re-cleared within the round; bans grow monotonically,
    so the loop reaches a fixed point in ≤ |pool| passes.

    Hooks the backends compose:

    * ``select_scores`` — scores used for SELECTION (WIS weights and the
      keep-priority order in conflict resolution).  Defaults to ``scores``;
      :class:`FairShare` passes an age-boosted transform here while the
      reported ``scores`` stay the raw auction values.
    * ``prefer`` — maps job_id → pool index (or tuple of indices, one per
      disjoint conflict cluster) to keep FIRST when that job's wins
      conflict, overriding the greedy best-score-first rule.  This is the
      primitive :class:`GlobalAssignment` drives its search with; with
      ``prefer=None`` the keep order is exactly the PR-2 greedy semantics
      (byte-identical, pinned by tests).
    * ``first_pass_sink`` — when given a list, it receives the ban-free
      first-pass selections (one list of pool indices per window) before
      conflict resolution starts, so callers that need the pre-resolution
      win structure (conflict-cluster discovery) don't re-run the
      per-window WIS sweep.
    """
    windows = list(windows)
    if not fit:
        return _empty_round(windows)
    if view is None:
        view = PoolView.build(fit)
    sel_scores = scores if select_scores is None else np.asarray(select_scores)

    from ..clearing import _overlap

    members: List[List[int]] = [[] for _ in windows]  # window -> pool indices
    for i, k in enumerate(win_idx):
        members[k].append(i)

    banned = np.zeros(len(fit), dtype=bool)
    selected_per_window: List[List[int]] = [[] for _ in windows]
    dirty = list(range(len(windows)))
    n_conflicts = 0

    def _reclear(k: int) -> None:
        idx = [i for i in members[k] if not banned[i]]
        if not idx:
            selected_per_window[k] = []
            return
        ia = np.asarray(idx, np.intp)
        sel, _ = selector(view.t_start[ia], view.t_end[ia], sel_scores[ia])
        selected_per_window[k] = [idx[int(j)] for j in np.asarray(sel)]

    # fixed point: each pass bans ≥ 1 variant or terminates, so the loop is
    # bounded by the pool size
    first_pass = True
    while True:
        for k in dirty:
            _reclear(k)
        dirty = []
        if first_pass:
            first_pass = False
            if first_pass_sink is not None:
                first_pass_sink.extend(list(s) for s in selected_per_window)

        # per-job win lists across all windows, best score first (preferred
        # win first when the backend pinned one)
        wins_by_job: Dict[str, List[int]] = {}
        for k, sel in enumerate(selected_per_window):
            for i in sel:
                wins_by_job.setdefault(fit[i].job_id, []).append(i)
        newly_banned = False
        for job_id, wins in wins_by_job.items():
            if len(wins) < 2 and work_budget is None:
                continue
            pin = prefer.get(job_id) if prefer is not None else None
            pins = (() if pin is None
                    else (int(pin),) if isinstance(pin, (int, np.integer))
                    else tuple(int(p) for p in pin))
            wins.sort(key=lambda i: (0 if i in pins else 1,
                                     -sel_scores[i], fit[i].t_start, win_idx[i]))
            kept: List[int] = []
            used_work = 0.0
            budget = None
            if work_budget is not None:
                budget = work_budget.get(job_id)
            for i in wins:
                drop = any(_overlap(fit[i], fit[j]) and win_idx[i] != win_idx[j]
                           for j in kept)
                if not drop and budget is not None:
                    work = float(fit[i].payload["work"]) if fit[i].payload else 0.0
                    if used_work + work > budget + 1e-9:
                        drop = True
                    else:
                        used_work += work
                if drop:
                    banned[i] = True
                    newly_banned = True
                    n_conflicts += 1
                    if win_idx[i] not in dirty:
                        dirty.append(win_idx[i])
                else:
                    kept.append(i)
        if not newly_banned:
            break

    # -- package per-window results + the flattened commit set ----------------
    from ..types import ClearingResult

    results: List[ClearingResult] = []
    all_selected: List[Variant] = []
    all_scores: List[float] = []
    for k, w in enumerate(windows):
        sel = sorted(selected_per_window[k], key=lambda i: fit[i].t_start)
        sel_set = set(sel)
        rejected = tuple(fit[i] for i in members[k] if i not in sel_set)
        results.append(
            ClearingResult(
                window=w,
                selected=tuple(fit[i] for i in sel),
                scores=tuple(float(scores[i]) for i in sel),
                total_score=float(sum(scores[i] for i in sel)),
                n_bids=len(members[k]),
                rejected=rejected,
            )
        )
        all_selected.extend(fit[i] for i in sel)
        all_scores.extend(float(scores[i]) for i in sel)
    return RoundResult(
        windows=tuple(windows),
        results=tuple(results),
        selected=tuple(all_selected),
        scores=tuple(all_scores),
        total_score=float(sum(all_scores)),
        n_bids=len(fit),
        n_conflicts=n_conflicts,
    )
