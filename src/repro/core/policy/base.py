"""The ``ClearingPolicy`` protocol: the round-clearing objective as an API.

The paper's scheduler performs *policy-driven clearing that balances
utilization, fairness, and temporal responsiveness*; fragmentation-aware
MIG schedulers (arXiv:2512.16099, arXiv:2511.18906) show that the CHOICE of
clearing objective is exactly where those trade-offs are won.  This module
makes the objective a first-class, swappable backend instead of a strategy
baked into free functions:

* a :class:`ClearingPolicy` owns the post-scores half of an auction round —
  per-window selection, cross-window conflict resolution, and tie-breaking
  (Algorithm 1 line 12 + step 12b);
* :func:`fixed_point_settle` is the shared machinery every shipped backend
  builds on: optimal WIS per window plus an iterated conflict-resolution
  loop, parameterized by (a) the scores used for SELECTION (which may be a
  fairness-transformed copy of the reported auction scores) and (b) a
  per-job keep-preference used when revoking conflicting wins (which is how
  a global assignment overrides the greedy keep-best rule).

Shipped backends (one module each):

* :class:`~repro.core.policy.greedy.GreedyWIS` — the default; byte-identical
  to the PR-1/PR-2 semantics (keep best-scored win, re-clear to fixed point).
* :class:`~repro.core.policy.assignment.GlobalAssignment` — searches
  assignments of conflicting jobs to windows (Hungarian seed + exhaustive /
  coordinate-descent refinement) and never clears less total score than
  greedy.
* :class:`~repro.core.policy.fairshare.FairShare` — age/Jain-weighted
  selection: starved jobs are boosted and multi-win jobs discounted so wins
  spread across jobs.

State mutation (commit, ages, calibration) stays the scheduler's job; a
backend is pure given its inputs, which is what lets the round pipeline
replay speculative rounds under ANY policy.
"""
from __future__ import annotations

import abc
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..types import PoolView, RoundResult, Variant, Window
from ..wis import RoundSelector, SettlePrefetch, wis_select

__all__ = ["ClearingPolicy", "fixed_point_settle"]


class ClearingPolicy(abc.ABC):
    """Owns one auction round's clearing objective (selection + conflicts).

    Implementations must be frozen dataclasses (hashable, comparable) so a
    :class:`~repro.core.policy.presets.Policy` embedding one stays a value
    object.  ``settle`` must be pure given its inputs — the round pipeline
    relies on replayability.
    """

    #: short stable identifier used in logs / benchmark rows
    name: str = "abstract"

    #: True when ``settle`` accepts the ``prefetch`` kwarg (an in-flight
    #: fused first-pass WIS from ``core.wis.RoundSelector.predispatch``).
    #: Backends that SELECT on the raw auction scores use the prefetch as
    #: dispatched; backends that transform selection scores publish the
    #: transform through :meth:`prefetch_transform` so it is applied
    #: in-dispatch and the fused first pass matches their selection weights.
    supports_prefetch: bool = False

    def prefetch_transform(self, view, ages):
        """Per-bid float32 selection-weight multiplier for the fused first
        pass, or None (identity — select on the raw scores).

        Called at PREDISPATCH time (before scores materialize), so it may
        only depend on host-known state (``view``, ``ages``).  A backend
        overriding this must select its first pass on
        ``float32(score) * float32(transform)`` — quantized exactly like
        the device gather — for the fused and unfused paths to agree.
        """
        return None

    @abc.abstractmethod
    def settle(
        self,
        windows: Sequence[Window],
        fit: Sequence[Variant],
        win_idx: Sequence[int],
        scores: np.ndarray,
        *,
        selector: Callable = wis_select,
        work_budget: Optional[Mapping[str, float]] = None,
        view: Optional[PoolView] = None,
        ages: Optional[Mapping[str, float]] = None,
    ) -> RoundResult:
        """Clear the scored pool: WIS per window + cross-window resolution.

        ``scores`` are the auction scores reported in the result (commit
        scores, totals); a backend may SELECT on a transformed copy but must
        report the raw values so totals stay comparable across backends.
        ``ages`` maps job_id → A_i(t) ∈ [0,1] for fairness-aware backends.
        """

    def clear_round(
        self,
        windows: Sequence[Window],
        variants: Sequence[Variant],
        scoring,
        **kw,
    ) -> RoundResult:
        """Full-round convenience: score the pool, then settle through self.

        Accepts the same keyword arguments as :func:`repro.core.clearing.
        clear_round` (ages, calibrate, score_impl, recheck_theta,
        per_agent_theta, work_budget, ...).
        """
        from ..clearing import clear_round as _clear_round

        return _clear_round(windows, variants, scoring, clearing=self, **kw)


def _empty_round(windows: Sequence[Window]) -> RoundResult:
    from ..clearing import _empty_round as _impl

    return _impl(windows)


def _pool_members(n_windows: int, win_idx: Sequence[int]) -> List[List[int]]:
    """window → pool indices (pool order), the layout every settle shares.

    Vectorized grouping: a stable argsort of ``win_idx`` yields the pool
    indices grouped by window with pool order preserved within each group —
    identical content to the per-element append loop, at numpy speed.
    """
    win_k = np.asarray(win_idx)
    if win_k.size == 0:
        return [[] for _ in range(n_windows)]
    order = np.argsort(win_k, kind="stable")
    counts = np.bincount(win_k, minlength=n_windows)
    splits = np.cumsum(counts)[:-1]
    return [part.tolist() for part in np.split(order, splits)]


class _FixedPointState:
    """One resumable fixed-point settle (WIS sweeps + conflict resolution).

    Extracted from the former monolithic loop so that (a) dirty windows of
    one pass re-clear in ONE batched dispatch when the selector is a
    :class:`~repro.core.wis.RoundSelector`, and (b) ``GlobalAssignment``
    can drive MANY candidate-configuration replays in lockstep, batching
    every live replay's dirty windows into a single dispatch per
    generation.  Semantics are byte-identical to the original loop (pinned
    by the frozen-reference tests): the state only re-sequences WHO calls
    the selector, never what it selects.
    """

    def __init__(self, windows, fit, win_idx, sel_scores, view, members,
                 selector, packed, work_budget, prefer):
        self.windows = windows
        self.fit = fit
        self.win_idx = win_idx
        self.sel_scores = sel_scores
        self.view = view
        self.members = members
        self.selector = selector
        self.rs = selector if isinstance(selector, RoundSelector) else None
        self.packed = packed
        self.work_budget = work_budget
        self.prefer = prefer
        self.banned = np.zeros(len(fit), dtype=bool)
        self.selected: List[List[int]] = [[] for _ in windows]
        self.dirty: List[int] = list(range(len(windows)))
        self.n_conflicts = 0
        self.active = True  # False once the fixed point is reached

    def seed(self, first_pass: Sequence[Sequence[int]]) -> None:
        """Adopt precomputed ban-free first-pass selections (skip sweep 1)."""
        self.selected = [list(s) for s in first_pass]
        self.dirty = []

    def take_dirty(self) -> List[int]:
        ks, self.dirty = self.dirty, []
        return ks

    def reclear(self, ks: Sequence[int]) -> None:
        """Re-run WIS on the given windows over their unbanned candidates."""
        if not ks:
            return
        if self.rs is not None:
            for k, sel in zip(ks, self.rs.select(self.packed, ks, self.banned)):
                self.selected[k] = sel
            return
        view, sel_scores = self.view, self.sel_scores
        for k in ks:
            idx = [i for i in self.members[k] if not self.banned[i]]
            if not idx:
                self.selected[k] = []
                continue
            ia = np.asarray(idx, np.intp)
            sel, _ = self.selector(view.t_start[ia], view.t_end[ia], sel_scores[ia])
            self.selected[k] = [idx[int(j)] for j in np.asarray(sel)]

    def resolve(self) -> bool:
        """One conflict-resolution pass; True while new bans were issued.

        Per-job win lists across all windows, best score first (preferred
        win first when the backend pinned one); drops cross-window
        overlapping wins and work-budget overruns, marking their windows
        dirty for the next re-clear sweep.  Interval/score reads go through
        the PoolView columns (same float64 values as the variant attrs, at
        array-index cost — replays hit this pass hundreds of times).
        """
        from ..types import OVERLAP_EPS

        fit, win_idx, sel_scores = self.fit, self.win_idx, self.sel_scores
        ts, te = self.view.t_start, self.view.t_end
        job_ids = self.view.job_ids
        eps = OVERLAP_EPS
        prefer, work_budget = self.prefer, self.work_budget
        wins_by_job: Dict[str, List[int]] = {}
        for k, sel in enumerate(self.selected):
            for i in sel:
                wins_by_job.setdefault(job_ids[i], []).append(i)
        newly_banned = False
        for job_id, wins in wins_by_job.items():
            if len(wins) < 2 and work_budget is None:
                continue
            pin = prefer.get(job_id) if prefer is not None else None
            pins = (() if pin is None
                    else (int(pin),) if isinstance(pin, (int, np.integer))
                    else tuple(int(p) for p in pin))
            wins.sort(key=lambda i: (0 if i in pins else 1,
                                     -sel_scores[i], ts[i], win_idx[i]))
            kept: List[int] = []
            used_work = 0.0
            budget = None
            if work_budget is not None:
                budget = work_budget.get(job_id)
            for i in wins:
                drop = any(ts[i] < te[j] - eps and ts[j] < te[i] - eps
                           and win_idx[i] != win_idx[j]
                           for j in kept)
                if not drop and budget is not None:
                    work = float(fit[i].payload["work"]) if fit[i].payload else 0.0
                    if used_work + work > budget + 1e-9:
                        drop = True
                    else:
                        used_work += work
                if drop:
                    self.banned[i] = True
                    newly_banned = True
                    self.n_conflicts += 1
                    if win_idx[i] not in self.dirty:
                        self.dirty.append(win_idx[i])
                else:
                    kept.append(i)
        self.active = newly_banned
        return newly_banned

    def run_to_fixed_point(self) -> "_FixedPointState":
        """Drive reclear/resolve until no new bans are issued; returns self."""
        while True:
            self.reclear(self.take_dirty())
            if not self.resolve():
                return self

    def total(self, scores: np.ndarray) -> float:
        """The cleared total this state would report, WITHOUT packaging.

        Float-sum order replicates :meth:`package` exactly (per-window
        ascending t_start, windows in order, one flat sum) so comparisons
        between replays keep the packaged tie-break semantics bit-for-bit.
        """
        t_start = self.view.t_start
        vals = [float(scores[i])
                for k in range(len(self.windows))
                for i in sorted(self.selected[k], key=t_start.__getitem__)]
        return float(sum(vals))

    def package(self, scores: np.ndarray) -> RoundResult:
        """Per-window results + the flattened commit set (+ pool indices)."""
        from ..types import ClearingResult

        fit, members = self.fit, self.members
        results: List[ClearingResult] = []
        all_selected: List[Variant] = []
        all_scores: List[float] = []
        selected_idx: List[tuple] = []
        for k, w in enumerate(self.windows):
            sel = sorted(self.selected[k], key=lambda i: fit[i].t_start)
            sel_set = set(sel)
            rejected = tuple(fit[i] for i in members[k] if i not in sel_set)
            results.append(
                ClearingResult(
                    window=w,
                    selected=tuple(fit[i] for i in sel),
                    scores=tuple(float(scores[i]) for i in sel),
                    total_score=float(sum(scores[i] for i in sel)),
                    n_bids=len(members[k]),
                    rejected=rejected,
                )
            )
            selected_idx.append(tuple(sel))
            all_selected.extend(fit[i] for i in sel)
            all_scores.extend(float(scores[i]) for i in sel)
        return RoundResult(
            windows=tuple(self.windows),
            results=tuple(results),
            selected=tuple(all_selected),
            scores=tuple(all_scores),
            total_score=float(sum(all_scores)),
            n_bids=len(fit),
            n_conflicts=self.n_conflicts,
            selected_idx=tuple(selected_idx),
        )


def fixed_point_settle(
    windows: Sequence[Window],
    fit: Sequence[Variant],
    win_idx: Sequence[int],
    scores: np.ndarray,
    *,
    selector: Callable = wis_select,
    work_budget: Optional[Mapping[str, float]] = None,
    view: Optional[PoolView] = None,
    select_scores: Optional[np.ndarray] = None,
    prefer: Optional[Mapping[str, int]] = None,
    first_pass_sink: Optional[List[List[int]]] = None,
    first_pass: Optional[Sequence[Sequence[int]]] = None,
    packed=None,
    prefetch: Optional[SettlePrefetch] = None,
) -> RoundResult:
    """WIS per window + iterated cross-window conflict resolution.

    The shared clearing core (Algorithm 1 line 12 and step 12b): each window
    is cleared optimally over its unbanned candidates, then per-job win
    lists across windows are scanned for conflicts — a job holding
    overlapping intervals on two slices, or (with ``work_budget``) more
    total work than it has — and conflicting wins are revoked.  Windows that
    lose a winner are re-cleared within the round; bans grow monotonically,
    so the loop reaches a fixed point in ≤ |pool| passes.

    ``selector`` is either the classic per-window callable (default
    :func:`wis_select`) or a batched :class:`~repro.core.wis.RoundSelector`
    — then every sweep (the ban-free first pass AND each conflict
    re-clear) dispatches ALL its dirty windows at once from retained packed
    buffers instead of looping windows on the host.

    Hooks the backends compose:

    * ``select_scores`` — scores used for SELECTION (WIS weights and the
      keep-priority order in conflict resolution).  Defaults to ``scores``;
      :class:`FairShare` passes an age-boosted transform here while the
      reported ``scores`` stay the raw auction values.
    * ``prefer`` — maps job_id → pool index (or tuple of indices, one per
      disjoint conflict cluster) to keep FIRST when that job's wins
      conflict, overriding the greedy best-score-first rule.  This is the
      primitive :class:`GlobalAssignment` drives its search with; with
      ``prefer=None`` the keep order is exactly the PR-2 greedy semantics
      (byte-identical, pinned by tests).
    * ``first_pass_sink`` — when given a list, it receives the ban-free
      first-pass selections (one list of pool indices per window) before
      conflict resolution starts, so callers that need the pre-resolution
      win structure (conflict-cluster discovery) don't re-run the
      per-window WIS sweep.
    * ``first_pass`` — the inverse: adopt precomputed ban-free first-pass
      selections and skip the initial sweep entirely (the first pass is
      ban-free and prefer-independent, so it is identical across
      ``GlobalAssignment``'s candidate-configuration replays).
    * ``packed`` — retained :class:`~repro.core.wis.PackedSettle` buffers
      to dispatch from (RoundSelector only); lets replays share one pack.
    * ``prefetch`` — an in-flight fused first pass dispatched against the
      round's device scores (``RoundSelector.predispatch``); honored when
      its transform state matches the settle's selection scores — an
      untransformed prefetch needs ``select_scores is None``, a transformed
      one (``prefetch.transformed``) needs the matching transformed
      ``select_scores``.
    """
    windows = list(windows)
    if not fit:
        return _empty_round(windows)
    if view is None:
        view = PoolView.build(fit)
    sel_scores = scores if select_scores is None else np.asarray(select_scores)

    members = (packed.members if packed is not None
               else _pool_members(len(windows), win_idx))
    if (prefetch is not None and first_pass is None
            and getattr(prefetch, "transformed", False)
            == (select_scores is not None)):
        from ...kernels.common import KernelDispatchError

        try:
            first_pass, packed = prefetch.materialize(sel_scores)
            members = packed.members
        except KernelDispatchError:
            # the fused first pass died in flight (device fault mid-round):
            # the prefetch is pure speculation — clear from host state as
            # if it had never been dispatched (selections are identical)
            first_pass = None
    rs = selector if isinstance(selector, RoundSelector) else None
    if rs is not None and packed is None:
        packed = rs.pack(members, view, sel_scores)

    st = _FixedPointState(windows, fit, win_idx, sel_scores, view, members,
                          selector, packed, work_budget, prefer)
    if first_pass is not None:
        st.seed(first_pass)

    # fixed point: each pass bans ≥ 1 variant or terminates, so the loop is
    # bounded by the pool size
    first_sweep = True
    while True:
        st.reclear(st.take_dirty())
        if first_sweep:
            first_sweep = False
            if first_pass_sink is not None:
                first_pass_sink.extend(list(s) for s in st.selected)
        if not st.resolve():
            break
    return st.package(scores)
