"""``GreedyWIS``: the default clearing backend (PR-1/PR-2 semantics).

Per-window optimal WIS, then greedy cross-window conflict resolution: a job
that wins overlapping intervals on two slices (or more total work than it
has) keeps only its best-scored wins; windows that lose a winner are
re-cleared within the round to a fixed point.  This is exactly the
pre-policy-API behavior — selections are byte-identical (pinned by a
property test against a frozen reference implementation), so the default
:class:`~repro.core.policy.presets.Policy` changes nothing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from ..types import PoolView, RoundResult, Variant, Window
from ..wis import wis_select
from .base import ClearingPolicy, fixed_point_settle

__all__ = ["GreedyWIS"]


@dataclass(frozen=True)
class GreedyWIS(ClearingPolicy):
    """Greedy keep-best-win clearing (the default backend, zero knobs)."""

    name = "greedy_wis"
    # selection runs on the raw auction scores, so a fused first-pass WIS
    # dispatched against the in-flight device scores is directly usable
    supports_prefetch = True

    def settle(
        self,
        windows: Sequence[Window],
        fit: Sequence[Variant],
        win_idx: Sequence[int],
        scores: np.ndarray,
        *,
        selector: Callable = wis_select,
        work_budget: Optional[Mapping[str, float]] = None,
        view: Optional[PoolView] = None,
        ages: Optional[Mapping[str, float]] = None,
        prefetch=None,
    ) -> RoundResult:
        return fixed_point_settle(
            windows, fit, win_idx, scores,
            selector=selector, work_budget=work_budget, view=view,
            prefetch=prefetch,
        )
