"""The unified ``Policy`` object: one validated, frozen configuration.

Before this API the policy surface was five loose fragments —
``ScoringPolicy`` (λ/α/β), ``WindowPolicy`` (announcement ordering),
``AgePolicy`` (starvation curve), ``CalibrationConfig`` (§4.2.1 trust) and
``SchedulerConfig.recheck_theta`` — with the clearing objective hardwired.
``Policy`` composes all of them plus the swappable
:class:`~repro.core.policy.base.ClearingPolicy` backend and the per-agent-θ
recheck mode into one coherent value object, with named presets for the
paper's three headline trade-offs:

====================  =====  ============  ==================  ==============
preset                λ      window order  clearing backend    distinguishing
====================  =====  ============  ==================  ==============
``Policy.utilization``  0.3  best_fit      GlobalAssignment    packs tight
                                                               gaps, recovers
                                                               conflict score
``Policy.fairness``     0.5  earliest      FairShare           β_age=0.5,
                                                               fast age curve,
                                                               win spreading
``Policy.responsive``   0.7  earliest      GreedyWIS           job/QoS-first
                                                               scores, lowest
                                                               clearing
                                                               latency
====================  =====  ============  ==================  ==============

``Policy()`` (the "balanced" default) is byte-identical to the pre-API
scheduler: GreedyWIS clearing, Table-2 balanced weights, recheck off.
Construct variations with :meth:`Policy.replace` or preset ``**overrides``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from ..calibration import CalibrationConfig
from ..fairness import AgePolicy
from ..scoring import ScoringPolicy
from ..windows import WindowPolicy
from .assignment import GlobalAssignment
from .base import ClearingPolicy
from .fairshare import FairShare
from .greedy import GreedyWIS

__all__ = ["Policy"]


@dataclass(frozen=True)
class Policy:
    """One coherent, validated scheduler policy (see module docstring).

    ``recheck_theta`` is the scheduler-wide in-dispatch safety-recheck
    override (None = no override); ``per_agent_theta`` re-verifies each bid
    against its OWN agent's declared ``AgentConfig.theta`` instead
    (``Variant.theta`` → ``PackedRound.thetas``).  When both are set the
    scheduler-wide override wins, matching the legacy
    ``SchedulerConfig.recheck_theta`` semantics.
    """

    name: str = "balanced"
    scoring: ScoringPolicy = ScoringPolicy()
    window: WindowPolicy = WindowPolicy()
    age: AgePolicy = AgePolicy()
    calibration: CalibrationConfig = CalibrationConfig()
    clearing: ClearingPolicy = GreedyWIS()
    recheck_theta: Optional[float] = None
    per_agent_theta: bool = False

    def __post_init__(self):
        if not isinstance(self.scoring, ScoringPolicy):
            raise TypeError(f"scoring must be a ScoringPolicy, got {type(self.scoring).__name__}")
        if not isinstance(self.window, WindowPolicy):
            raise TypeError(f"window must be a WindowPolicy, got {type(self.window).__name__}")
        if not isinstance(self.age, AgePolicy):
            raise TypeError(f"age must be an AgePolicy, got {type(self.age).__name__}")
        if not isinstance(self.calibration, CalibrationConfig):
            raise TypeError(
                f"calibration must be a CalibrationConfig, got {type(self.calibration).__name__}")
        if not isinstance(self.clearing, ClearingPolicy):
            raise TypeError(
                f"clearing must be a ClearingPolicy backend, got {type(self.clearing).__name__}")
        if self.recheck_theta is not None and not (0.0 < self.recheck_theta <= 1.0):
            raise ValueError(f"recheck_theta must be in (0, 1], got {self.recheck_theta}")

    def replace(self, **kw) -> "Policy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> str:
        """One-line summary (benchmark rows, simulator reports)."""
        return (f"{self.name}: lam={self.scoring.lam} "
                f"window={self.window.kind} clearing={self.clearing.name} "
                f"beta_age={self.scoring.beta_age} "
                f"recheck={'theta=%g' % self.recheck_theta if self.recheck_theta is not None else ('per-agent' if self.per_agent_theta else 'off')}")

    # -- named presets ---------------------------------------------------------
    @classmethod
    def utilization(cls, **overrides) -> "Policy":
        """Utilization-first: pack tight gaps, recover conflict utility.

        System-side weights dominate (λ=0.3, Table 2 "utilization-first"),
        windows are announced best-fit-first so small gaps fill before they
        expire, and the :class:`GlobalAssignment` backend reassigns
        conflicting cross-window wins instead of greedily revoking them.
        """
        kw = dict(
            name="utilization",
            scoring=ScoringPolicy(
                lam=0.3,
                betas={"utilization": 0.55, "slack": 0.25,
                       "mem_headroom": 0.1, "energy": 0.05, "age": 0.05},
            ),
            window=WindowPolicy(kind="best_fit"),
            clearing=GlobalAssignment(),
        )
        kw.update(overrides)
        return cls(**kw)

    @classmethod
    def fairness(cls, **overrides) -> "Policy":
        """Fairness-first: heavy age pressure + win-spreading clearing.

        β_age=0.5 with a fast-saturating age curve promotes starved jobs in
        SCORING; the :class:`FairShare` backend additionally boosts them in
        SELECTION and spreads per-round wins across jobs (Jain-friendly).
        """
        kw = dict(
            name="fairness",
            scoring=ScoringPolicy(
                lam=0.5,
                betas={"utilization": 0.25, "slack": 0.1,
                       "mem_headroom": 0.1, "energy": 0.05, "age": 0.5},
            ),
            age=AgePolicy(tau=30.0),
            clearing=FairShare(),
        )
        kw.update(overrides)
        return cls(**kw)

    @classmethod
    def responsive(cls, **overrides) -> "Policy":
        """Responsiveness-first: job/QoS-weighted scores, minimal latency.

        λ=0.7 (Table 2 "QoS-first") lets declared job utility dominate,
        windows are announced earliest-first to minimize announcement →
        execution latency, and the zero-knob :class:`GreedyWIS` backend
        keeps per-round clearing cost at its floor.
        """
        kw = dict(
            name="responsive",
            scoring=ScoringPolicy(lam=0.7),
            window=WindowPolicy(kind="earliest"),
            clearing=GreedyWIS(),
        )
        kw.update(overrides)
        return cls(**kw)
