"""Temporal Resource Profiles (TRP) and Functional Memory Profiles (FMP).

Paper §3.2: a TRP is "a probabilistic model of time-varying resource demand
over execution ... warm-up phases, steady-state intervals, and transient
bursts"; an FMP is a TRP specialized to device memory.  The paper (and SJA)
leave the concrete family open; we use piecewise-phase Gaussian profiles:

    RAM(t) ~ N(mu(t), sigma(t)^2)   per grid point,

with phases (warmup ramp, steady, burst) and two safety evaluators:

* ``prob_exceed_grid``  — exact under per-grid-point independence:
  ``Pr(max_t RAM > c) = 1 - prod_t Phi((c - mu_t)/sigma_t)`` (log-space).
* ``prob_exceed_union`` — distribution-free union (Bonferroni) upper bound:
  ``sum_t (1 - Phi(z_t))``; conservative, monotone, cheap.

Both are validated against Monte-Carlo ground truth in tests.  The TRP also
drives duration prediction (``predict_duration``): subjob wall time is
modelled log-normally around work/throughput.

The vectorized safety math is mirrored by ``kernels/jasda_score`` (Pallas) and
its ``ref.py`` oracle.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Sequence, Tuple

import numpy as np
from scipy.special import log_ndtr, ndtr  # Phi and log Phi, vectorized

__all__ = [
    "Phase",
    "PhaseFMP",
    "prob_exceed_grid",
    "prob_exceed_union",
    "predict_duration",
    "fmp_static",
    "fmp_from_model",
    "DEFAULT_GRID",
]

DEFAULT_GRID = 64  # time-grid resolution for safety evaluation


@dataclass(frozen=True)
class Phase:
    """One phase of a piecewise profile.

    ``frac`` is the fraction of total subjob duration this phase occupies.
    ``mu0 -> mu1`` ramps linearly across the phase (bytes). ``sigma`` is the
    per-point std (bytes).
    """

    frac: float
    mu0: float
    mu1: float
    sigma: float


@dataclass(frozen=True)
class PhaseFMP:
    """Piecewise-phase Gaussian memory profile (compact FMP descriptor)."""

    phases: Tuple[Phase, ...]

    def __post_init__(self):
        total = sum(p.frac for p in self.phases)
        if not math.isclose(total, 1.0, rel_tol=1e-6):
            raise ValueError(f"phase fractions must sum to 1, got {total}")

    # -- profile evaluation -------------------------------------------------
    def mean_std(self, t_rel: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(mu, sigma) at relative times ``t_rel`` in [0, 1]."""
        t = np.clip(np.asarray(t_rel, dtype=np.float64), 0.0, 1.0)
        mu = np.zeros_like(t)
        sigma = np.zeros_like(t)
        lo = 0.0
        for p in self.phases:
            hi = lo + p.frac
            # include right edge for the final phase
            in_phase = (t >= lo) & (t < hi) if hi < 1.0 - 1e-12 else (t >= lo)
            if p.frac > 0:
                alpha = (t - lo) / p.frac
            else:  # zero-length phase: degenerate
                alpha = np.zeros_like(t)
            mu = np.where(in_phase, p.mu0 + alpha * (p.mu1 - p.mu0), mu)
            sigma = np.where(in_phase, p.sigma, sigma)
            lo = hi
        return mu, sigma

    def grid(self, n: int = DEFAULT_GRID) -> Tuple[np.ndarray, np.ndarray]:
        """Discretize the profile onto an ``n``-point grid (cell midpoints)."""
        t = (np.arange(n) + 0.5) / n
        return self.mean_std(t)

    def peak_mean(self) -> float:
        return max(max(p.mu0, p.mu1) for p in self.phases)

    def scale(self, factor: float) -> "PhaseFMP":
        """Scale memory (e.g. for a different microbatch size)."""
        return PhaseFMP(
            tuple(
                Phase(p.frac, p.mu0 * factor, p.mu1 * factor, p.sigma * factor)
                for p in self.phases
            )
        )

    # -- sampling (simulator ground truth & MC validation) ------------------
    def sample_trajectory(
        self, rng: np.random.Generator, n: int = DEFAULT_GRID
    ) -> np.ndarray:
        mu, sigma = self.grid(n)
        return rng.normal(mu, sigma)


# ---------------------------------------------------------------------------
# Safety evaluators (paper §4.1(a): safe-by-construction)
# ---------------------------------------------------------------------------


def prob_exceed_grid(
    mu: np.ndarray, sigma: np.ndarray, capacity: float
) -> float:
    """``Pr(max_t RAM(t) > c)`` under per-grid-point independence.

    Computed in log space: ``1 - exp(sum_t log Phi((c - mu_t)/sigma_t))``.
    Deterministic points (sigma == 0) contribute 0/-inf exactly.
    """
    mu = np.asarray(mu, dtype=np.float64)
    sigma = np.asarray(sigma, dtype=np.float64)
    z = np.where(sigma > 0, (capacity - mu) / np.maximum(sigma, 1e-300), np.inf)
    # deterministic overflow: any mu > c with sigma == 0 -> certain violation
    det_violation = np.any((sigma == 0) & (mu > capacity))
    if det_violation:
        return 1.0
    log_survive = np.sum(log_ndtr(z[np.isfinite(z)]))
    return float(-np.expm1(log_survive))


def prob_exceed_union(
    mu: np.ndarray, sigma: np.ndarray, capacity: float
) -> float:
    """Union (Bonferroni) upper bound ``sum_t Pr(RAM_t > c)``, clipped to 1."""
    mu = np.asarray(mu, dtype=np.float64)
    sigma = np.asarray(sigma, dtype=np.float64)
    z = np.where(sigma > 0, (capacity - mu) / np.maximum(sigma, 1e-300), np.inf)
    tail = np.where(
        sigma > 0, 1.0 - ndtr(z), (mu > capacity).astype(np.float64)
    )
    return float(min(1.0, np.sum(tail)))


def is_safe(fmp: PhaseFMP, capacity: float, theta: float, *, n: int = DEFAULT_GRID,
            method: str = "grid") -> bool:
    """Eligibility condition (a): ``Pr(max RAM > c_k | FMP) <= theta``."""
    mu, sigma = fmp.grid(n)
    p = prob_exceed_grid(mu, sigma, capacity) if method == "grid" else \
        prob_exceed_union(mu, sigma, capacity)
    return p <= theta


# ---------------------------------------------------------------------------
# Duration prediction
# ---------------------------------------------------------------------------


def predict_duration(
    work: float,
    throughput: float,
    *,
    cv: float = 0.1,
    quantile: float = 0.9,
) -> float:
    """Predicted subjob duration Δt̃ from a log-normal runtime model.

    ``work / throughput`` is the median; the declared duration is the
    ``quantile`` of LogNormal(log median, sigma) with coefficient of
    variation ``cv`` — jobs declare a high quantile so the subjob completes
    within its committed interval w.h.p. (the temporal analogue of
    safe-by-construction).
    """
    if throughput <= 0:
        raise ValueError("throughput must be positive")
    median = work / throughput
    sigma = math.sqrt(math.log1p(cv * cv))
    # LogNormal quantile: median * exp(sigma * Phi^{-1}(q))
    from scipy.special import ndtri

    return float(median * math.exp(sigma * ndtri(quantile)))


# ---------------------------------------------------------------------------
# FMP constructors
# ---------------------------------------------------------------------------


def fmp_static(mean_bytes: float, sigma_bytes: float = 0.0) -> PhaseFMP:
    """Flat profile (constant residency), e.g. pure parameter residency."""
    return PhaseFMP((Phase(1.0, mean_bytes, mean_bytes, sigma_bytes),))


def fmp_standard(
    base: float,
    steady: float,
    burst: float = 0.0,
    *,
    warmup_frac: float = 0.1,
    burst_frac: float = 0.05,
    rel_sigma: float = 0.02,
) -> PhaseFMP:
    """Warmup-ramp / steady / burst profile (the paper's three regimes)."""
    steady_frac = 1.0 - warmup_frac - burst_frac
    if steady_frac < 0:
        raise ValueError("warmup_frac + burst_frac must be <= 1")
    phases = [
        Phase(warmup_frac, base, steady, rel_sigma * steady),
        Phase(steady_frac, steady, steady, rel_sigma * steady),
    ]
    if burst_frac > 0:
        peak = steady + burst
        phases.append(Phase(burst_frac, peak, peak, rel_sigma * peak))
    else:
        phases[-1] = Phase(
            phases[-1].frac + burst_frac, steady, steady, rel_sigma * steady
        )
    return PhaseFMP(tuple(phases))


def fmp_from_model(
    *,
    param_bytes: float,
    optimizer_bytes: float,
    activation_bytes: float,
    kv_cache_bytes: float = 0.0,
    transient_frac: float = 0.05,
    rel_sigma: float = 0.02,
) -> PhaseFMP:
    """Derive a training/serving FMP from model memory accounting.

    This is where architecture specifics (MoE optimizer state, SSM state
    caches, VLM cross-KV) enter the paper's technique: configs/ computes the
    four components per (arch, shape) and this builds the compact descriptor.
    """
    base = param_bytes + optimizer_bytes + kv_cache_bytes
    steady = base + activation_bytes
    burst = transient_frac * steady  # allocator/transient headroom spikes
    return fmp_standard(base, steady, burst, rel_sigma=rel_sigma)
