"""Core JASDA datatypes (paper §3.1–§3.3).

These are plain frozen dataclasses: the scheduler control plane is host-side
Python (as in the paper), while the numeric hot paths (scoring, safety, WIS)
have vectorized JAX / Pallas implementations operating on struct-of-array
views produced by :func:`variants_to_arrays`.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Shared float tolerances
# ---------------------------------------------------------------------------

# ONE base tolerance for time/interval comparisons; the three tolerances the
# clearing layer needs are all derived from it by fixed factors, replacing
# what used to be unrelated hardcoded literals (1e-9 / 1e-12 / 1e-6) spread
# across clearing.py and windows.py.  The derived values deliberately
# preserve the historical semantics at each site (selections are pinned
# byte-identical by tests), while making the relationships explicit:
#
#   OVERLAP_EPS (1e-3x) < TIME_EPS < DEAD_WINDOW_EPS (1e3x)
#
# i.e. the overlap predicate is STRICTER than window containment (a bid may
# sit at a window boundary, but two bids must be cleanly disjoint), and
# dead-window matching is LOOSER than both (it absorbs float drift
# accumulated across whole release/early-finish/merge chains).
TIME_EPS = 1e-9

# Window-containment slack: clearing._fits / assign_bids / Window.contains
# accept a bid protruding past an announced boundary by at most this much.
# (This is the base constant itself; named uses below derive from it.)

# Temporal-overlap strictness: clearing._overlap, types.overlaps, the WIS
# brute-force oracle and the agents' own-interval checks treat two intervals
# overlapping by less than this as disjoint.
OVERLAP_EPS = 1e-3 * TIME_EPS

# Dead-window matching tolerance: windows.DeadWindowRegistry defaults to
# this (and SchedulerConfig.dead_window_eps mirrors it).
DEAD_WINDOW_EPS = 1e3 * TIME_EPS

# ---------------------------------------------------------------------------
# Slices (the MIG analogue: a TPU mesh partition)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SliceSpec:
    """A schedulable resource slice ``s_k`` with fixed capacity ``c_k`` (A1).

    On the paper's hardware this is a MIG slice of one GPU; in our TPU
    adaptation it is a partition of a pod mesh (``n_chips`` chips, aggregate
    HBM ``capacity_bytes``).
    """

    slice_id: str
    capacity_bytes: float  # c_k
    n_chips: int = 1
    flops_per_s: float = 197e12  # bf16 peak per chip (v5e-class)
    hbm_bw: float = 819e9  # bytes/s per chip
    # relative execution speed multiplier (stragglers are < 1.0)
    speed: float = 1.0

    @property
    def total_flops(self) -> float:
        return self.n_chips * self.flops_per_s * self.speed


# ---------------------------------------------------------------------------
# Windows (paper §3.1): w* = (s_k, c_k, t_min, Δt)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Window:
    """An announced time–capacity window on a slice."""

    slice_id: str
    capacity: float  # c_k  (bytes)
    t_min: float  # earliest start
    duration: float  # Δt

    @property
    def t_end(self) -> float:
        return self.t_min + self.duration

    @property
    def key(self) -> Tuple[str, float]:
        """Stable within-round identity: (slice, start).

        Windows announced in one round are disjoint gaps per slice, so the
        pair identifies a window uniquely; round-feedback cutoff maps
        (negotiation.messages.RoundFeedback) key on it.
        """
        return (self.slice_id, self.t_min)

    def contains(self, t_start: float, dur: float, *, eps: float = TIME_EPS) -> bool:
        return (t_start >= self.t_min - eps) and (t_start + dur <= self.t_end + eps)


# ---------------------------------------------------------------------------
# Variants (paper §3.2): v_{i,k,w*} = (s_k, t_start, Δt̃_i, TRP_i)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Variant:
    """A candidate subjob proposed by a job for a specific window.

    ``declared_features`` holds the job's self-declared normalized feature
    values φ_i(v) ∈ [0,1] (paper Eq. 2 / §4.2.1) — these are what ex-post
    verification compares against observations.  ``local_utility`` is the
    aggregate h̃(v) = Σ αᵢ φᵢ(v).
    """

    job_id: str
    slice_id: str
    t_start: float
    duration: float  # Δt̃_i (predicted)
    fmp: "FMPLike"  # compact TRP descriptor (memory profile)
    local_utility: float  # h̃(v) ∈ [0,1], declared by the job
    declared_features: Mapping[str, float] = field(default_factory=dict)
    payload: Any = None  # opaque subjob spec (e.g. a step-range chunk)
    variant_id: str = ""
    # the bidding agent's declared capacity-violation risk bound θ (paper
    # §4.1 condition (a)).  Carried per variant so the in-dispatch safety
    # recheck can verify each bid against ITS OWN agent's θ
    # (PackedRound.thetas); 1.0 = unconstrained (p_exceed ≤ 1 always holds),
    # the right default for variants built outside a JobAgent.
    theta: float = 1.0

    @property
    def t_end(self) -> float:
        return self.t_start + self.duration

    @property
    def interval(self) -> Tuple[float, float]:
        """I(v) = [t_start, t_start + Δt̃]."""
        return (self.t_start, self.t_end)


# Anything exposing the FMP protocol (mean/std over a time grid).
class FMPLike:  # pragma: no cover - typing helper
    def mean_std(self, t: np.ndarray) -> Tuple[np.ndarray, np.ndarray]: ...


# ---------------------------------------------------------------------------
# Jobs
# ---------------------------------------------------------------------------


class JobState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"


@dataclass
class JobSpec:
    """Static description of a job entering the system."""

    job_id: str
    arrival_time: float
    total_work: float  # abstract work units (e.g. total step·chip-seconds)
    fmp: Any  # the job's (true or declared) memory profile model
    qos_deadline: Optional[float] = None  # QoS target completion time
    min_capacity: float = 0.0  # smallest slice capacity the job can use
    priority: float = 1.0
    # energy model: joules per unit work (used by the ψ_energy feature)
    energy_per_work: float = 1.0
    # preemption checkpoint granularity in work units: an interrupted chunk
    # keeps floor(done / granularity) × granularity of its progress (the
    # revocation ladder's preempt-with-credit rung).  0.0 — the default —
    # keeps the historical all-or-nothing semantics byte-identically: an
    # interruption torches the whole chunk.
    preempt_granularity: float = 0.0
    metadata: Mapping[str, Any] = field(default_factory=dict)


@dataclass
class JobStats:
    """Mutable per-job accounting used by fairness + calibration."""

    work_done: float = 0.0
    last_scheduled_time: Optional[float] = None
    first_scheduled_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_bids: int = 0
    n_wins: int = 0
    # calibration state (paper §4.2.1)
    hist_avg: float = 0.5  # HistAvg(J): EWMA of verified scores
    reliability: float = 1.0  # ρ_J ∈ (0, 1]
    verified_errors: list = field(default_factory=list)  # ε(v) history


# ---------------------------------------------------------------------------
# Commitments / schedule bookkeeping
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Commitment:
    """A variant committed to the schedule (paper step 5)."""

    variant: Variant
    commit_time: float
    score: float


@dataclass
class ClearingResult:
    """Output of one clearing iteration (Algorithm 1)."""

    window: Window
    selected: Sequence[Variant]
    scores: Sequence[float]
    total_score: float
    n_bids: int
    rejected: Sequence[Variant] = ()


@dataclass
class RoundResult:
    """Output of one batched auction ROUND over all announced windows.

    ``results[i]`` is the per-window clearing outcome for ``windows[i]``
    after cross-window conflict resolution; ``selected``/``scores`` are the
    flattened winners across every window (the commit set).  ``n_conflicts``
    counts wins revoked because a job won overlapping intervals on several
    slices (or more work than it had) and kept only its best-scored wins.
    """

    windows: Sequence[Window]
    results: Sequence["ClearingResult"]
    selected: Sequence[Variant]
    scores: Sequence[float]
    total_score: float
    n_bids: int
    n_bidders: int = 0
    n_conflicts: int = 0
    # per-window tuples of SELECTED pool indices (into the round's fitting
    # pool, aligned with ``results``) — lets downstream consumers (the
    # vectorized RoundFeedback assembly) classify winners from PoolView
    # columns without re-identifying variant objects.  Backends that do not
    # track pool indices may leave it empty (callers must fall back).
    selected_idx: Sequence = ()


# ---------------------------------------------------------------------------
# Struct-of-arrays view for vectorized scoring / WIS (JAX + Pallas paths)
# ---------------------------------------------------------------------------


@dataclass
class PoolView:
    """Single-walk struct-of-arrays view of a variant pool.

    The round hot path (window assignment → feature packing → per-window
    WIS) used to re-walk the python variant objects once per stage; a
    PoolView walks the pool ONCE and every stage operates on numpy columns
    (plus parallel python lists for the non-numeric fields).  ``take``
    produces an aligned sub-view without touching the variant objects.
    """

    variants: list
    t_start: np.ndarray  # (M,) float64
    duration: np.ndarray  # (M,) float64
    t_end: np.ndarray  # (M,) float64
    local_utility: np.ndarray  # (M,) float64
    thetas: np.ndarray  # (M,) float64 per-variant safety bound θ
    slice_ids: list  # per-variant slice id strings
    job_ids: list  # per-variant job id strings
    fmps: list  # per-variant FMP references
    variant_ids: list  # per-variant id strings (round-unique)

    @classmethod
    def build(cls, variants: Sequence[Variant]) -> "PoolView":
        if not variants:
            z = np.zeros(0, np.float64)
            return cls([], z, z.copy(), z.copy(), z.copy(), z.copy(),
                       [], [], [], [])
        rows = [
            (v.t_start, v.duration, v.slice_id, v.job_id, v.fmp,
             v.local_utility, v.theta, v.variant_id)
            for v in variants
        ]
        ts, dur, sids, jids, fmps, h, th, vids = zip(*rows)
        t_start = np.asarray(ts, np.float64)
        duration = np.asarray(dur, np.float64)
        return cls(
            list(variants), t_start, duration, t_start + duration,
            np.asarray(h, np.float64), np.asarray(th, np.float64),
            list(sids), list(jids), list(fmps), list(vids),
        )

    def __len__(self) -> int:
        return len(self.variants)

    def take(self, idx) -> "PoolView":
        idx = np.asarray(idx, np.intp)
        return PoolView(
            [self.variants[i] for i in idx],
            self.t_start[idx], self.duration[idx], self.t_end[idx],
            self.local_utility[idx], self.thetas[idx],
            [self.slice_ids[i] for i in idx],
            [self.job_ids[i] for i in idx],
            [self.fmps[i] for i in idx],
            [self.variant_ids[i] for i in idx],
        )


def variants_to_arrays(variants: Sequence[Variant]) -> dict:
    """Convert a variant pool to a struct-of-arrays dict for device kernels."""
    n = len(variants)
    return {
        "t_start": np.asarray([v.t_start for v in variants], dtype=np.float64),
        "t_end": np.asarray([v.t_end for v in variants], dtype=np.float64),
        "duration": np.asarray([v.duration for v in variants], dtype=np.float64),
        "local_utility": np.asarray(
            [v.local_utility for v in variants], dtype=np.float64
        ),
        "index": np.arange(n),
    }


def overlaps(a: Variant, b: Variant, *, eps: float = OVERLAP_EPS) -> bool:
    """Temporal overlap predicate on the same slice (clearing constraint i)."""
    return a.t_start < b.t_end - eps and b.t_start < a.t_end - eps
