"""Baseline schedulers for the comparison study (paper §6(a) future work).

The paper positions JASDA against schedulers that treat jobs as
"indivisible, monolithic entities".  We implement four such baselines behind
the same scheduler interface the simulator drives, so all systems run on
identical workloads, slices, and execution noise:

* ``FifoScheduler``        — strict arrival order; head-of-line blocking.
* ``BackfillScheduler``    — EASY backfill: FIFO head gets a reservation,
                             later jobs may jump ahead iff they do not delay it.
* ``BestFitScheduler``     — greedy: each free slice takes the waiting job
                             with minimal leftover capacity (bin-packing flavour).
* ``AuctionScheduler``     — Themis-flavoured monolithic auction: jobs bid
                             whole-job utilities each round, highest bid wins
                             the slice for its FULL runtime (no atomization).

All baselines schedule whole jobs as single non-preemptive blocks — the
delta to JASDA is therefore exactly (i) atomization + (ii) variant bidding +
(iii) optimal per-window clearing, which is what the study isolates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .jobs import JobAgent
from .trp import is_safe, predict_duration
from .types import ClearingResult, Commitment, RoundResult, SliceSpec, Variant, Window
from .windows import SliceTimeline

__all__ = [
    "MonolithicScheduler",
    "FifoScheduler",
    "BackfillScheduler",
    "BestFitScheduler",
    "AuctionScheduler",
]


class MonolithicScheduler:
    """Common machinery: whole-job commitments on slice timelines.

    ``policy`` optionally accepts the same unified ``repro.core.policy.
    Policy`` object JASDA takes, so comparison sweeps can hand every system
    one configuration: monolithic baselines have no variants to clear, so
    only the safety bound applies (a scheduler-wide ``recheck_theta``
    overrides ``theta``, mirroring JASDA's precedence); everything else is
    ignored by construction.
    """

    name = "monolithic"

    def __init__(self, slices: Sequence[SliceSpec], *, theta: float = 0.05,
                 policy=None):
        if policy is not None and getattr(policy, "recheck_theta", None) is not None:
            theta = policy.recheck_theta
        self.policy = policy
        self.slices: Dict[str, SliceTimeline] = {
            s.slice_id: SliceTimeline(s) for s in slices
        }
        self.agents: Dict[str, JobAgent] = {}
        self.commitments: List[Commitment] = []  # outstanding only
        # running totals (simulator metrics): commitments prune on settle
        self.n_committed_total: int = 0
        self.committed_score_total: float = 0.0
        self.retired_intervals: Dict[str, List] = {}
        self._queue: List[str] = []  # arrival order
        self.theta = theta

    # -- membership (simulator interface) -----------------------------------
    def add_job(self, agent: JobAgent, now: float) -> None:
        self.agents[agent.spec.job_id] = agent
        self._queue.append(agent.spec.job_id)

    def remove_job(self, job_id: str) -> None:
        self.agents.pop(job_id, None)
        if job_id in self._queue:
            self._queue.remove(job_id)

    def add_slice(self, spec: SliceSpec) -> None:
        self.slices[spec.slice_id] = SliceTimeline(spec)

    def drop_slice(self, slice_id: str, now: Optional[float] = None) -> List[Commitment]:
        tl = self.slices.pop(slice_id, None)
        if tl is not None:
            ivs = tl.busy()
            if now is not None:
                ivs = [(s0, min(e0, now)) for s0, e0 in ivs if s0 < now]
            self.retired_intervals.setdefault(slice_id, []).extend(ivs)
        lost = [c for c in self.commitments if c.variant.slice_id == slice_id]
        self.commitments = [c for c in self.commitments if c.variant.slice_id != slice_id]
        return lost

    def complete(self, variant: Variant, observed, *, observed_utility=None,
                 work_done=None, actual_end=None) -> float:
        # settle the commitment so a partially-done job (runtime overran its
        # committed block → tail work lost) can re-enter the waiting queue
        self.commitments = [c for c in self.commitments if c.variant is not variant]
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.record_progress(
                work_done if work_done is not None else variant.payload["work"]
            )
        if actual_end is not None and actual_end < variant.t_end - 1e-9:
            tl = self.slices.get(variant.slice_id)
            if tl is not None:
                tl.release(variant.t_start, variant.t_end)
                tl.commit(variant.t_start, actual_end)
        return 0.0

    def fail(self, variant: Variant, now: float) -> None:
        self.commitments = [c for c in self.commitments if c.variant is not variant]
        tl = self.slices.get(variant.slice_id)
        if tl is not None:
            tl.release(variant.t_start, variant.t_end)
            occupied_until = min(now, variant.t_end)
            if occupied_until > variant.t_start:
                tl.commit(variant.t_start, occupied_until)
        # monolithic: the WHOLE job restarts (nothing was checkpointed)
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.work_done = 0.0
            if variant.job_id not in self._queue:
                self._queue.append(variant.job_id)

    # -- round API (simulator interface) --------------------------------------
    def run_round(self, now: float) -> Optional[RoundResult]:
        """Drive the baseline's step() to quiescence for one scheduler tick.

        Monolithic baselines have no batched auction; a "round" is the
        legacy greedy loop (bounded like the pre-round simulator driver was)
        packaged behind the same interface JASDA's round exposes, so the
        simulator drives every scheduler uniformly.
        """
        results: List[ClearingResult] = []
        selected: List[Variant] = []
        budget = 3 * max(len(self.slices), 1)
        while budget > 0:
            budget -= 1
            res = self.step(now)
            if res is None:
                break
            results.append(res)
            selected.extend(res.selected)
        if not results:
            return None
        return RoundResult(
            windows=tuple(r.window for r in results),
            results=tuple(results),
            selected=tuple(selected),
            scores=tuple(s for r in results for s in r.scores),
            total_score=float(sum(r.total_score for r in results)),
            n_bids=sum(r.n_bids for r in results),
        )

    def utilization(self, t_from: float, t_to: float) -> Dict[str, float]:
        out = {}
        span = max(t_to - t_from, 1e-9)
        intervals: Dict[str, list] = {
            sid: list(tl.busy()) for sid, tl in self.slices.items()
        }
        for sid, ivs in self.retired_intervals.items():
            intervals.setdefault(sid, []).extend(ivs)
        for sid, ivs in intervals.items():
            busy = sum(max(0.0, min(e, t_to) - max(s, t_from)) for s, e in ivs)
            out[sid] = busy / span
        return out

    # -- helpers --------------------------------------------------------------
    def _waiting(self) -> List[JobAgent]:
        out = []
        committed = {c.variant.job_id for c in self.commitments}
        for jid in self._queue:
            a = self.agents.get(jid)
            if a is not None and not a.finished and jid not in committed:
                out.append(a)
        return out

    def _whole_job_variant(self, agent: JobAgent, sid: str, t_start: float) -> Optional[Variant]:
        tl = self.slices[sid]
        spec = tl.spec
        if not is_safe(agent.spec.fmp, spec.capacity_bytes, self.theta):
            return None
        thr = agent.throughput_on(spec.capacity_bytes, spec.n_chips)
        if thr <= 0:
            return None
        activation = 0.25  # checkpoint-restore/startup, same cost as JASDA chunks
        dur = predict_duration(agent.work_remaining, thr, quantile=0.9) + activation
        return Variant(
            job_id=agent.spec.job_id,
            slice_id=sid,
            t_start=t_start,
            duration=dur,
            fmp=agent.spec.fmp,
            local_utility=0.5,
            declared_features={},
            payload={"work": agent.work_remaining, "activation": activation},
            variant_id=f"{agent.spec.job_id}/mono",
        )

    def _commit(self, v: Variant, now: float, score: float = 0.0) -> None:
        self.slices[v.slice_id].commit(v.t_start, v.t_end)
        self.commitments.append(Commitment(variant=v, commit_time=now, score=score))
        self.n_committed_total += 1
        self.committed_score_total += float(score)
        # mirror JASDA's per-agent win accounting so cross-system win-rate
        # and cleared-score comparisons read off the same agent fields
        agent = self.agents.get(v.job_id)
        if agent is not None:
            agent.n_wins += 1
            agent.score_won += float(score)

    def _free_at(self, sid: str, now: float) -> bool:
        tl = self.slices[sid]
        gaps = tl.gaps(now, 1e-6)
        return bool(gaps)

    def _result(self, window_sid: str, now: float, selected: List[Variant]) -> ClearingResult:
        spec = self.slices[window_sid].spec
        w = Window(window_sid, spec.capacity_bytes, now, max((v.duration for v in selected), default=0.0))
        return ClearingResult(
            window=w, selected=tuple(selected),
            scores=tuple(0.0 for _ in selected),
            total_score=0.0, n_bids=len(selected),
        )


class FifoScheduler(MonolithicScheduler):
    name = "fifo"

    def step(self, now: float) -> Optional[ClearingResult]:
        waiting = self._waiting()
        if not waiting:
            return None
        head = waiting[0]
        selected: List[Variant] = []
        for sid in sorted(self.slices):
            if not self._free_at(sid, now):
                continue
            v = self._whole_job_variant(head, sid, now)
            if v is not None:
                self._commit(v, now)
                selected.append(v)
                break
        # strict FIFO: if the head cannot start, nobody else may.
        return self._result(selected[0].slice_id, now, selected) if selected else None


class BackfillScheduler(MonolithicScheduler):
    name = "easy-backfill"

    def step(self, now: float) -> Optional[ClearingResult]:
        waiting = self._waiting()
        if not waiting:
            return None
        selected: List[Variant] = []
        head = waiting[0]

        # 1) try to start the head job immediately on any free slice
        placed_head = False
        for sid in sorted(self.slices):
            if self._free_at(sid, now):
                v = self._whole_job_variant(head, sid, now)
                if v is not None:
                    self._commit(v, now)
                    selected.append(v)
                    placed_head = True
                    break

        # 2) head blocked → give it a reservation at the earliest future
        #    moment any compatible slice frees up; backfill others before it
        if not placed_head:
            shadow: Dict[str, float] = {}
            best_sid, best_t = None, float("inf")
            for sid, tl in self.slices.items():
                t_free = tl.busy_until(now)
                vprobe = self._whole_job_variant(head, sid, t_free)
                if vprobe is not None and t_free < best_t:
                    best_sid, best_t = sid, t_free
            if best_sid is not None:
                shadow[best_sid] = best_t  # head's reservation start
                for agent in waiting[1:]:
                    for sid in sorted(self.slices):
                        if not self._free_at(sid, now):
                            continue
                        v = self._whole_job_variant(agent, sid, now)
                        if v is None:
                            continue
                        # EASY rule: must not push past the reservation
                        if sid in shadow and v.t_end > shadow[sid] + 1e-9:
                            continue
                        self._commit(v, now)
                        selected.append(v)
                        break
        return self._result(selected[0].slice_id, now, selected) if selected else None


class BestFitScheduler(MonolithicScheduler):
    name = "best-fit"

    def step(self, now: float) -> Optional[ClearingResult]:
        waiting = self._waiting()
        if not waiting:
            return None
        selected: List[Variant] = []
        for sid in sorted(self.slices):
            if not self._free_at(sid, now):
                continue
            spec = self.slices[sid].spec
            # minimal leftover capacity = tightest-fitting job
            best, best_leftover = None, float("inf")
            for agent in waiting:
                if any(v.job_id == agent.spec.job_id for v in selected):
                    continue
                peak = agent.spec.fmp.peak_mean()
                if peak > spec.capacity_bytes:
                    continue
                leftover = spec.capacity_bytes - peak
                if leftover < best_leftover:
                    v = self._whole_job_variant(agent, sid, now)
                    if v is not None:
                        best, best_leftover = v, leftover
            if best is not None:
                self._commit(best, now)
                selected.append(best)
        return self._result(selected[0].slice_id, now, selected) if selected else None


class AuctionScheduler(MonolithicScheduler):
    """Whole-job sealed-bid auction per free slice (Themis-flavoured).

    Jobs bid value density = priority / predicted JCT; each free slice is
    awarded to the highest bid.  Identical to JASDA's market framing but
    WITHOUT atomization, variants, or per-window WIS packing.
    """

    name = "auction"

    def step(self, now: float) -> Optional[ClearingResult]:
        waiting = self._waiting()
        if not waiting:
            return None
        selected: List[Variant] = []
        taken: set = set()
        for sid in sorted(self.slices):
            if not self._free_at(sid, now):
                continue
            bids = []
            for agent in waiting:
                if agent.spec.job_id in taken:
                    continue
                v = self._whole_job_variant(agent, sid, now)
                if v is None:
                    continue
                # finish-time-fairness flavoured bid: short jobs with
                # deadlines bid higher
                urgency = 1.0
                if agent.spec.qos_deadline is not None:
                    slack = agent.spec.qos_deadline - (now + v.duration)
                    urgency = 2.0 if slack < 0 else 1.0 + 1.0 / (1.0 + slack)
                bids.append((agent.spec.priority * urgency / max(v.duration, 1e-9), v))
            if bids:
                bids.sort(key=lambda b: -b[0])
                v = bids[0][1]
                self._commit(v, now, score=bids[0][0])
                taken.add(v.job_id)
                selected.append(v)
        return self._result(selected[0].slice_id, now, selected) if selected else None
