"""Discrete-event cluster simulator for JASDA and baseline schedulers.

The paper defers its quantitative study; this simulator IS that study's
engine.  It drives the scheduler's interaction cycle against a synthetic
cluster in which committed subjobs execute with *stochastic* runtimes and
memory trajectories drawn from the jobs' TRUE profiles (which may differ
from the declared ones — that is how misreporting and the §4.2.1
verification loop are exercised).

Fault model (beyond-paper, per assignment):
  * slice failures  — a slice dies at a random time, killing its running
    subjob; the job loses only that chunk (atomization = cheap recovery);
    the slice optionally resurrects after ``repair_time`` (elasticity).
  * stragglers      — a slice runs at speed < 1; observed durations inflate,
    ex-post ε grows, and calibration de-prioritizes jobs mapped there —
    mitigation falls out of the paper's own trust machinery.

Scenario axes (beyond-paper): fault model, stragglers, misreporting — and
mixed-strategy POPULATIONS (``make_workload(strategies=[...])``): jobs can
run different ``negotiation.BiddingStrategy`` backends side by side, and
``SimResult.strategy_stats`` reports per-strategy bids/wins/cleared score
so strategy matchups (AdaptiveBidder vs GreedyChunking) read off one run.

Metrics: utilization, mean/95p JCT, makespan, Jain fairness on slowdown,
bid/win counts, capacity-violation rate (validates θ).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .events import EventHeap, ExecutionPlumbing
from .events import ARRIVE as _ARRIVE
from .events import COMPLETE as _COMPLETE
from .events import FAIL as _FAIL
from .events import FAULT as _FAULT
from .events import REPAIR as _REPAIR
from .events import TICK as _TICK
from .fairness import jain_index
from .faults import (DEVICE_DISPATCH_FAIL, SCHEDULER_CRASH, SLICE_DEGRADED,
                     SLICE_REVOKED, FaultInjector, FaultPlan)
from .jobs import JobAgent
from .scheduler import JasdaScheduler, SchedulerConfig
from .types import JobSpec, SliceSpec, Variant

__all__ = ["SimConfig", "SimResult", "simulate", "make_workload"]


@dataclass(frozen=True)
class SimConfig:
    t_end: float = 2000.0
    iteration_dt: float = 1.0  # scheduler wakes up every dt (A3)
    seed: int = 0
    # execution noise: actual duration = predicted_median * LogNormal(cv)
    runtime_cv: float = 0.1
    # failure injection
    failure_rate: float = 0.0  # per-slice failures per unit time
    repair_time: float = 50.0
    # capacity enforcement: sample the true memory trajectory and count
    # violations (validates the θ safety bound end-to-end)
    check_capacity: bool = True
    # double-buffer consecutive auction rounds (core/pipeline.py): the host
    # prepares tick t+dt's bids while tick t's scores are in flight on
    # device.  Selections are identical to serial rounds (tested); disable
    # to force the serial reference path.
    pipeline: bool = True
    # dynamic repartitioning (core/repartition.py): a RepartitionPolicy the
    # coordinator consults every ``repartition_every`` ticks, BEFORE the
    # round at that tick (between-rounds semantics).  None disables the
    # subsystem entirely; StaticInventory runs it but proposes nothing —
    # both are byte-identical to the pre-repartition simulator (tested).
    # Requires a pow2-consistent inventory (see ProfileLattice.infer).
    repartition: Optional[object] = None
    repartition_every: int = 1
    # preemption-aware recovery (core/repartition.py MigrationConfig): when
    # set, slice revocations and forced repartition drains walk the
    # migrate → preempt-with-credit → revoke-lossy ladder through a
    # MigrationPlanner instead of torching in-flight commitments.  None
    # disables the subsystem; a config with migration_budget=0 combined
    # with preempt_granularity=0 jobs degenerates to the lossy path
    # byte-identically (tested).
    migration: Optional[object] = None


@dataclass
class SimResult:
    utilization: float
    per_slice_utilization: Dict[str, float]
    mean_jct: float
    p95_jct: float
    makespan: float
    jain_slowdown: float
    n_finished: int
    n_jobs: int
    capacity_violations: int
    n_committed: int
    total_score: float
    jct_per_job: Dict[str, float] = field(default_factory=dict)
    reliability: Dict[str, float] = field(default_factory=dict)
    # full Calibrator.snapshot() — round-trippable via Calibrator.restore(),
    # so a follow-up run can resume the trust state this run ended with
    calibration: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # per-BiddingStrategy aggregates (mixed-strategy populations): strategy
    # name -> {n_jobs, n_finished, n_bids, n_wins, score_won}
    strategy_stats: Dict[str, Dict[str, float]] = field(default_factory=dict)
    iterations: int = 0
    # names of the policy / clearing backend that produced this run (JASDA
    # schedulers report Policy.name + ClearingPolicy.name; baselines their
    # scheduler name) so preset sweeps stay self-describing
    policy: str = ""
    clearing: str = ""
    # the scheduler that FINISHED the run: after a scheduler_crash +
    # checkpoint restore this is the restored instance, not the one the
    # caller passed in (whose state is pre-crash and stale)
    scheduler: object = field(default=None, repr=False, compare=False)
    # the RepartitionCoordinator that finished the run (None when
    # cfg.repartition is None): carries frag_trace, move counters and the
    # energy proxy for benchmarks/tests
    repartition: object = field(default=None, repr=False, compare=False)
    # disruption accounting (the revocation ladder's audit surface):
    # commitments preempted with credit / migrated across slices / lost
    # outright, total granule-aligned work credited, and the per-reason
    # loss histogram (scheduler.loss_reasons) — all zero/empty on the
    # default lossy path
    n_preempted: int = 0
    n_migrated: int = 0
    n_lost_commitments: int = 0
    work_credited: float = 0.0
    loss_reasons: Dict[str, int] = field(default_factory=dict)

    def summary(self) -> str:
        tag = ""
        if self.policy:
            tag = f" policy={self.policy}" + (
                f"/{self.clearing}" if self.clearing else "")
        return (
            f"util={self.utilization:.3f} meanJCT={self.mean_jct:.1f} "
            f"p95JCT={self.p95_jct:.1f} makespan={self.makespan:.1f} "
            f"jain={self.jain_slowdown:.3f} finished={self.n_finished}/{self.n_jobs} "
            f"violations={self.capacity_violations}" + tag
        )


# Event kinds live in core/events.py (shared with repro.service): ordered so
# completions fire before scheduler ticks at equal time and planned fault
# events fire AFTER the tick sharing their timestamp (the round at t
# observes faults injected strictly before t).


def simulate(
    scheduler: JasdaScheduler,
    agents: Sequence[JobAgent],
    cfg: SimConfig = SimConfig(),
    *,
    faults: Optional[FaultPlan] = None,
    checkpoint=None,
    checkpoint_every: int = 1,
) -> SimResult:
    """Drive the scheduler against the synthetic cluster (module docstring).

    ``faults`` (a :class:`~repro.core.faults.FaultPlan`) injects the
    deterministic fault schedule: slice revocations/degradations and
    device-dispatch failures are delivered through the event heap; agent
    silent/error windows are enforced by the scheduler's bid-collection
    gate; ``scheduler_crash`` events kill the in-memory state and restore
    the latest checkpoint (requires ``checkpoint``, a
    :class:`~repro.checkpoint.CheckpointStore`; crashes are ignored
    without one).  With ``checkpoint`` set, the FULL simulation state
    (scheduler + calibrator + agents + event heap + rng) is snapshotted
    before every ``checkpoint_every``-th tick — speculation is flushed
    first (semantics-preserving), so a snapshot never captures an
    in-flight round.  Crash-at-round-k + restore replays byte-identically
    to the uninterrupted run under the same plan (tested).
    """
    rng = np.random.default_rng(cfg.seed)
    heap = EventHeap()

    for a in agents:
        heap.push(a.spec.arrival_time, _ARRIVE, a)
    heap.push(0.0, _TICK)

    # failure schedule (Poisson per slice)
    if cfg.failure_rate > 0:
        for sid in list(scheduler.slices):
            t = rng.exponential(1.0 / cfg.failure_rate)
            while t < cfg.t_end:
                heap.push(t, _FAIL, sid)
                t += cfg.repair_time + rng.exponential(1.0 / cfg.failure_rate)

    # deterministic fault plan: slice/device/crash events ride the heap;
    # agent silent/error windows live in the gate (time-windowed, so
    # speculative bid collections replay identically — see core/faults.py)
    if faults is not None:
        injector = faults if isinstance(faults, FaultInjector) \
            else FaultInjector(faults)
        scheduler.fault_gate = injector
        for e in injector.scheduled_events():
            heap.push(e.t, _FAULT, e)

    # multi-tick round pipelining: JASDA schedulers expose the prepare/settle
    # split; baselines fall back to their serial run_round
    pipe = None
    if cfg.pipeline and hasattr(scheduler, "_prepare_round"):
        from .pipeline import RoundPipeline

        pipe = RoundPipeline(scheduler)

    # executor-side state (launch/complete plumbing shared with the service):
    # running/pending/violations live on the plumbing object so one pickle
    # graph checkpoints them together with the scheduler they share Variant
    # identities with
    ex = ExecutionPlumbing(scheduler, heap, rng,
                           runtime_cv=cfg.runtime_cv,
                           check_capacity=cfg.check_capacity)

    # dynamic repartitioning: the coordinator owns the buddy layout and
    # executes policy moves between rounds; its mutations bump the
    # scheduler epoch, so the pipeline's speculation protocol handles them
    # like any other state change (no special flush needed)
    # preemption-aware recovery: ONE planner walks the revocation ladder on
    # every forced slice death (fault path + repartition drains); None keeps
    # the historical lossy path
    planner = None
    if cfg.migration is not None:
        from .repartition import MigrationConfig, MigrationPlanner

        mig_cfg = (cfg.migration if isinstance(cfg.migration, MigrationConfig)
                   else None)
        planner = MigrationPlanner(scheduler, mig_cfg)

    coord = None
    if cfg.repartition is not None:
        from .repartition import RepartitionCoordinator

        coord = RepartitionCoordinator(scheduler, cfg.repartition,
                                       migration=planner)

    dead_slices: Dict[str, SliceSpec] = {}
    jct: Dict[str, float] = {}
    arrival: Dict[str, float] = {}
    iterations = 0
    now = 0.0

    store = checkpoint
    tick_count = 0
    # crash events already delivered this PROCESS lifetime.  Deliberately a
    # plain local that is NOT part of the checkpointed state: the restored
    # heap still contains the crash event that triggered the restore, and
    # skipping it on the re-pop is exactly what makes recovery terminate.
    consumed_crashes: Set[Tuple[float, int]] = set()

    while heap:
        # snapshot BEFORE the tick executes: restore resumes at round k with
        # the heap (including the pending tick itself) exactly as it was
        if store is not None and heap.peek()[1] == _TICK:
            if tick_count % checkpoint_every == 0:
                if pipe is not None:
                    pipe.flush()  # speculation holds device handles; flushing
                    # is semantics-preserving (pipeline equivalence contract)
                from ..kernels.common import dispatch_faults_snapshot

                store.save_state(tick_count, {
                    "scheduler": scheduler,
                    "agents": list(agents),
                    "events": heap,
                    "exec": ex,
                    "dead_slices": dead_slices,
                    "jct": jct,
                    "arrival": arrival,
                    "iterations": iterations,
                    "now": now,
                    "rng": rng,
                    "tick_count": tick_count,
                    "armed_faults": dispatch_faults_snapshot(),
                    # repartition layout + drain queue ride the same pickle
                    # graph (coordinator references the scheduler above)
                    "repartition": coord,
                    # migration ladder state (counters + config) rides the
                    # same graph, so resume across a migration boundary is
                    # byte-identical
                    "migration": planner,
                })
            tick_count += 1

        t, kind, eseq, payload = heap.pop()
        if t > cfg.t_end:
            break
        now = t

        if kind == _ARRIVE:
            agent: JobAgent = payload
            scheduler.add_job(agent, now)
            arrival[agent.spec.job_id] = now

        elif kind == _TICK:
            # "This cycle repeats continuously" (paper §3): one batched
            # auction round clears ALL open windows across all slices —
            # replacing the former 3 × n_slices sequential step() loop.
            iterations += 1
            if coord is not None and (
                    (iterations - 1) % max(1, cfg.repartition_every) == 0):
                coord.tick(now, ex)
            if pipe is not None:
                nxt = now + cfg.iteration_dt
                rr = pipe.tick(now, next_time=nxt if nxt <= cfg.t_end else None)
            else:
                rr = scheduler.run_round(now)
            if rr is not None and rr.selected:
                ex.pending.extend(rr.selected)
            # launch any committed variants whose start has arrived
            ex.launch_due(now, cfg.iteration_dt, dead_slices)
            if now + cfg.iteration_dt <= cfg.t_end:
                heap.push(now + cfg.iteration_dt, _TICK)

        elif kind == _COMPLETE:
            done = ex.complete(payload, now)
            if done is None:
                continue
            v, _dur = done
            agent = scheduler.agents.get(v.job_id)
            if agent is not None and agent.finished and v.job_id not in jct:
                jct[v.job_id] = now - arrival[v.job_id]

        elif kind == _FAIL:
            sid = payload
            if sid not in scheduler.slices:
                continue
            spec = scheduler.slices[sid].spec
            ex.fail_running(sid, now)
            lost = scheduler.drop_slice(sid, now=now)
            ex.drop_pending(sid)
            dead_slices[sid] = spec
            heap.push(now + cfg.repair_time, _REPAIR, sid)

        elif kind == _REPAIR:
            sid = payload
            spec = dead_slices.pop(sid, None)
            if spec is not None:
                scheduler.add_slice(spec)

        elif kind == _FAULT:
            e = payload
            if e.kind == SLICE_REVOKED:
                sid = e.target
                if sid not in scheduler.slices:
                    continue
                spec = scheduler.slices[sid].spec
                if planner is not None:
                    # revocation ladder: migrate → preempt-with-credit →
                    # revoke-lossy per commitment (core/repartition.py)
                    planner.evacuate(sid, now, ex)
                else:
                    ex.fail_running(sid, now)
                    # revoke (vs drop): requeues lost commitments through
                    # the atomizer, retires the slice's windows in the
                    # dead-window registry, notifies via LOSS_SLICE_FAILED
                    scheduler.revoke_slice(sid, now)
                    ex.drop_pending(sid)
                dead_slices[sid] = spec
                if e.duration > 0:
                    heap.push(now + e.duration, _REPAIR, sid)
            elif e.kind == SLICE_DEGRADED:
                if e.target in scheduler.slices:
                    scheduler.degrade_slice(e.target, e.magnitude)
            elif e.kind == DEVICE_DISPATCH_FAIL:
                from ..kernels.common import inject_dispatch_fault

                inject_dispatch_fault(e.target or "ref")
                # bump the scheduler epoch so any speculative prep rebuilds
                # and the armed fault lands at a deterministic dispatch
                scheduler.invalidate_speculation()
            elif e.kind == SCHEDULER_CRASH:
                key = (t, eseq)
                if (store is None or key in consumed_crashes
                        or store.latest_step() is None):
                    continue  # nothing to restore from: crash is a no-op
                consumed_crashes.add(key)
                from ..kernels.common import restore_dispatch_faults

                state, _ = store.restore_state()
                # rebind EVERY loop local from the snapshot; the plumbing
                # object restores with its scheduler/heap/rng references
                # intact (one pickle graph → identities preserved)
                scheduler = state["scheduler"]
                agents = state["agents"]
                heap = state["events"]
                ex = state["exec"]
                dead_slices = state["dead_slices"]
                jct = state["jct"]
                arrival = state["arrival"]
                iterations = state["iterations"]
                now = state["now"]
                rng = state["rng"]
                tick_count = state["tick_count"]
                coord = state.get("repartition")
                planner = state.get("migration")
                restore_dispatch_faults(state["armed_faults"])
                if pipe is not None:
                    pipe = RoundPipeline(scheduler)

    if pipe is not None:
        pipe.flush()  # roll back any outstanding speculative bid statistics

    # ---- metrics ------------------------------------------------------------
    # utilization over the ACTIVE span [first arrival, last completion]: long
    # idle tails after the workload drains would otherwise dilute the metric
    t_first = min(arrival.values()) if arrival else 0.0
    t_last = max(jct[j] + arrival[j] for j in jct) if jct else min(now, cfg.t_end)
    horizon = max(t_last - t_first, 1e-9)
    per_slice = scheduler.utilization(t_first, t_last)
    slowdowns = []
    for jid, a in scheduler.agents.items():
        if jid in jct:
            ideal = a.spec.total_work  # thr=1 ⇒ seconds
            slowdowns.append(jct[jid] / max(ideal, 1e-9))
    jcts = np.array(list(jct.values())) if jct else np.array([np.nan])
    calibrator = getattr(scheduler, "calibrator", None)
    cal = calibrator.snapshot() if calibrator is not None else {}
    # attribution: baselines carry a scheduler-identifying ``name`` class
    # attribute and never dispatch through a clearing backend, so they
    # report that name alone — even when handed a Policy for its θ — while
    # JASDA schedulers report the Policy + backend that actually cleared
    sched_name = getattr(scheduler, "name", "")
    policy = None if sched_name else getattr(scheduler, "policy", None)
    # per-strategy aggregates: the mixed-strategy scenario axis.  Keyed on
    # BiddingStrategy.name; one row per strategy present in the population.
    strategy_stats: Dict[str, Dict[str, float]] = {}
    for a in agents:
        name = getattr(getattr(a, "strategy", None), "name", "")
        if not name:
            continue
        row = strategy_stats.setdefault(
            name,
            {"n_jobs": 0, "n_finished": 0, "n_bids": 0, "n_wins": 0,
             "score_won": 0.0},
        )
        row["n_jobs"] += 1
        row["n_finished"] += int(a.spec.job_id in jct)
        row["n_bids"] += a.n_bids
        row["n_wins"] += a.n_wins
        row["score_won"] += float(getattr(a, "score_won", 0.0))
    return SimResult(
        policy=sched_name or getattr(policy, "name", ""),
        clearing=getattr(getattr(policy, "clearing", None), "name", ""),
        utilization=float(np.mean(list(per_slice.values()))) if per_slice else 0.0,
        per_slice_utilization=per_slice,
        mean_jct=float(np.nanmean(jcts)),
        p95_jct=float(np.nanpercentile(jcts, 95)),
        # makespan = last completion − first arrival (NOT the largest per-job
        # JCT, which under-reports whenever the longest-running job arrived
        # after the first one)
        makespan=float(t_last - t_first) if jct else float("nan"),
        jain_slowdown=jain_index(slowdowns) if slowdowns else 1.0,
        n_finished=len(jct),
        n_jobs=len(agents),
        capacity_violations=ex.violations,
        # running totals survive commitment pruning (completed/failed
        # commitments leave the outstanding list; see scheduler.commit_log)
        n_committed=getattr(scheduler, "n_committed_total",
                            len(scheduler.commitments)),
        total_score=float(getattr(scheduler, "committed_score_total",
                                  sum(c.score for c in scheduler.commitments))),
        jct_per_job=jct,
        reliability={j: s["rho"] for j, s in cal.items()},
        calibration=cal,
        strategy_stats=strategy_stats,
        iterations=iterations,
        scheduler=scheduler,
        repartition=coord,
        n_preempted=int(getattr(scheduler, "n_preempted_total", 0)),
        n_migrated=int(getattr(scheduler, "n_migrated_total", 0)),
        n_lost_commitments=int(getattr(scheduler, "n_lost_total", 0)),
        work_credited=float(getattr(scheduler, "work_credited_total", 0.0)),
        loss_reasons=dict(getattr(scheduler, "loss_reasons", {})),
    )


# ---------------------------------------------------------------------------
# Synthetic workloads
# ---------------------------------------------------------------------------


def make_workload(
    n_jobs: int,
    *,
    seed: int = 0,
    arrival_rate: float = 0.2,
    work_range: Tuple[float, float] = (20.0, 200.0),
    mem_range_gb: Tuple[float, float] = (2.0, 14.0),
    qos_fraction: float = 0.3,
    misreport_fraction: float = 0.0,
    misreport_factor: float = 1.5,
    strategies: Optional[Sequence] = None,
    min_capacity_fraction: float = 0.0,
    min_capacity_range_gb: Tuple[float, float] = (8.0, 20.0),
    preempt_granularity: float = 0.0,
) -> List[JobAgent]:
    """Poisson arrivals, log-uniform work, warmup/steady/burst FMPs.

    ``strategies`` opens the mixed-strategy scenario axis: a sequence of
    ``repro.core.negotiation.BiddingStrategy`` instances assigned round-
    robin across the jobs (job i gets ``strategies[i % len(strategies)]``),
    so populations like half-greedy/half-adaptive stay deterministic per
    seed.  None keeps every job on the default GreedyChunking.

    ``min_capacity_fraction`` opens the heterogeneous-capacity axis
    (profile-sensitive repartition scenarios): that fraction of jobs
    draws a hard ``JobSpec.min_capacity`` floor from
    ``min_capacity_range_gb`` — such jobs bid zero on any smaller slice
    (``jobs.throughput_on``), so they strand on fragmented inventories.
    The default 0.0 draws nothing from the rng, keeping workloads
    byte-identical to earlier revisions.

    ``preempt_granularity`` sets every job's checkpointable progress
    granule (``JobSpec.preempt_granularity``, in work units) for the
    revocation ladder's preempt-with-credit rung.  Assigned uniformly
    without touching the rng, so the default 0.0 — all-or-nothing — is
    byte-identical to earlier revisions.
    """
    from .jobs import AgentConfig
    from .trp import fmp_standard

    rng = np.random.default_rng(seed)
    t = 0.0
    agents = []
    gb = 1 << 30
    for i in range(n_jobs):
        t += rng.exponential(1.0 / arrival_rate)
        work = float(np.exp(rng.uniform(np.log(work_range[0]), np.log(work_range[1]))))
        steady = rng.uniform(*mem_range_gb) * gb
        fmp = fmp_standard(0.3 * steady, steady, 0.1 * steady, rel_sigma=0.03)
        deadline = None
        if rng.uniform() < qos_fraction:
            deadline = t + work * rng.uniform(2.0, 6.0)
        min_cap = 0.0
        if min_capacity_fraction > 0.0 and rng.uniform() < min_capacity_fraction:
            min_cap = rng.uniform(*min_capacity_range_gb) * gb
        spec = JobSpec(
            job_id=f"J{i:03d}",
            arrival_time=t,
            total_work=work,
            fmp=fmp,
            qos_deadline=deadline,
            min_capacity=min_cap,
            preempt_granularity=preempt_granularity,
        )
        mis = misreport_factor if rng.uniform() < misreport_fraction else 1.0
        strategy = strategies[i % len(strategies)] if strategies else None
        agents.append(JobAgent(spec, AgentConfig(misreport=mis, strategy=strategy)))
    return agents
