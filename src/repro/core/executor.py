"""JasdaExecutor: the paper's interaction cycle driving REAL training jobs.

This is the integration layer that makes JASDA a first-class feature of the
framework rather than a simulation: training runs are registered as jobs,
atomized into step-chunks, bid into announced windows, and EXECUTED (real
jax train steps).  Measured wall time feeds the §4.2.1 ex-post verification
(ρ_J, HistAvg driven by real observations), and every chunk boundary is a
checkpoint — fault tolerance falls out of atomization (the SJA thesis).

Single-host realization: slices are executor lanes sharing this host's
device; chunks execute sequentially in committed-start order while the
schedule bookkeeping stays per-slice.  On a cluster, lanes map to mesh
partitions and chunks launch remotely; the control flow is identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..runtime.monitor import HealthMonitor
from .jobs import AgentConfig, JobAgent
from .scheduler import JasdaScheduler
from .trp import fmp_from_model
from .types import JobSpec, Variant

__all__ = ["TrainingJob", "JasdaExecutor"]


@dataclass
class TrainingJob:
    """A real training run: step_fn advances `steps` and returns metrics."""

    job_id: str
    total_steps: int
    step_fn: Callable[[int, int], Dict[str, float]]  # (start, n) -> metrics
    checkpoint_fn: Optional[Callable[[int], None]] = None
    # memory accounting for the FMP (bytes)
    param_bytes: float = 0.0
    optimizer_bytes: float = 0.0
    activation_bytes: float = 0.0
    # throughput declaration (steps/sec); calibrated from observations
    steps_per_sec: float = 1.0
    qos_deadline: Optional[float] = None
    steps_done: int = 0
    metrics_log: List[Dict[str, float]] = field(default_factory=list)


class JasdaExecutor:
    def __init__(self, scheduler: JasdaScheduler, *,
                 monitor: Optional[HealthMonitor] = None):
        self.scheduler = scheduler
        self.monitor = monitor or HealthMonitor()
        for sid in scheduler.slices:
            self.monitor.register(sid, now=0.0)
        self.jobs: Dict[str, TrainingJob] = {}
        self._t0 = time.perf_counter()

    # -- clock ----------------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._t0

    # -- registration --------------------------------------------------------
    def register(self, job: TrainingJob, *, agent_cfg: AgentConfig = AgentConfig(),
                 atomizer=None) -> None:
        fmp = fmp_from_model(
            param_bytes=job.param_bytes,
            optimizer_bytes=job.optimizer_bytes,
            activation_bytes=job.activation_bytes,
        )
        spec = JobSpec(
            job_id=job.job_id,
            arrival_time=self.now(),
            total_work=float(job.total_steps),
            fmp=fmp,
            qos_deadline=job.qos_deadline,
        )
        agent = _TrainingAgent(spec, job, agent_cfg, atomizer) if atomizer else \
            _TrainingAgent(spec, job, agent_cfg)
        self.jobs[job.job_id] = job
        self.scheduler.add_job(agent, self.now())

    # -- main loop ------------------------------------------------------------
    def run(self, *, max_wall: float = 300.0, idle_exit: float = 5.0) -> None:
        """Drive the interaction cycle until jobs finish or wall limit."""
        last_progress = self.now()
        pending: List[Variant] = []
        while self.now() < max_wall:
            result = self.scheduler.step(self.now())
            if result and result.selected:
                pending.extend(result.selected)
                last_progress = self.now()

            # execute the next committed chunk whose start has arrived
            pending.sort(key=lambda v: v.t_start)
            ran = False
            for v in list(pending):
                if v.t_start <= self.now() + 1e-6:
                    pending.remove(v)
                    self._execute(v)
                    ran = True
                    last_progress = self.now()
                    break
            if not ran and not (result and result.selected):
                if all(j.steps_done >= j.total_steps for j in self.jobs.values()):
                    return
                if self.now() - last_progress > idle_exit:
                    time.sleep(0.01)

    # -- chunk execution --------------------------------------------------------
    def _execute(self, v: Variant) -> None:
        job = self.jobs[v.job_id]
        n_steps = max(1, int(round(v.payload["work"])))
        n_steps = min(n_steps, job.total_steps - job.steps_done)
        t_start = time.perf_counter()
        metrics = job.step_fn(job.steps_done, n_steps)
        wall = time.perf_counter() - t_start
        job.steps_done += n_steps
        job.metrics_log.append({"steps": n_steps, "wall": wall, **(metrics or {})})
        if job.checkpoint_fn is not None:
            job.checkpoint_fn(job.steps_done)  # chunk boundary = checkpoint

        # ex-post verification with REAL measurements (paper §4.2.1)
        declared = dict(v.declared_features)
        ratio = float(np.clip(v.duration / max(wall, 1e-9), 0.0, 1.0))
        observed = {k: float(np.clip(val * ratio, 0.0, 1.0)) if k in ("jct",)
                    else val for k, val in declared.items()}
        self.scheduler.complete(
            v, observed, work_done=float(n_steps),
            actual_end=v.t_start + wall)
        self.monitor.heartbeat(
            v.slice_id, now=self.now(),
            observed_speed=float(np.clip(v.duration / max(wall, 1e-9), 0.0, 2.0)))


class _TrainingAgent(JobAgent):
    """JobAgent whose throughput model tracks the job's measured step rate."""

    def __init__(self, spec: JobSpec, job: TrainingJob, cfg: AgentConfig,
                 atomizer=None):
        from .atomizer import AtomizerConfig
        super().__init__(spec, cfg, atomizer or AtomizerConfig(
            tau_min=0.5, activation_cost=0.1, max_variants_per_window=3))
        self._job = job

    def throughput_on(self, capacity: float, n_chips: int = 1) -> float:
        if capacity < self.spec.min_capacity:
            return 0.0
        if self._job.metrics_log:
            recent = self._job.metrics_log[-4:]
            sps = sum(m["steps"] for m in recent) / max(
                sum(m["wall"] for m in recent), 1e-9)
            return float(sps)
        return float(self._job.steps_per_sec)
