"""The JASDA scheduler (paper §3), refactored to batched auction rounds.

``JasdaScheduler`` owns the control plane.  One :meth:`JasdaScheduler.run_round`
drives the paper's five-step cycle over ALL open capacity at once:

  * announce every eligible window across every slice   (windows.py, step 1)
  * pooled bid collection from registered JobAgents     (jobs.py, steps 2–3)
  * ONE batched scoring dispatch + per-window WIS with
    cross-window conflict resolution                    (clearing.py, step 4)
  * commitment + bookkeeping + fairness/trust           (step 5)

The paper prototype's one-window-per-iteration loop (A3) survives as the
thin :meth:`JasdaScheduler.step` compatibility wrapper — a round restricted
to the single policy-preferred window — so external drivers (executor.py)
and the equivalence tests keep working unchanged.

The scheduler is execution-agnostic: the simulator (simulator.py) and the
real TPU executor (executor.py) both feed back observations through
``complete()``/``fail()``.  That separation mirrors the paper's
architecture, where the scheduler reasons only over declared profiles and
ex-post measurements.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .calibration import CalibrationConfig, Calibrator
from .clearing import clear_round
from .fairness import AgePolicy, AgeTracker
from .jobs import JobAgent
from .scoring import ScoringPolicy
from .types import ClearingResult, Commitment, JobSpec, RoundResult, SliceSpec, Variant, Window
from .windows import (DeadWindowRegistry, SliceTimeline, WindowPolicy,
                      announce_window, announce_windows)

__all__ = ["JasdaScheduler", "SchedulerConfig"]


@dataclass(frozen=True)
class SchedulerConfig:
    scoring: ScoringPolicy = ScoringPolicy()
    window: WindowPolicy = WindowPolicy()
    calibration: CalibrationConfig = CalibrationConfig()
    age: AgePolicy = AgePolicy()
    # windows announced but receiving no winning bids are excluded for this
    # much TIME (prevents re-announcing a dead gap forever)
    dead_window_cooldown: float = 8.0
    # epsilon for matching a re-derived gap against a suppressed window
    # (float drift from releases/early finishes must not resurrect it)
    dead_window_eps: float = 1e-6
    # batched-scoring backend override: None = auto (Pallas on TPU, jnp
    # reference elsewhere); "ref" | "pallas" to force
    score_impl: Optional[str] = None


@dataclass
class IterationLog:
    """One row of the scheduler's audit trail (transparency, paper §5(f)).

    In round mode a row covers the whole round: ``n_windows`` announced
    windows cleared together (``window`` keeps the first announced window
    for backward compatibility; None when the round was empty).
    """

    t: float
    window: Optional[Window]
    n_bidders: int
    n_bids: int
    n_selected: int
    total_score: float
    n_windows: int = 0
    n_conflicts: int = 0


class JasdaScheduler:
    def __init__(self, slices: Sequence[SliceSpec], config: SchedulerConfig = SchedulerConfig()):
        self.config = config
        self.slices: Dict[str, SliceTimeline] = {
            s.slice_id: SliceTimeline(s) for s in slices
        }
        self.agents: Dict[str, JobAgent] = {}
        self.calibrator = Calibrator(config.calibration)
        self.ages = AgeTracker(config.age)
        self.commitments: List[Commitment] = []
        self.log: List[IterationLog] = []
        self.retired_intervals: Dict[str, List[Tuple[float, float]]] = {}
        self._dead_windows = DeadWindowRegistry(eps=config.dead_window_eps)

    # -- membership -----------------------------------------------------------
    def add_job(self, agent: JobAgent, now: float) -> None:
        self.agents[agent.spec.job_id] = agent
        self.ages.register_arrival(agent.spec.job_id, now)

    def remove_job(self, job_id: str) -> None:
        self.agents.pop(job_id, None)
        self.ages.remove(job_id)

    def add_slice(self, spec: SliceSpec) -> None:
        """Elastic scale-up: a new slice joins the pool mid-run."""
        self.slices[spec.slice_id] = SliceTimeline(spec)

    def drop_slice(self, slice_id: str, now: Optional[float] = None) -> List[Commitment]:
        """Slice failure/scale-down: returns the commitments that were lost."""
        tl = self.slices.pop(slice_id, None)
        if tl is not None:  # keep history for utilization accounting, but
            # only the part actually EXECUTED (future commitments are lost,
            # re-bid elsewhere — counting them would double-book busy time)
            ivs = tl.busy()
            if now is not None:
                ivs = [(s0, min(e0, now)) for s0, e0 in ivs if s0 < now]
            self.retired_intervals.setdefault(slice_id, []).extend(ivs)
        lost = [c for c in self.commitments if c.variant.slice_id == slice_id]
        self.commitments = [c for c in self.commitments if c.variant.slice_id != slice_id]
        for c in lost:
            agent = self.agents.get(c.variant.job_id)
            if agent is not None:
                agent.mark_settled(c.variant)  # work becomes biddable again
        return lost

    # -- the interaction cycle: batched auction rounds --------------------------
    def run_round(self, now: float) -> Optional[RoundResult]:
        """Run ONE auction round over every announceable window.

        Returns None when no window is announceable (idle control plane).
        """
        self._dead_windows.prune(now)
        windows = announce_windows(
            self.slices, now, self.config.window, exclude=self._dead_windows
        )
        if not windows:
            self.log.append(IterationLog(now, None, 0, 0, 0, 0.0))
            return None
        return self._execute_round(now, windows)

    def step(self, now: float) -> Optional[ClearingResult]:
        """Legacy single-window iteration (paper A3): a one-window round.

        Thin compatibility wrapper over the round machinery; selections are
        identical to the pre-round per-window path (equivalence-tested).
        """
        self._dead_windows.prune(now)
        window = announce_window(
            self.slices, now, self.config.window, exclude=self._dead_windows
        )
        if window is None:
            self.log.append(IterationLog(now, None, 0, 0, 0, 0.0))
            return None
        return self._execute_round(now, [window]).results[0]

    def _execute_round(self, now: float, windows: Sequence[Window]) -> RoundResult:
        # Steps 2–3: every job answers the full window set (or stays silent).
        chips = {sid: tl.spec.n_chips for sid, tl in self.slices.items()}
        pool: List[Variant] = []
        bidders = 0
        budget: Dict[str, float] = {}
        for agent in self.agents.values():
            vs = agent.generate_variants_round(windows, now, chips)
            if vs:
                bidders += 1
                pool.extend(vs)
                budget[agent.spec.job_id] = agent.biddable_work

        # Step 4: one batched scoring dispatch + WIS per window + cross-window
        # conflict resolution (a job keeps only compatible best-scored wins).
        rr = clear_round(
            windows,
            pool,
            self.config.scoring,
            ages=self.ages.ages(now),
            calibrate=self.calibrator.calibrate,
            work_budget=budget,
            score_impl=self.config.score_impl,
        )

        # Step 5: commit winners; suppress windows that cleared empty.
        for result in rr.results:
            if result.selected:
                tl = self.slices[result.window.slice_id]
                for v, s in zip(result.selected, result.scores):
                    tl.commit(v.t_start, v.t_end)
                    self.commitments.append(Commitment(variant=v, commit_time=now, score=s))
                    self.ages.mark_selected(v.job_id, now)
                    agent = self.agents[v.job_id]
                    agent.n_wins += 1
                    agent.mark_committed(v)
            else:
                self._dead_windows.add(
                    result.window.slice_id,
                    result.window.t_min,
                    now + self.config.dead_window_cooldown,
                )

        rr.n_bidders = bidders
        self.log.append(
            IterationLog(
                now, windows[0], bidders, rr.n_bids, len(rr.selected),
                rr.total_score, n_windows=len(windows), n_conflicts=rr.n_conflicts,
            )
        )
        return rr

    # -- ex-post feedback (paper §4.2.1) -----------------------------------------
    def complete(
        self,
        variant: Variant,
        observed_features: Dict[str, float],
        *,
        observed_utility: Optional[float] = None,
        work_done: Optional[float] = None,
        actual_end: Optional[float] = None,
    ) -> float:
        """Ingest execution ground truth for a committed variant.

        Updates calibration state (ρ_J, HistAvg) and job progress; if the
        subjob finished EARLY, the reclaimed tail of its committed interval
        is released back to the timeline (new window for future rounds).
        """
        eps = self.calibrator.verify(variant, observed_features, observed_utility)
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.mark_settled(variant)
            agent.record_progress(
                work_done if work_done is not None else variant.payload["work"]
            )
        if actual_end is not None and actual_end < variant.t_end - 1e-9:
            tl = self.slices.get(variant.slice_id)
            if tl is not None:
                tl.release(variant.t_start, variant.t_end)
                tl.commit(variant.t_start, actual_end)
        return eps

    def fail(self, variant: Variant, now: float) -> None:
        """A committed subjob died (node failure): release its reservation.

        The job's progress for the chunk is NOT recorded (it restarts from
        the last checkpoint boundary = chunk start), and the slice becomes
        free from ``now`` — exactly the recovery path atomization buys.
        """
        tl = self.slices.get(variant.slice_id)
        if tl is not None:
            tl.release(variant.t_start, variant.t_end)
            occupied_until = min(now, variant.t_end)
            if occupied_until > variant.t_start:
                tl.commit(variant.t_start, occupied_until)  # occupancy until death
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.mark_settled(variant)

    # -- reporting ------------------------------------------------------------
    def utilization(self, t_from: float, t_to: float) -> Dict[str, float]:
        out = {}
        span = max(t_to - t_from, 1e-9)
        intervals: Dict[str, list] = {
            sid: list(tl.busy()) for sid, tl in self.slices.items()
        }
        for sid, ivs in self.retired_intervals.items():
            intervals.setdefault(sid, []).extend(ivs)
        for sid, ivs in intervals.items():
            busy = sum(max(0.0, min(e, t_to) - max(s, t_from)) for s, e in ivs)
            out[sid] = busy / span
        return out
