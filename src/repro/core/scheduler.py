"""The JASDA scheduler (paper §3), refactored to batched auction rounds.

``JasdaScheduler`` owns the control plane.  It is configured by ONE unified
``repro.core.policy.Policy`` value — scoring weights, window ordering, age
curve, calibration, θ-recheck mode AND the pluggable clearing backend
(``GreedyWIS`` / ``GlobalAssignment`` / ``FairShare``) — constructed
directly (``JasdaScheduler(slices, Policy.utilization())``) or via the
named presets.  The legacy ``SchedulerConfig`` still works: its scattered
policy fragments are converted with :meth:`SchedulerConfig.to_policy` (a
DeprecationWarning points at the Policy API), and runtime knobs
(dead-window cooldown, score backend override, log caps, cache sizes) stay
on ``SchedulerConfig`` either way.

One :meth:`JasdaScheduler.run_round`
drives the paper's five-step cycle over ALL open capacity at once:

  * announce every eligible window across every slice   (windows.py, step 1)
  * pooled bid collection from registered JobAgents
    via the typed negotiation protocol
    (WindowAnnouncement → BidBundle)                    (jobs.py, steps 2–3)
  * ONE batched scoring dispatch + per-window WIS with
    cross-window conflict resolution                    (clearing.py, step 4)
  * commitment + bookkeeping + fairness/trust, then a
    RoundFeedback broadcast back to every bidder
    (negotiation/messages.py: cutoffs, awards, loss
    reasons, calibration state) — the clearing→agent
    feedback channel adaptive strategies learn from     (step 5)

The round is split into a **prepare** half (announce + bid collection +
packing + async scoring dispatch — :meth:`_prepare_round`) and a **settle**
half (block on scores, WIS + conflicts, commit — :meth:`_settle_round`).
``run_round`` composes them serially; :meth:`run_rounds_pipelined`
double-buffers them across consecutive rounds (core/pipeline.py): while
round k's scores are in flight on device, the host speculatively prepares
round k+1, and an epoch counter (``_epoch``, bumped by every state
mutation) guarantees a speculative preparation is only used when it is
provably byte-identical to what a serial preparation would produce.

The paper prototype's one-window-per-iteration loop (A3) survives as the
thin :meth:`JasdaScheduler.step` compatibility wrapper — a round restricted
to the single policy-preferred window — so external drivers (executor.py)
and the equivalence tests keep working unchanged.

Commitment bookkeeping is bounded: ``commitments`` holds only OUTSTANDING
commitments (settled ones are pruned on :meth:`complete`/:meth:`fail`);
the append-only ``commit_log`` keeps lightweight audit rows (no FMP/variant
references) with running totals, optionally capped via
``SchedulerConfig.max_log_rows`` together with the iteration ``log``.

The scheduler is execution-agnostic: the simulator (simulator.py) and the
real TPU executor (executor.py) both feed back observations through
``complete()``/``fail()``.  That separation mirrors the paper's
architecture, where the scheduler reasons only over declared profiles and
ex-post measurements.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..runtime.monitor import retry_with_backoff
from .calibration import CalibrationConfig, Calibrator
from .clearing import assign_bids
from .fairness import AgePolicy, AgeTracker
from .faults import AgentFault
from .jobs import JobAgent
from .negotiation import RoundFeedback, WindowAnnouncement, build_feedback
from .negotiation.messages import (LOSS_SLICE_FAILED, LossReport,
                                   build_shed_feedback)
from .policy import ClearingPolicy, GreedyWIS, Policy
from .scoring import ScoringPolicy, score_round_async
from .types import (DEAD_WINDOW_EPS, ClearingResult, Commitment, JobSpec,
                    RoundResult, SliceSpec, Variant, Window)
from .windows import (DeadWindowRegistry, SliceTimeline, WindowPolicy,
                      announce_window, announce_windows)

__all__ = ["JasdaScheduler", "SchedulerConfig", "CommitRecord", "RoundPrep"]


@dataclass(frozen=True)
class SchedulerConfig:
    """Runtime knobs + (deprecated) scattered policy fragments.

    The policy surface — scoring / window / age / calibration / clearing
    backend / θ-recheck — now lives on the unified ``repro.core.policy.
    Policy`` object; pass one straight to ``JasdaScheduler``.  The fragment
    fields below keep working (converted via :meth:`to_policy`, with a
    DeprecationWarning from the scheduler when overridden), so legacy
    ``SchedulerConfig(scoring=..., window=...)`` construction is unchanged.
    Runtime knobs (cooldowns, backend override, cache/log caps) are NOT part
    of ``Policy`` and remain first-class here.
    """

    scoring: ScoringPolicy = ScoringPolicy()
    window: WindowPolicy = WindowPolicy()
    calibration: CalibrationConfig = CalibrationConfig()
    age: AgePolicy = AgePolicy()
    # windows announced but receiving no winning bids are excluded for this
    # much TIME (prevents re-announcing a dead gap forever)
    dead_window_cooldown: float = 8.0
    # epsilon for matching a re-derived gap against a suppressed window
    # (float drift from releases/early finishes must not resurrect it)
    dead_window_eps: float = DEAD_WINDOW_EPS
    # batched-scoring backend override: None = auto (Pallas on TPU, jnp
    # reference elsewhere); "numpy" | "ref" | "pallas" to force
    score_impl: Optional[str] = None
    # settle-side WIS backend (the device-resident batched settle): None =
    # the historical per-window host loop (byte-identical default);
    # "numpy" = batched host float64 (byte-identical, one DP loop per lane
    # for all windows); "ref" | "pallas" = kernels/wis_dp device dispatch
    # with the first WIS pass fused behind the scoring dispatch.  A runtime
    # knob like score_impl — it changes WHERE clearing runs, never what is
    # selected (parity is gated by tests/test_device_settle.py).
    wis_impl: Optional[str] = None
    # auction mesh (a jax.sharding.Mesh, e.g. launch.mesh.make_auction_mesh)
    # sharding the device dispatches of a round — the pooled-bid axis of
    # scoring and the window axis of the batched settle — via shard_map.
    # Another WHERE-not-WHAT knob: sharded rounds are byte-identical to
    # single-device (tests/test_sharded_auction.py).  None = single device;
    # ignored by host ("numpy"/None) backends.  Mesh is hashable, so the
    # frozen-dataclass contract holds.
    mesh: Optional[object] = None
    # re-verify safety condition (a) in-dispatch with this θ against each
    # bid's OWN window capacity (per-variant capacities; heterogeneous
    # slices).  None = off: generation already enforces condition (a).
    # Scheduler-wide OVERRIDE: takes precedence over recheck_per_agent.
    recheck_theta: Optional[float] = None
    # re-verify with each bid's OWN agent θ (Variant.theta → PackedRound.
    # thetas) instead of one scheduler-wide bound
    recheck_per_agent: bool = False
    # round-clearing backend (repro.core.policy.ClearingPolicy); None =
    # GreedyWIS (the historical greedy semantics, byte-identical)
    clearing: Optional[ClearingPolicy] = None
    # bid-collection fault handling (active only when a fault gate is
    # installed — ``scheduler.fault_gate``): an erroring agent's respond()
    # is retried up to ``bid_retries`` times with capped exponential
    # backoff; silent agents and retry-exhausted agents are DROPPED for
    # the round (empty bid groups) so a faulty bidder never stalls it
    bid_retries: int = 2
    bid_backoff_base: float = 0.01
    bid_backoff_factor: float = 2.0
    bid_backoff_max: float = 0.25
    # bounded FMP-grid discretization cache (entries), scoped to this
    # scheduler instance — see kernels.jasda_score.ops.FMPGridCache
    grid_cache_size: int = 1024
    # cap on audit-trail rows (iteration log AND commit log); None = keep all
    max_log_rows: Optional[int] = None
    # the unified Policy this config was built from (the BLESSED way to
    # combine a Policy with runtime knobs — set directly or via
    # :meth:`from_policy`).  When present it takes precedence over the
    # legacy fragment fields above and suppresses the deprecation warning;
    # a real dataclass field so ``dataclasses.replace`` preserves it.
    policy: Optional[Policy] = None

    def to_policy(self) -> Policy:
        """The unified Policy: the ``policy`` field if set, else the lifted
        legacy fragments."""
        if self.policy is not None:
            return self.policy
        return Policy(
            name="legacy",
            scoring=self.scoring,
            window=self.window,
            age=self.age,
            calibration=self.calibration,
            clearing=self.clearing if self.clearing is not None else GreedyWIS(),
            recheck_theta=self.recheck_theta,
            per_agent_theta=self.recheck_per_agent,
        )

    def _policy_fragments_overridden(self) -> bool:
        """True when legacy policy kwargs were used (→ deprecation path)."""
        if self.policy is not None:
            return False  # unified path: fragments only mirror the Policy
        return (
            self.scoring != ScoringPolicy()
            or self.window != WindowPolicy()
            or self.calibration != CalibrationConfig()
            or self.age != AgePolicy()
            or self.recheck_theta is not None
            or self.recheck_per_agent
            or self.clearing is not None
        )

    @classmethod
    def from_policy(cls, policy: Policy, **runtime_kw) -> "SchedulerConfig":
        """Mirror a Policy into a SchedulerConfig (runtime knobs as kwargs).

        The fragment fields are populated for introspection, and the
        ``policy`` field keeps the original object authoritative (preset
        name included) — surviving ``dataclasses.replace`` and never
        triggering the scattered-kwargs DeprecationWarning.
        """
        return cls(
            scoring=policy.scoring,
            window=policy.window,
            calibration=policy.calibration,
            age=policy.age,
            recheck_theta=policy.recheck_theta,
            recheck_per_agent=policy.per_agent_theta,
            clearing=policy.clearing,
            policy=policy,
            **runtime_kw,
        )


@dataclass
class IterationLog:
    """One row of the scheduler's audit trail (transparency, paper §5(f)).

    In round mode a row covers the whole round: ``n_windows`` announced
    windows cleared together (``window`` keeps the first announced window
    for backward compatibility; None when the round was empty).
    """

    t: float
    window: Optional[Window]
    n_bidders: int
    n_bids: int
    n_selected: int
    total_score: float
    n_windows: int = 0
    n_conflicts: int = 0
    # agents dropped from THIS round's bid collection (silent / erroring
    # past the retry budget) — the audit trail of graceful degradation
    n_dropped: int = 0


@dataclass
class CommitRecord:
    """Lightweight audit row for one commitment (no variant/FMP retained).

    ``status`` tracks the commitment lifecycle: ``active`` →
    ``completed`` | ``failed`` | ``lost`` (slice died, progress torched) |
    ``preempted`` (interrupted with partial-progress credit) |
    ``migrated`` (residual re-placed on another slice; the successor row
    is a fresh ``active`` commit).  On early finishes ``t_end`` is
    truncated to the actually-executed end; ``work_credited`` records the
    granule-aligned progress kept by the preempt/migrate rungs of the
    revocation ladder (0.0 for every other status).
    """

    variant_id: str
    job_id: str
    slice_id: str
    t_start: float
    t_end: float
    commit_time: float
    score: float
    status: str = "active"
    work_credited: float = 0.0

    @property
    def interval(self) -> Tuple[float, float]:
        return (self.t_start, self.t_end)


@dataclass
class RoundPrep:
    """The prepared (host) half of one auction round, ready to settle.

    Produced by :meth:`JasdaScheduler._prepare_round`; the scoring dispatch
    (``handle``) may still be in flight on device.  ``epoch`` snapshots the
    scheduler state version the preparation was computed against — the
    pipeline only reuses a speculative prep whose epoch still matches.
    ``bids[a][k]`` holds agent a's bids on window k (agent-major pool
    order), so invalidated windows can be dropped without regenerating the
    surviving windows' bids.
    """

    now: float
    epoch: int
    windows: List[Window]
    agents: List[JobAgent] = field(default_factory=list)
    # per-agent bid groups (read-only; group containers may be tuples)
    bids: List[Sequence[Sequence[Variant]]] = field(default_factory=list)
    pool: List[Variant] = field(default_factory=list)
    fit: List[Variant] = field(default_factory=list)
    win_idx: object = None  # (F,) window index per fitting bid
    view: object = None  # types.PoolView aligned with ``fit``
    bidders: int = 0
    budget: Dict[str, float] = field(default_factory=dict)
    ages: Optional[Dict[str, float]] = None  # A_i(now), reused by settle
    handle: Optional[object] = None  # scoring.ScoreHandle
    # (F,) host array of ψ_energy per fitting bid when an EnergyModel is
    # attached (core/repartition.py); None = no energy term (historical)
    energy: Optional[object] = None
    # in-flight fused first-pass WIS chained on the scoring dispatch
    # (core.wis.SettlePrefetch; device wis_impl + prefetch-capable backend)
    wis_prefetch: Optional[object] = None
    stats_snap: Optional[Dict[str, Tuple[int, int]]] = None  # speculative only
    n_dropped: int = 0  # agents dropped by the bid-collection fault gate


class JasdaScheduler:
    def __init__(
        self,
        slices: Sequence[SliceSpec],
        config: Union[SchedulerConfig, Policy, None] = None,
    ):
        """``config`` is a unified ``Policy`` (preferred) or a legacy
        ``SchedulerConfig`` (deprecated when its policy fragments are
        overridden; runtime knobs alone do not warn)."""
        if config is None:
            config = SchedulerConfig()
        if isinstance(config, Policy):
            self.policy = config
            self.config = SchedulerConfig.from_policy(config)
        elif isinstance(config, SchedulerConfig):
            if config._policy_fragments_overridden():
                warnings.warn(
                    "configuring JasdaScheduler policy through scattered "
                    "SchedulerConfig kwargs (scoring/window/age/calibration/"
                    "recheck_theta/clearing) is deprecated; pass a unified "
                    "repro.core.policy.Policy (e.g. Policy.utilization()) "
                    "instead",
                    DeprecationWarning,
                    stacklevel=2,
                )
            # to_policy returns the authoritative ``policy`` field when set
            # (preset name included); hand-built legacy configs are lifted
            self.policy = config.to_policy()
            self.config = config
        else:
            raise TypeError(
                f"config must be a Policy or SchedulerConfig, got {type(config).__name__}"
            )
        self.slices: Dict[str, SliceTimeline] = {
            s.slice_id: SliceTimeline(s) for s in slices
        }
        self.agents: Dict[str, JobAgent] = {}
        self.calibrator = Calibrator(self.policy.calibration)
        self.ages = AgeTracker(self.policy.age)
        # outstanding commitments only; settled ones are pruned (complete/
        # fail/drop_slice) and survive as commit_log rows + running totals
        self.commitments: List[Commitment] = []
        self.commit_log: List[CommitRecord] = []
        self.n_committed_total: int = 0
        self.committed_score_total: float = 0.0
        # keyed by id(variant): variant ids are only unique within a round
        # (jobs._make_variant), while outstanding commitments span rounds —
        # identity keying cannot collide because the Commitment in the entry
        # keeps its variant alive for exactly the entry's lifetime
        self._commit_index: Dict[int, Tuple[Commitment, CommitRecord]] = {}
        self.log: List[IterationLog] = []
        # the most recent RoundFeedback broadcast (negotiation channel)
        self.last_feedback: Optional[RoundFeedback] = None
        self.retired_intervals: Dict[str, List[Tuple[float, float]]] = {}
        # disruption accounting (the revocation ladder's audit surface):
        # commitments preempted with credit, migrated to another slice, or
        # lost outright, plus the total granule-aligned work credited and a
        # per-reason loss histogram (slice_failed / preempted / migrated)
        self.n_preempted_total: int = 0
        self.n_migrated_total: int = 0
        self.n_lost_total: int = 0
        self.work_credited_total: float = 0.0
        self.loss_reasons: Dict[str, int] = {}
        self._dead_windows = DeadWindowRegistry(eps=self.config.dead_window_eps)
        # state version: bumped by EVERY mutation that could change what a
        # future round announces, who bids, or how bids are scored.  The
        # round pipeline validates speculative preparations against it.
        self._epoch = 0
        # per-scheduler bounded FMP grid cache (replaces the old
        # process-global lru_cache, which leaked grids across instances)
        from ..kernels.jasda_score.ops import FMPGridCache

        self._grid_cache = FMPGridCache(maxsize=self.config.grid_cache_size)
        # sticky per-backend health shared by the scoring and settle
        # dispatches: one device failure anywhere degrades BOTH down the
        # pallas → ref → numpy ladder (kernels.common.BackendHealth)
        from ..kernels.common import BackendHealth

        self.backend_health = BackendHealth()
        # bid-collection fault gate (faults.FaultInjector or any callable
        # ``gate(agent, now, attempt)`` raising faults.AgentFault); None =
        # fault-free collection, byte-identical to the historical path
        self.fault_gate = None
        # repartition-layer inputs (core/repartition.py), both None by
        # default so the historical behavior is byte-identical:
        # window_demand feeds the ``frag_aware`` announcement ordering;
        # energy_model gives ψ_energy a per-slice power figure
        self.window_demand: Optional[Tuple[float, ...]] = None
        self.energy_model = None
        # settle-side WIS backend (SchedulerConfig.wis_impl): the default is
        # the historical per-window host loop; the batched backends clear
        # every window of a round in one dispatch (core/wis.py)
        from .wis import make_round_selector

        self._wis_selector = make_round_selector(self.config.wis_impl,
                                                 mesh=self.config.mesh,
                                                 health=self.backend_health)

    # -- membership -----------------------------------------------------------
    def add_job(self, agent: JobAgent, now: float) -> None:
        self.agents[agent.spec.job_id] = agent
        self.ages.register_arrival(agent.spec.job_id, now)
        self._epoch += 1

    def remove_job(self, job_id: str) -> None:
        self.agents.pop(job_id, None)
        self.ages.remove(job_id)
        self._epoch += 1

    def add_slice(self, spec: SliceSpec) -> None:
        """Elastic scale-up: a new slice joins the pool mid-run."""
        self.slices[spec.slice_id] = SliceTimeline(spec)
        self._epoch += 1

    def drop_slice(self, slice_id: str, now: Optional[float] = None) -> List[Commitment]:
        """Slice failure/scale-down: returns the commitments that were lost."""
        tl = self.slices.pop(slice_id, None)
        if tl is not None:  # keep history for utilization accounting, but
            # only the part actually EXECUTED (future commitments are lost,
            # re-bid elsewhere — counting them would double-book busy time)
            ivs = tl.busy()
            if now is not None:
                ivs = [(s0, min(e0, now)) for s0, e0 in ivs if s0 < now]
            self.retired_intervals.setdefault(slice_id, []).extend(ivs)
        lost = [c for c in self.commitments if c.variant.slice_id == slice_id]
        self.commitments = [c for c in self.commitments if c.variant.slice_id != slice_id]
        for c in lost:
            entry = self._commit_index.pop(id(c.variant), None)
            if entry is not None:
                entry[1].status = "lost"
            agent = self.agents.get(c.variant.job_id)
            if agent is not None:
                agent.mark_settled(c.variant)  # work becomes biddable again
        if lost:
            self.n_lost_total += len(lost)
            self.loss_reasons["slice_failed"] = (
                self.loss_reasons.get("slice_failed", 0) + len(lost))
        self._epoch += 1
        return lost

    # -- fault handling (core/faults.py drives these) --------------------------
    def revoke_slice(self, slice_id: str, now: float) -> List[Commitment]:
        """Slice death with the FULL recovery protocol (beyond drop_slice).

        On top of :meth:`drop_slice` (commitments marked ``lost`` in the
        commit_log, their work re-entering the owning agents' biddable
        pools through ``mark_settled``), this (a) retires the slice's
        announced windows through the :class:`DeadWindowRegistry` so an
        ε-close twin re-derived after repair cannot resurrect immediately,
        and (b) broadcasts an out-of-round :class:`RoundFeedback` carrying
        one ``slice_failed`` :class:`LossReport` per revoked commitment, so
        adaptive strategies and calibration observe the revocation the same
        way they observe any other round outcome.  Returns the lost
        commitments (all of whose variants the atomizer will re-chunk on
        the next announcement).

        Idempotent: revoking an already-dead slice (not in the pool, no
        outstanding commitments) is a strict no-op — no duplicate ``lost``
        commit rows, no second ``slice_failed`` broadcast, no epoch bump,
        no dead-window churn.  Fault and repartition paths may race to the
        same revocation; only the first one observes anything.
        """
        if slice_id not in self.slices and not any(
                c.variant.slice_id == slice_id for c in self.commitments):
            return []
        tl = self.slices.get(slice_id)
        capacity = tl.spec.capacity_bytes if tl is not None else 0.0
        cooldown = now + self.config.dead_window_cooldown
        if self.last_feedback is not None:
            for w in self.last_feedback.windows:
                if w.slice_id == slice_id:
                    self._dead_windows.add(slice_id, w.t_min, cooldown)
        lost = self.drop_slice(slice_id, now=now)
        if not lost:
            return lost
        losses: Dict[str, List[LossReport]] = {}
        for c in lost:
            v = c.variant
            w = Window(slice_id, capacity, v.t_start, v.t_end - v.t_start)
            self._dead_windows.add(slice_id, v.t_start, cooldown)
            losses.setdefault(v.job_id, []).append(
                LossReport(v.variant_id, w, LOSS_SLICE_FAILED))
        reliability: Dict[str, float] = {}
        cal_err: Dict[str, float] = {}
        cal_bias: Dict[str, float] = {}
        for job_id in losses:
            st = self.calibrator.state(job_id)
            reliability[job_id] = float(st.rho)
            cal_err[job_id] = float(
                st.mean_error(self.calibrator.config.error_window))
            cal_bias[job_id] = float(st.bias)
        feedback = RoundFeedback(
            t=now, windows=(), cutoffs={}, awards={},
            losses={j: tuple(ls) for j, ls in losses.items()},
            reliability=reliability, calibration_error=cal_err,
            calibration_bias=cal_bias,
        )
        for job_id in losses:
            agent = self.agents.get(job_id)
            if agent is not None:
                agent.observe_feedback(feedback)
        self.last_feedback = feedback
        return lost

    def shed_job(self, job_id: str, now: float) -> bool:
        """Admission-control eviction (open-loop service back-pressure).

        Removes the job from the biddable pool and notifies its agent via
        an out-of-round :class:`RoundFeedback` carrying one ``shed``
        :class:`LossReport` (``negotiation.messages.LOSS_SHED``) — the
        admission-side mirror of :meth:`revoke_slice`'s ``slice_failed``
        broadcast.  The caller owns any outstanding commitments: queued
        chunks should be cancelled via ``fail`` (releasing reservations)
        before shedding; a chunk already running settles harmlessly
        against the departed agent (``complete``/``fail`` tolerate it).
        Unlike a settled round, the broadcast does NOT replace
        ``last_feedback`` (sheds are out-of-band; the last real round's
        window set must stay visible to revoke_slice's dead-window
        bookkeeping).  Returns False when the job is unknown.
        """
        agent = self.agents.get(job_id)
        if agent is None:
            return False
        self.remove_job(job_id)
        agent.observe_feedback(
            build_shed_feedback(now, [job_id], self.calibrator))
        return True

    def degrade_slice(self, slice_id: str, speed_factor: float) -> None:
        """Straggler injection: the slice keeps running at reduced speed.

        Declared capacity is unchanged (commitments stay valid); observed
        durations inflate, ex-post ε grows, and calibration shifts bids
        away — the paper's own trust machinery is the mitigation.
        """
        tl = self.slices.get(slice_id)
        if tl is None:
            return
        import dataclasses

        tl.spec = dataclasses.replace(
            tl.spec, speed=tl.spec.speed * float(speed_factor))
        self._epoch += 1

    def set_window_demand(self, demand) -> None:
        """Attach the pending pool's capacity-demand histogram (repartition
        layer) to window announcement.  Only the ``frag_aware`` ordering
        reads it; a change invalidates speculative preparations exactly
        like any other announcement input."""
        demand = tuple(demand) if demand is not None else None
        if demand != self.window_demand:
            self.window_demand = demand
            self._epoch += 1

    def retire_slice(self, slice_id: str, now: float) -> List[Commitment]:
        """Permanently remove a slice (repartition merge-away/power-gate).

        Runs the full :meth:`revoke_slice` recovery protocol when
        commitments are outstanding (commit-log ``lost`` rows,
        ``LOSS_SLICE_FAILED`` feedback), then retires the id's
        dead-window entries — a slice reborn later under the same
        canonical id (split/merge cycles reuse interval-derived names)
        must start with a clean suppression slate.
        """
        if any(c.variant.slice_id == slice_id for c in self.commitments):
            lost = self.revoke_slice(slice_id, now)
        else:
            lost = self.drop_slice(slice_id, now=now)
        self._dead_windows.drop_slice(slice_id)
        return lost

    def invalidate_speculation(self) -> None:
        """Bump the state epoch so in-flight speculative preparations are
        discarded (fault epochs: e.g. a dispatch fault armed between
        rounds must be observed by a FRESH dispatch, not a stale one)."""
        self._epoch += 1

    # -- the interaction cycle: batched auction rounds --------------------------
    def run_round(self, now: float) -> Optional[RoundResult]:
        """Run ONE auction round over every announceable window.

        Returns None when no window is announceable (idle control plane).
        """
        return self._settle_round(self._prepare_round(now))

    def run_rounds_pipelined(self, times: Sequence[float]) -> List[Optional[RoundResult]]:
        """Run consecutive rounds with host/device double-buffering.

        Semantically identical to ``[self.run_round(t) for t in times]`` —
        selections, commitments, logs and agent statistics are byte-for-byte
        equal (equivalence-tested) — but while round k's batched scores are
        in flight on device, the host already announces windows and
        collects/packs bids for round k+1.  See core/pipeline.py for the
        speculation-validation protocol.
        """
        from .pipeline import RoundPipeline

        times = list(times)
        pipe = RoundPipeline(self)
        out: List[Optional[RoundResult]] = []
        for i, t in enumerate(times):
            nxt = times[i + 1] if i + 1 < len(times) else None
            out.append(pipe.tick(t, next_time=nxt))
        pipe.flush()
        return out

    def step(self, now: float) -> Optional[ClearingResult]:
        """Legacy single-window iteration (paper A3): a one-window round.

        Thin compatibility wrapper over the round machinery; selections are
        identical to the pre-round per-window path (equivalence-tested).
        """
        self._dead_windows.prune(now)
        window = announce_window(
            self.slices, now, self.policy.window, exclude=self._dead_windows,
            demand=self.window_demand,
        )
        if window is None:
            self._append_log(IterationLog(now, None, 0, 0, 0, 0.0))
            return None
        rr = self._settle_round(self._build_prep(now, [window]))
        return rr.results[0]

    # -- prepare half: announce + bids + pack + async dispatch ----------------
    def _prepare_round(self, now: float, *, speculative: bool = False) -> RoundPrep:
        """Host-side half of a round: announce, collect bids, dispatch scores.

        With ``speculative=True`` the per-agent bid statistics are
        snapshotted (generation mutates them) so the pipeline can roll them
        back if the preparation is discarded; variant ids are deterministic
        (jobs.py), so generation itself is replayable.
        """
        self._dead_windows.prune(now)
        windows = announce_windows(
            self.slices, now, self.policy.window, exclude=self._dead_windows,
            demand=self.window_demand,
        )
        if not windows:
            return RoundPrep(now=now, epoch=self._epoch, windows=[])
        return self._build_prep(now, windows, speculative=speculative)

    def _build_prep(
        self, now: float, windows: List[Window], *, speculative: bool = False
    ) -> RoundPrep:
        # Steps 2–3: every job answers the full window set (or stays silent)
        # through the typed negotiation protocol (one WindowAnnouncement in,
        # one BidBundle per agent out).
        chips = {sid: tl.spec.n_chips for sid, tl in self.slices.items()}
        agents = list(self.agents.values())
        snap = (
            {a.spec.job_id: a.stats_snapshot() for a in agents}
            if speculative else None
        )
        announcement = WindowAnnouncement(
            now=now, windows=tuple(windows), chips=chips
        )
        # bundle groups are consumed read-only (pooling, pipeline refilter
        # rebuilds outer lists) — keep the frozen tuples, no unwrap copy
        bids, n_dropped = self._collect_bids(agents, announcement)
        prep = RoundPrep(
            now=now, epoch=self._epoch, windows=list(windows),
            agents=agents, bids=bids, stats_snap=snap, n_dropped=n_dropped,
        )
        self._finalize_prep(prep)
        return prep

    def _collect_bids(
        self, agents: List[JobAgent], announcement: WindowAnnouncement
    ) -> Tuple[List[Sequence[Sequence[Variant]]], int]:
        """Bid collection with a deadline: faulty bidders never stall a round.

        Without a fault gate this is exactly the historical comprehension
        (one ``respond()`` per agent).  With one, each attempt first passes
        through ``self.fault_gate(agent, now, attempt)``: a retryable
        fault (``AgentRespondError``) retries with capped exponential
        backoff up to ``config.bid_retries`` times; a non-retryable one
        (``AgentSilentError`` — the deadline expiring with no response)
        or an exhausted retry budget drops the agent for THIS round (empty
        bid groups, counted in ``IterationLog.n_dropped``).  The gate is
        evaluated at the ROUND time with deterministic attempt indices, so
        a speculative (pipelined) collection replays identically to a
        serial one.  Backoff sleeps are simulated-time no-ops: the round
        deadline is a modeling construct, not a wall-clock wait.
        """
        gate = self.fault_gate
        if gate is None:
            return [list(a.respond(announcement).by_window)
                    for a in agents], 0
        cfg = self.config
        empty: List[Sequence[Variant]] = [() for _ in announcement.windows]
        bids: List[Sequence[Sequence[Variant]]] = []
        dropped = 0
        now = announcement.now
        for a in agents:
            def _attempt(k: int, agent=a):
                gate(agent, now, k)
                return list(agent.respond(announcement).by_window)

            try:
                bids.append(retry_with_backoff(
                    _attempt,
                    retries=cfg.bid_retries,
                    base=cfg.bid_backoff_base,
                    factor=cfg.bid_backoff_factor,
                    max_delay=cfg.bid_backoff_max,
                    sleep=lambda _delay: None,
                    retryable=lambda e: isinstance(e, AgentFault)
                    and e.retryable,
                ))
            except AgentFault:
                bids.append(list(empty))
                dropped += 1
        return bids, dropped

    def _finalize_prep(self, prep: RoundPrep) -> None:
        """Pool assembly + packing + scoring dispatch for prepared bids.

        Factored out so the pipeline can re-run it after dropping the bids
        of invalidated (suppressed-since-speculation) windows.
        """
        pool: List[Variant] = []
        bidders = 0
        budget: Dict[str, float] = {}
        for agent, per_window in zip(prep.agents, prep.bids):
            n = sum(len(vs) for vs in per_window)
            if n:
                bidders += 1
                for vs in per_window:
                    pool.extend(vs)
                budget[agent.spec.job_id] = agent.biddable_work
        prep.pool = pool
        prep.bidders = bidders
        prep.budget = budget
        prep.fit, prep.win_idx, prep.view = assign_bids(prep.windows, pool)
        prep.handle = None
        prep.wis_prefetch = None
        prep.energy = None
        prep.ages = self.ages.ages(prep.now)
        if prep.fit:
            # Step 4a: ONE batched scoring dispatch, left in flight (JAX
            # async) — the settle half blocks on it; the pipeline overlaps
            # it with the next round's host work.
            prep.handle = score_round_async(
                prep.fit, prep.windows, prep.win_idx,
                self.policy.scoring,
                ages=prep.ages,
                calibrate=self.calibrator.calibrate,
                impl=self.config.score_impl,
                recheck_theta=self.policy.recheck_theta,
                per_agent_theta=self.policy.per_agent_theta,
                grid_cache=self._grid_cache,
                view=prep.view,
                mesh=self.config.mesh,
                health=self.backend_health,
            )
            # ψ_energy (repartition layer): per-bid slice-power feature,
            # folded into the settled scores on the host.  The Eq. 3 clip
            # is slack (Σβ ≤ 1, ψ ∈ [0,1]), so the host-side addition is
            # exactly the batched objective with one more fs column.
            beta_e = self.policy.scoring.betas.get("energy", 0.0)
            if self.energy_model is not None and beta_e > 0.0:
                lam = self.policy.scoring.lam
                psi = np.array(
                    [self.energy_model.psi(v.slice_id) for v in prep.fit],
                    np.float64)
                prep.energy = (1.0 - lam) * beta_e * psi
            # Step 4a': fused score→clear — with a device wis_impl the
            # ban-free first WIS pass is dispatched right behind the
            # scoring call, consuming the still-in-flight device scores.
            # Settle (and, pipelined, the next round's host prep) then
            # overlaps the whole score+clear chain instead of just scoring.
            # The energy adjustment lands AFTER the device dispatch, so the
            # prefetch (which would clear on pre-adjustment scores) is
            # skipped whenever the term is active.
            if prep.energy is None:
                from .wis import predispatch_settle

                prep.wis_prefetch = predispatch_settle(
                    self._wis_selector, self.policy.clearing,
                    len(prep.windows), prep.win_idx, prep.view, prep.handle,
                    ages=prep.ages)

    # -- settle half: block on scores, clear, commit ---------------------------
    def _settle_round(self, prep: RoundPrep) -> Optional[RoundResult]:
        if not prep.windows:
            self._append_log(IterationLog(prep.now, None, 0, 0, 0, 0.0))
            return None
        scores = prep.handle.result() if prep.handle is not None else np.zeros(0)
        if prep.energy is not None:
            scores = scores + prep.energy
        # Step 4b: selection + conflict resolution, dispatched through the
        # configured clearing backend (Policy.clearing; GreedyWIS default)
        # with the configured WIS selector; the fused first-pass prefetch is
        # forwarded only to backends that declare support for it (custom
        # backends with the original settle signature stay compatible).
        kw = {}
        if (prep.wis_prefetch is not None
                and getattr(self.policy.clearing, "supports_prefetch", False)):
            kw["prefetch"] = prep.wis_prefetch
        rr = self.policy.clearing.settle(
            prep.windows, prep.fit, prep.win_idx, scores,
            selector=self._wis_selector,
            work_budget=prep.budget, view=prep.view, ages=prep.ages,
            **kw,
        )

        # Step 5: commit winners; suppress windows that cleared empty.
        now = prep.now
        for result in rr.results:
            if result.selected:
                tl = self.slices[result.window.slice_id]
                for v, s in zip(result.selected, result.scores):
                    tl.commit(v.t_start, v.t_end)
                    self._record_commit(v, now, s)
                    self.ages.mark_selected(v.job_id, now)
                    agent = self.agents[v.job_id]
                    agent.n_wins += 1
                    agent.score_won += float(s)
                    agent.mark_committed(v)
            else:
                self._dead_windows.add(
                    result.window.slice_id,
                    result.window.t_min,
                    now + self.config.dead_window_cooldown,
                )
        # The clearing→agent feedback channel (the negotiation loop's
        # closing leg): publish one RoundFeedback broadcast — per-window
        # winning-score cutoffs, per-job awards/losses with reasons, and the
        # §4.2.1 calibration state — to every agent of the round.  A
        # strategy that adapts (observe_feedback → True) could bid
        # differently next round, so it invalidates speculative
        # preparations exactly like a state mutation: epoch-validated, the
        # same protocol that guards dead windows (core/pipeline.py).
        feedback = build_feedback(
            now, prep.windows, prep.agents, prep.bids, rr, self.calibrator,
            view=prep.view, win_idx=prep.win_idx,
        )
        adapted = False
        for agent in prep.agents:
            if agent.observe_feedback(feedback):
                adapted = True
        self.last_feedback = feedback

        if rr.selected or adapted:
            # timelines, agent budgets, ages or strategy state changed:
            # invalidate any speculative preparation built against the
            # pre-settle state
            self._epoch += 1

        rr.n_bidders = prep.bidders
        self._append_log(
            IterationLog(
                now, prep.windows[0], prep.bidders, rr.n_bids, len(rr.selected),
                rr.total_score, n_windows=len(prep.windows),
                n_conflicts=rr.n_conflicts, n_dropped=prep.n_dropped,
            )
        )
        return rr

    # -- bounded bookkeeping ---------------------------------------------------
    def _record_commit(self, v: Variant, now: float, score: float) -> None:
        c = Commitment(variant=v, commit_time=now, score=score)
        rec = CommitRecord(
            variant_id=v.variant_id, job_id=v.job_id, slice_id=v.slice_id,
            t_start=v.t_start, t_end=v.t_end, commit_time=now,
            score=float(score),
        )
        self.commitments.append(c)
        self._commit_index[id(v)] = (c, rec)
        self.commit_log.append(rec)
        self.n_committed_total += 1
        self.committed_score_total += float(score)
        cap = self.config.max_log_rows
        if cap is not None and len(self.commit_log) > cap:
            del self.commit_log[: len(self.commit_log) - cap]

    def _append_log(self, row: IterationLog) -> None:
        self.log.append(row)
        cap = self.config.max_log_rows
        if cap is not None and len(self.log) > cap:
            del self.log[: len(self.log) - cap]

    def _prune_commitment(self, variant: Variant, status: str) -> Optional[CommitRecord]:
        # identity lookup: complete()/fail() receive the committed Variant
        # object back from the executor/simulator (an equal-but-distinct
        # object would simply not prune, as before this PR — never corrupt)
        entry = self._commit_index.pop(id(variant), None)
        if entry is None:
            return None
        c, rec = entry
        rec.status = status
        try:
            self.commitments.remove(c)
        except ValueError:
            pass  # already removed (e.g. slice dropped concurrently)
        return rec

    # -- ex-post feedback (paper §4.2.1) -----------------------------------------
    def complete(
        self,
        variant: Variant,
        observed_features: Dict[str, float],
        *,
        observed_utility: Optional[float] = None,
        work_done: Optional[float] = None,
        actual_end: Optional[float] = None,
    ) -> float:
        """Ingest execution ground truth for a committed variant.

        Updates calibration state (ρ_J, HistAvg) and job progress; prunes the
        commitment from the outstanding set (its audit row survives in
        ``commit_log`` as ``completed``); if the subjob finished EARLY, the
        reclaimed tail of its committed interval is released back to the
        timeline (new window for future rounds) and the audit row's end is
        truncated to the executed end.
        """
        eps = self.calibrator.verify(variant, observed_features, observed_utility)
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.mark_settled(variant)
            agent.record_progress(
                work_done if work_done is not None else variant.payload["work"]
            )
        rec = self._prune_commitment(variant, "completed")
        if actual_end is not None and actual_end < variant.t_end - 1e-9:
            tl = self.slices.get(variant.slice_id)
            if tl is not None:
                tl.release(variant.t_start, variant.t_end)
                tl.commit(variant.t_start, actual_end)
            if rec is not None:
                rec.t_end = actual_end
        self._epoch += 1
        return eps

    def fail(self, variant: Variant, now: float) -> None:
        """A committed subjob died (node failure): release its reservation.

        The job's progress for the chunk is NOT recorded (it restarts from
        the last checkpoint boundary = chunk start), and the slice becomes
        free from ``now`` — exactly the recovery path atomization buys.
        """
        tl = self.slices.get(variant.slice_id)
        if tl is not None:
            tl.release(variant.t_start, variant.t_end)
            occupied_until = min(now, variant.t_end)
            if occupied_until > variant.t_start:
                tl.commit(variant.t_start, occupied_until)  # occupancy until death
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.mark_settled(variant)
        self._prune_commitment(variant, "failed")
        self._epoch += 1

    def preempt(
        self,
        variant: Variant,
        now: float,
        *,
        work_done: float = 0.0,
        observed_features: Optional[Dict[str, float]] = None,
    ) -> Optional[CommitRecord]:
        """Interrupt a committed subjob, keeping granule-aligned progress.

        The preempt-with-credit rung of the revocation ladder: like
        :meth:`fail` the reservation is released (occupancy kept up to
        ``now``), but ``work_done`` — the completed ``preempt_granularity``
        granules, computed by the caller from the observed execution — is
        credited through ``JobAgent.record_progress``, so only the residual
        re-enters the biddable pool.  When the caller supplies the partial
        observation, calibration ingests the OBSERVED partial speed instead
        of discarding the sample.  The audit row becomes ``preempted`` with
        ``work_credited`` set and ``t_end`` truncated to the executed end.
        Returns the audit row, or None for an unknown commitment.
        """
        if id(variant) not in self._commit_index:
            return None
        if observed_features:
            self.calibrator.verify(variant, observed_features)
        tl = self.slices.get(variant.slice_id)
        if tl is not None:
            tl.release(variant.t_start, variant.t_end)
            occupied_until = min(now, variant.t_end)
            if occupied_until > variant.t_start:
                tl.commit(variant.t_start, occupied_until)
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.mark_settled(variant)
            if work_done > 0.0:
                agent.record_progress(work_done)
        rec = self._prune_commitment(variant, "preempted")
        if rec is not None:
            rec.work_credited = float(work_done)
            rec.t_end = max(variant.t_start, min(now, variant.t_end))
        self.n_preempted_total += 1
        self.work_credited_total += float(work_done)
        self.loss_reasons["preempted"] = (
            self.loss_reasons.get("preempted", 0) + 1)
        self._epoch += 1
        return rec

    def migrate_commitment(
        self,
        variant: Variant,
        now: float,
        *,
        slice_id: str,
        t_start: float,
        duration: float,
        residual_work: float,
        credited_work: float = 0.0,
        observed_features: Optional[Dict[str, float]] = None,
    ) -> Optional[Variant]:
        """Re-place a commitment's residual work on a surviving slice.

        The migrate rung of the revocation ladder: the old placement is
        vacated exactly like :meth:`preempt` (occupancy kept to ``now``,
        ``credited_work`` granules recorded as progress, partial
        observation fed to calibration), its audit row becomes
        ``migrated``, and a successor variant carrying ``residual_work``
        is committed at ``(slice_id, t_start, duration)`` — the commit
        score carries over, migration is not a re-auction.  The caller
        owns placement feasibility (capacity, windows, dead-window
        suppression: :class:`~repro.core.repartition.MigrationPlanner`);
        this method enforces only the timeline's own no-overlap invariant.
        Returns the successor variant, or None for an unknown commitment
        or a target slice not in the pool.
        """
        import dataclasses

        entry = self._commit_index.get(id(variant))
        tl_new = self.slices.get(slice_id)
        if entry is None or tl_new is None:
            return None
        c, _rec = entry
        if observed_features:
            self.calibrator.verify(variant, observed_features)
        tl = self.slices.get(variant.slice_id)
        if tl is not None:
            tl.release(variant.t_start, variant.t_end)
            occupied_until = min(now, variant.t_end)
            if occupied_until > variant.t_start:
                tl.commit(variant.t_start, occupied_until)
        agent = self.agents.get(variant.job_id)
        if agent is not None:
            agent.mark_settled(variant)
            if credited_work > 0.0:
                agent.record_progress(credited_work)
        old_rec = self._prune_commitment(variant, "migrated")
        if old_rec is not None:
            old_rec.work_credited = float(credited_work)
            old_rec.t_end = max(variant.t_start, min(now, variant.t_end))
        payload = (dict(variant.payload)
                   if isinstance(variant.payload, dict) else {})
        payload["work"] = float(residual_work)
        new_v = dataclasses.replace(
            variant,
            slice_id=slice_id,
            t_start=t_start,
            duration=duration,
            payload=payload,
            variant_id=variant.variant_id + "~mig",
        )
        tl_new.commit(t_start, t_start + duration)
        self._record_commit(new_v, now, c.score)
        if agent is not None:
            agent.mark_committed(new_v)
        self.n_migrated_total += 1
        self.work_credited_total += float(credited_work)
        self.loss_reasons["migrated"] = (
            self.loss_reasons.get("migrated", 0) + 1)
        self._epoch += 1
        return new_v

    # -- checkpointing (crash recovery; checkpoint/store.py) -------------------
    def __getstate__(self):
        """Picklable state for checkpointed crash recovery.

        ``_commit_index`` is keyed by ``id(variant)`` — identities do not
        survive a pickle round-trip, so the index is serialized as its
        entry list and re-keyed on the restored variant objects in
        :meth:`__setstate__`.  Pickling the scheduler TOGETHER with any
        simulator state that shares its Variant objects (one combined
        dump) preserves those identities across the boundary, which is
        what makes ``complete()``/``fail()`` identity lookups keep working
        after a restore.  Requires ``config.mesh is None`` (device meshes
        are process-bound and cannot ride a checkpoint).
        """
        if self.config.mesh is not None:
            raise ValueError(
                "checkpointing a mesh-sharded scheduler is unsupported: "
                "jax meshes are process-bound (set SchedulerConfig.mesh=None)")
        state = self.__dict__.copy()
        state["_commit_index"] = list(self._commit_index.values())
        return state

    def __setstate__(self, state):
        entries = state.pop("_commit_index")
        self.__dict__.update(state)
        self._commit_index = {
            id(c.variant): (c, rec) for c, rec in entries}
        # checkpoints taken before the repartition layer existed
        self.__dict__.setdefault("window_demand", None)
        self.__dict__.setdefault("energy_model", None)
        # checkpoints taken before the preemption/migration subsystem
        self.__dict__.setdefault("n_preempted_total", 0)
        self.__dict__.setdefault("n_migrated_total", 0)
        self.__dict__.setdefault("n_lost_total", 0)
        self.__dict__.setdefault("work_credited_total", 0.0)
        self.__dict__.setdefault("loss_reasons", {})

    # -- reporting ------------------------------------------------------------
    def utilization(self, t_from: float, t_to: float) -> Dict[str, float]:
        out = {}
        span = max(t_to - t_from, 1e-9)
        intervals: Dict[str, list] = {
            sid: list(tl.busy()) for sid, tl in self.slices.items()
        }
        for sid, ivs in self.retired_intervals.items():
            intervals.setdefault(sid, []).extend(ivs)
        for sid, ivs in intervals.items():
            busy = sum(max(0.0, min(e, t_to) - max(s, t_from)) for s, e in ivs)
            out[sid] = busy / span
        return out
