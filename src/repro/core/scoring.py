"""Scoring model (paper §4.2, Eqs. 1–4).

Each variant gets a normalized composite score

    Score(v) = λ · h̃(v) + (1 − λ) · f̃_sys(v),          λ ∈ [0, 1]   (Eq. 4)

with feature decompositions

    h̃(v)     = Σ_i α_i φ_i(v),   Σ_i α_i ≤ 1,  φ_i ∈ [0, 1]          (Eq. 2)
    f̃_sys(v) = Σ_j β_j ψ_j(v),   Σ_j β_j ≤ 1,  ψ_j ∈ [0, 1]          (Eq. 3)

so Score(v) ∈ [0, 1] by construction.  The paper's representative features
(φ_JCT, φ_QoS, ψ_energy, ψ_mem_headroom) are implemented below, plus the
system-side utilization/slack features its text describes and the age term of
§4.3 (β_age · A_i(t) folded into f̃_sys).

The scheduler-side evaluation is vectorized over the variant pool; the same
math is mirrored on-device by ``kernels/jasda_score``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from .types import Variant, Window

__all__ = [
    "ScoringPolicy",
    "JobFeatures",
    "SystemFeatures",
    "composite_score",
    "score_pool",
    "score_round",
    "score_round_async",
    "ScoreHandle",
    "job_utility",
    "system_utility",
    "POLICY_QOS_FIRST",
    "POLICY_BALANCED",
    "POLICY_UTILIZATION_FIRST",
]


# ---------------------------------------------------------------------------
# Policy (λ, α, β weights) — Table 2 presets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScoringPolicy:
    """Policy weights governing the job/system trade-off (paper Table 2).

    ``alphas`` weight job-side features φ_i, ``betas`` weight system-side
    features ψ_j.  Weights must be non-negative with Σα ≤ 1, Σβ ≤ 1 so the
    composite score stays in [0, 1].
    """

    lam: float = 0.5  # λ
    alphas: Mapping[str, float] = field(
        default_factory=lambda: {"jct": 0.5, "qos": 0.3, "progress": 0.2}
    )
    betas: Mapping[str, float] = field(
        default_factory=lambda: {
            "utilization": 0.4,
            "slack": 0.2,
            "mem_headroom": 0.1,
            "energy": 0.1,
            "age": 0.2,
        }
    )

    def __post_init__(self):
        if not (0.0 <= self.lam <= 1.0):
            raise ValueError(f"lambda must be in [0,1], got {self.lam}")
        for name, w in list(self.alphas.items()) + list(self.betas.items()):
            if w < 0:
                raise ValueError(f"negative weight {name}={w}")
        if sum(self.alphas.values()) > 1.0 + 1e-9:
            raise ValueError("sum(alpha) must be <= 1")
        if sum(self.betas.values()) > 1.0 + 1e-9:
            raise ValueError("sum(beta) must be <= 1")

    @property
    def beta_age(self) -> float:
        return self.betas.get("age", 0.0)

    def replace(self, **kw) -> "ScoringPolicy":
        return dataclasses.replace(self, **kw)


# Pools smaller than this score on host numpy when impl is unset: one jnp /
# Pallas dispatch costs more than the whole matmul at these sizes.
SMALL_POOL_M = 256

# Table 2 presets.
POLICY_QOS_FIRST = ScoringPolicy(lam=0.7)
POLICY_BALANCED = ScoringPolicy(lam=0.5)
POLICY_UTILIZATION_FIRST = ScoringPolicy(lam=0.3)


# ---------------------------------------------------------------------------
# Job-side features φ_i(v) ∈ [0,1]  (declared by the job)
# ---------------------------------------------------------------------------


class JobFeatures:
    """Reference implementations of the paper's job-side features.

    Jobs *declare* these (they may misreport — that is what §4.2.1 verifies);
    the functions here are what an honest job computes.
    """

    @staticmethod
    def jct(delta_jct: float, delta_jct_max: float) -> float:
        """φ_JCT = 1 − ΔJCT/ΔJCT_max : earlier expected completion → higher."""
        if delta_jct_max <= 0:
            return 1.0
        return float(np.clip(1.0 - delta_jct / delta_jct_max, 0.0, 1.0))

    @staticmethod
    def qos(meets_qos: bool) -> float:
        """φ_QoS = 1[meets QoS]."""
        return 1.0 if meets_qos else 0.0

    @staticmethod
    def progress(work_in_variant: float, work_remaining: float) -> float:
        """Fraction of the job's remaining work covered by this variant."""
        if work_remaining <= 0:
            return 1.0
        return float(np.clip(work_in_variant / work_remaining, 0.0, 1.0))


# ---------------------------------------------------------------------------
# System-side features ψ_j(v) ∈ [0,1]  (computed by the scheduler)
# ---------------------------------------------------------------------------


class SystemFeatures:
    @staticmethod
    def utilization(variant: Variant, window: Window) -> float:
        """ψ_util: fraction of the announced window the variant occupies."""
        if window.duration <= 0:
            return 0.0
        return float(np.clip(variant.duration / window.duration, 0.0, 1.0))

    @staticmethod
    def slack(variant: Variant, window: Window) -> float:
        """ψ_slack: 1 − normalized dead time the variant leaves *before* it.

        Variants that start right at the window start leave no leading gap
        (which could otherwise be unfillable), hence score 1.
        """
        if window.duration <= 0:
            return 1.0
        lead = (variant.t_start - window.t_min) / window.duration
        return float(np.clip(1.0 - lead, 0.0, 1.0))

    @staticmethod
    def mem_headroom(variant: Variant, window: Window, *, grid: int = 32) -> float:
        """ψ_mem_headroom = E[(c_k − RAM_i(t)) / c_k] over I(v)  (paper §4.2)."""
        if window.capacity <= 0:
            return 0.0
        mu, _ = variant.fmp.grid(grid)
        headroom = (window.capacity - mu) / window.capacity
        return float(np.clip(np.mean(headroom), 0.0, 1.0))

    @staticmethod
    def energy(energy_joules: float, energy_max: float) -> float:
        """ψ_energy = 1 − E(v)/E_max."""
        if energy_max <= 0:
            return 1.0
        return float(np.clip(1.0 - energy_joules / energy_max, 0.0, 1.0))


# ---------------------------------------------------------------------------
# Composite scoring (Eq. 4) — scalar and pooled/vectorized forms
# ---------------------------------------------------------------------------


def job_utility(features: Mapping[str, float], policy: ScoringPolicy) -> float:
    """h̃(v) = Σ α_i φ_i(v) over the features the variant declares."""
    total = 0.0
    for name, alpha in policy.alphas.items():
        phi = float(features.get(name, 0.0))
        if not (-1e-9 <= phi <= 1.0 + 1e-9):
            raise ValueError(f"feature {name}={phi} outside [0,1]")
        total += alpha * np.clip(phi, 0.0, 1.0)
    return float(total)


def system_utility(
    variant: Variant,
    window: Window,
    policy: ScoringPolicy,
    *,
    age: float = 0.0,
    extra: Optional[Mapping[str, float]] = None,
) -> float:
    """f̃_sys(v) = Σ β_j ψ_j(v) + β_age · A_i(t)   (paper §4.2 + §4.3)."""
    psis: Dict[str, float] = {
        "utilization": SystemFeatures.utilization(variant, window),
        "slack": SystemFeatures.slack(variant, window),
        "mem_headroom": SystemFeatures.mem_headroom(variant, window),
        "age": float(np.clip(age, 0.0, 1.0)),
    }
    if extra:
        psis.update({k: float(np.clip(v, 0.0, 1.0)) for k, v in extra.items()})
    total = 0.0
    for name, beta in policy.betas.items():
        total += beta * psis.get(name, 0.0)
    return float(total)


def composite_score(h_tilde: float, f_sys: float, lam: float) -> float:
    """Eq. 4: Score(v) = λ h̃ + (1−λ) f̃_sys, guaranteed ∈ [0,1]."""
    s = lam * h_tilde + (1.0 - lam) * f_sys
    return float(np.clip(s, 0.0, 1.0))


def score_pool(
    variants: Sequence[Variant],
    window: Window,
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    extra_sys: Optional[Callable[[Variant], Mapping[str, float]]] = None,
) -> np.ndarray:
    """Score every variant in the pool (Algorithm 1, lines 6–8).

    ``calibrate`` is the §4.2.1 hook: it maps the *declared* h̃(v) to the
    calibrated ĥ(v) (e.g. via ``calibration.Calibrator.calibrate``).
    ``ages`` maps job_id → A_i(t) ∈ [0,1].
    """
    ages = ages or {}
    out = np.zeros(len(variants), dtype=np.float64)
    for idx, v in enumerate(variants):
        h = v.local_utility
        if calibrate is not None:
            h = calibrate(v, h)
        f = system_utility(
            v,
            window,
            policy,
            age=ages.get(v.job_id, 0.0),
            extra=extra_sys(v) if extra_sys else None,
        )
        out[idx] = composite_score(h, f, policy.lam)
    return out


class ScoreHandle:
    """A possibly in-flight batched scoring dispatch.

    The device paths (jnp reference / Pallas) return jax arrays that
    materialize asynchronously; :meth:`result` blocks only at the host
    boundary.  The pipeline (core/pipeline.py) dispatches round k, overlaps
    host work for round k+1 while the scores are in flight, and settles k
    via ``result()``.  The numpy small-pool path is eager (already a host
    array) so ``result()`` is free.

    Device handles keep the BUCKET-PADDED score array (``m`` marks the real
    pool size, sliced off at ``result()``): the padded shape is what lets
    the fused settle dispatch (``core.wis.RoundSelector.predispatch``)
    gather selection weights from :attr:`device_scores` without a per-pool-
    size retrace — pool indices are always < m ≤ m_pad, so padding never
    leaks into a selection.
    """

    def __init__(self, scores, m: Optional[int] = None, fallback=None,
                 health=None, backend: Optional[str] = None):
        self._scores = scores
        self._m = m
        # host recompute closure (the numpy reference scores) + the sticky
        # health to notify: an ASYNC device failure only surfaces when the
        # in-flight array materializes, so result() is the last line of the
        # degradation ladder
        self._fallback = fallback
        self._health = health
        self._backend = backend

    @property
    def in_flight(self) -> bool:
        """True while the scores are still device-side (worth overlapping)."""
        return not isinstance(self._scores, np.ndarray)

    @property
    def device_scores(self):
        """The raw (possibly padded, possibly in-flight) scores array."""
        return self._scores

    def result(self) -> np.ndarray:
        if not isinstance(self._scores, np.ndarray):
            try:
                # np.asarray on a jax array blocks until the computation lands
                arr = np.asarray(self._scores, dtype=np.float64)
                self._scores = arr[: self._m] if self._m is not None else arr
            except Exception as exc:
                if self._fallback is None:
                    raise
                # device died after the async launch: degrade to the host
                # recompute and make the failure sticky so the NEXT round
                # never dispatches on this backend again
                if self._health is not None and self._backend is not None:
                    self._health.mark_failed(
                        self._backend, f"in-flight materialize: {exc}")
                self._scores = np.asarray(self._fallback(), np.float64)
        return self._scores


def score_round_async(
    variants: Sequence[Variant],
    windows: Sequence[Window],
    win_idx,
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    impl: Optional[str] = None,
    grid: int = 32,
    recheck_theta: Optional[float] = None,
    per_agent_theta: bool = False,
    grid_cache=None,
    view=None,
    mesh=None,
    health=None,
) -> ScoreHandle:
    """Pack + dispatch one pooled round; return without blocking on scores.

    Same contract as :func:`score_round` but the device computation is left
    in flight (JAX async dispatch): call ``.result()`` on the returned
    :class:`ScoreHandle` to materialize.  This is the dispatch half the
    round pipeline overlaps with the next round's host-side work.
    ``view`` (types.PoolView aligned with ``variants``) skips the remaining
    per-variant python walks when the caller already built one.
    ``mesh`` (``launch.mesh.make_auction_mesh``) shards the pooled bid axis
    of the device dispatch across devices — byte-identical scores, ignored
    by the host numpy path.
    """
    m = len(variants)
    if m == 0:
        return ScoreHandle(np.zeros(0, dtype=np.float64))
    # lazy import: keeps the numpy-only control plane importable without jax
    from ..kernels.jasda_score.ops import pool_to_arrays_round

    if calibrate is None and view is not None:
        h = view.local_utility  # already a float64 column; no python walk
    else:
        h = np.empty(m, dtype=np.float64)
        for i, v in enumerate(variants):
            h[i] = calibrate(v, v.local_utility) if calibrate is not None else v.local_utility
    # θ precedence: a scheduler-wide recheck_theta overrides the per-agent
    # bounds; per_agent_theta alone gathers each bid's OWN declared θ
    # (Variant.theta, set from AgentConfig.theta at generation) into
    # PackedRound.thetas so heterogeneous agents recheck heterogeneously.
    recheck = recheck_theta is not None or per_agent_theta
    if recheck_theta is not None:
        theta = recheck_theta
    elif per_agent_theta:
        theta = (view.thetas if view is not None
                 else np.asarray([v.theta for v in variants], np.float64))
    else:
        theta = 1.0
    packed = pool_to_arrays_round(
        variants, windows, np.asarray(win_idx), policy,
        h=h, ages=ages, grid=grid, pack_grids=recheck,
        theta=theta, cache=grid_cache,
        view=view,
    )
    def _numpy_scores() -> np.ndarray:
        # host float64 reference: the ladder's last rung, also the small-
        # pool fast path.  Ranks match the legacy per-window path.
        if recheck:
            from ..kernels.jasda_score.ops import score_variants_numpy

            scores, _, _ = score_variants_numpy(
                packed.fj, packed.fs, packed.alphas, packed.betas,
                packed.mu, packed.sg,
                lam=policy.lam, capacity=packed.caps, theta=packed.thetas,
            )
            return np.asarray(scores, np.float64)
        hh = np.clip(packed.fj @ packed.alphas, 0.0, 1.0)
        ff = np.clip(packed.fs @ packed.betas, 0.0, 1.0)
        return policy.lam * hh + (1.0 - policy.lam) * ff

    if impl is None and m < SMALL_POOL_M:
        # device-dispatch overhead dominates tiny pools; same math on host
        impl = "numpy"
    dev_impl = impl
    if dev_impl is not None and dev_impl != "numpy" and health is not None:
        dev_impl = health.resolve(dev_impl)
    if dev_impl is None and health is not None:
        # resolve the auto choice so sticky failures steer it too
        import jax

        dev_impl = health.resolve(
            "pallas" if jax.default_backend() == "tpu" else "ref")
    if dev_impl == "numpy":
        return ScoreHandle(_numpy_scores())

    from ..kernels.common import KernelDispatchError
    from ..kernels.jasda_score.ops import score_variants

    # trim=False keeps the bucket-padded device array on the handle: the
    # fused settle dispatch gathers weights from it shape-stably (padded
    # rows are self-masking, and result() slices back to m on the host).
    # With a BackendHealth attached the dispatch walks the degradation
    # ladder: a failing backend is marked sick (sticky) and the round
    # re-dispatches one rung down, bottoming out at the host numpy path.
    while True:
        try:
            scores, _, _ = score_variants(
                packed.fj, packed.fs, packed.alphas, packed.betas,
                packed.mu, packed.sg,
                lam=policy.lam,
                capacity=packed.caps if recheck else 1.0,
                theta=packed.thetas if recheck else 1.0,
                impl=dev_impl,
                trim=False,
                mesh=mesh,
            )
            return ScoreHandle(scores, m=m, fallback=_numpy_scores,
                               health=health, backend=dev_impl)
        except KernelDispatchError as exc:
            if health is None:
                raise
            health.mark_failed(exc.backend, str(exc))
            dev_impl = health.resolve(exc.backend)
            if dev_impl == "numpy":
                return ScoreHandle(_numpy_scores())


def score_round(
    variants: Sequence[Variant],
    windows: Sequence[Window],
    win_idx,
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    impl: Optional[str] = None,
    grid: int = 32,
    recheck_theta: Optional[float] = None,
    per_agent_theta: bool = False,
    grid_cache=None,
    view=None,
    mesh=None,
) -> np.ndarray:
    """Score a pooled ROUND of bids with ONE batched dispatch (Eq. 4).

    Semantically equivalent to running :func:`score_pool` per window over
    each window's sub-pool, but the union of all bids is packed into
    struct-of-arrays (``kernels/jasda_score.pool_to_arrays_round``) and
    scored in a single vectorized call — the Pallas kernel on TPU, the jnp
    reference elsewhere (``impl`` forces a path).  Calibration (§4.2.1) is a
    host-side per-job transform, applied before packing.

    Safety (condition (a)) was already enforced at variant generation; pass
    ``recheck_theta`` to RE-verify it in-dispatch against each bid's OWN
    window capacity (per-variant capacities, heterogeneous slices): unsafe
    variants score 0 and never enter clearing.  ``per_agent_theta=True``
    rechecks against each bid's OWN agent θ (``Variant.theta``) instead of
    one scheduler-wide bound; an explicit ``recheck_theta`` overrides it.
    All three backends (numpy / jnp ref / Pallas) implement identical
    recheck semantics.

    ``win_idx[i]`` gives the index into ``windows`` that variant i bids on.
    ``impl``: None = auto (host numpy below ``SMALL_POOL_M`` bids, else
    Pallas on TPU / jnp reference), or "numpy" | "ref" | "pallas" to force.
    ``grid_cache`` optionally reuses FMP grid discretizations across rounds
    (see ``kernels.jasda_score.ops.FMPGridCache``).
    Returns float scores aligned with ``variants``.
    """
    return score_round_async(
        variants, windows, win_idx, policy,
        ages=ages, calibrate=calibrate, impl=impl, grid=grid,
        recheck_theta=recheck_theta, per_agent_theta=per_agent_theta,
        grid_cache=grid_cache, view=view, mesh=mesh,
    ).result()
