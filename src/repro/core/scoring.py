"""Scoring model (paper §4.2, Eqs. 1–4).

Each variant gets a normalized composite score

    Score(v) = λ · h̃(v) + (1 − λ) · f̃_sys(v),          λ ∈ [0, 1]   (Eq. 4)

with feature decompositions

    h̃(v)     = Σ_i α_i φ_i(v),   Σ_i α_i ≤ 1,  φ_i ∈ [0, 1]          (Eq. 2)
    f̃_sys(v) = Σ_j β_j ψ_j(v),   Σ_j β_j ≤ 1,  ψ_j ∈ [0, 1]          (Eq. 3)

so Score(v) ∈ [0, 1] by construction.  The paper's representative features
(φ_JCT, φ_QoS, ψ_energy, ψ_mem_headroom) are implemented below, plus the
system-side utilization/slack features its text describes and the age term of
§4.3 (β_age · A_i(t) folded into f̃_sys).

The scheduler-side evaluation is vectorized over the variant pool; the same
math is mirrored on-device by ``kernels/jasda_score``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from .types import Variant, Window

__all__ = [
    "ScoringPolicy",
    "JobFeatures",
    "SystemFeatures",
    "composite_score",
    "score_pool",
    "job_utility",
    "system_utility",
    "POLICY_QOS_FIRST",
    "POLICY_BALANCED",
    "POLICY_UTILIZATION_FIRST",
]


# ---------------------------------------------------------------------------
# Policy (λ, α, β weights) — Table 2 presets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScoringPolicy:
    """Policy weights governing the job/system trade-off (paper Table 2).

    ``alphas`` weight job-side features φ_i, ``betas`` weight system-side
    features ψ_j.  Weights must be non-negative with Σα ≤ 1, Σβ ≤ 1 so the
    composite score stays in [0, 1].
    """

    lam: float = 0.5  # λ
    alphas: Mapping[str, float] = field(
        default_factory=lambda: {"jct": 0.5, "qos": 0.3, "progress": 0.2}
    )
    betas: Mapping[str, float] = field(
        default_factory=lambda: {
            "utilization": 0.4,
            "slack": 0.2,
            "mem_headroom": 0.1,
            "energy": 0.1,
            "age": 0.2,
        }
    )

    def __post_init__(self):
        if not (0.0 <= self.lam <= 1.0):
            raise ValueError(f"lambda must be in [0,1], got {self.lam}")
        for name, w in list(self.alphas.items()) + list(self.betas.items()):
            if w < 0:
                raise ValueError(f"negative weight {name}={w}")
        if sum(self.alphas.values()) > 1.0 + 1e-9:
            raise ValueError("sum(alpha) must be <= 1")
        if sum(self.betas.values()) > 1.0 + 1e-9:
            raise ValueError("sum(beta) must be <= 1")

    @property
    def beta_age(self) -> float:
        return self.betas.get("age", 0.0)

    def replace(self, **kw) -> "ScoringPolicy":
        return dataclasses.replace(self, **kw)


# Table 2 presets.
POLICY_QOS_FIRST = ScoringPolicy(lam=0.7)
POLICY_BALANCED = ScoringPolicy(lam=0.5)
POLICY_UTILIZATION_FIRST = ScoringPolicy(lam=0.3)


# ---------------------------------------------------------------------------
# Job-side features φ_i(v) ∈ [0,1]  (declared by the job)
# ---------------------------------------------------------------------------


class JobFeatures:
    """Reference implementations of the paper's job-side features.

    Jobs *declare* these (they may misreport — that is what §4.2.1 verifies);
    the functions here are what an honest job computes.
    """

    @staticmethod
    def jct(delta_jct: float, delta_jct_max: float) -> float:
        """φ_JCT = 1 − ΔJCT/ΔJCT_max : earlier expected completion → higher."""
        if delta_jct_max <= 0:
            return 1.0
        return float(np.clip(1.0 - delta_jct / delta_jct_max, 0.0, 1.0))

    @staticmethod
    def qos(meets_qos: bool) -> float:
        """φ_QoS = 1[meets QoS]."""
        return 1.0 if meets_qos else 0.0

    @staticmethod
    def progress(work_in_variant: float, work_remaining: float) -> float:
        """Fraction of the job's remaining work covered by this variant."""
        if work_remaining <= 0:
            return 1.0
        return float(np.clip(work_in_variant / work_remaining, 0.0, 1.0))


# ---------------------------------------------------------------------------
# System-side features ψ_j(v) ∈ [0,1]  (computed by the scheduler)
# ---------------------------------------------------------------------------


class SystemFeatures:
    @staticmethod
    def utilization(variant: Variant, window: Window) -> float:
        """ψ_util: fraction of the announced window the variant occupies."""
        if window.duration <= 0:
            return 0.0
        return float(np.clip(variant.duration / window.duration, 0.0, 1.0))

    @staticmethod
    def slack(variant: Variant, window: Window) -> float:
        """ψ_slack: 1 − normalized dead time the variant leaves *before* it.

        Variants that start right at the window start leave no leading gap
        (which could otherwise be unfillable), hence score 1.
        """
        if window.duration <= 0:
            return 1.0
        lead = (variant.t_start - window.t_min) / window.duration
        return float(np.clip(1.0 - lead, 0.0, 1.0))

    @staticmethod
    def mem_headroom(variant: Variant, window: Window, *, grid: int = 32) -> float:
        """ψ_mem_headroom = E[(c_k − RAM_i(t)) / c_k] over I(v)  (paper §4.2)."""
        if window.capacity <= 0:
            return 0.0
        mu, _ = variant.fmp.grid(grid)
        headroom = (window.capacity - mu) / window.capacity
        return float(np.clip(np.mean(headroom), 0.0, 1.0))

    @staticmethod
    def energy(energy_joules: float, energy_max: float) -> float:
        """ψ_energy = 1 − E(v)/E_max."""
        if energy_max <= 0:
            return 1.0
        return float(np.clip(1.0 - energy_joules / energy_max, 0.0, 1.0))


# ---------------------------------------------------------------------------
# Composite scoring (Eq. 4) — scalar and pooled/vectorized forms
# ---------------------------------------------------------------------------


def job_utility(features: Mapping[str, float], policy: ScoringPolicy) -> float:
    """h̃(v) = Σ α_i φ_i(v) over the features the variant declares."""
    total = 0.0
    for name, alpha in policy.alphas.items():
        phi = float(features.get(name, 0.0))
        if not (-1e-9 <= phi <= 1.0 + 1e-9):
            raise ValueError(f"feature {name}={phi} outside [0,1]")
        total += alpha * np.clip(phi, 0.0, 1.0)
    return float(total)


def system_utility(
    variant: Variant,
    window: Window,
    policy: ScoringPolicy,
    *,
    age: float = 0.0,
    extra: Optional[Mapping[str, float]] = None,
) -> float:
    """f̃_sys(v) = Σ β_j ψ_j(v) + β_age · A_i(t)   (paper §4.2 + §4.3)."""
    psis: Dict[str, float] = {
        "utilization": SystemFeatures.utilization(variant, window),
        "slack": SystemFeatures.slack(variant, window),
        "mem_headroom": SystemFeatures.mem_headroom(variant, window),
        "age": float(np.clip(age, 0.0, 1.0)),
    }
    if extra:
        psis.update({k: float(np.clip(v, 0.0, 1.0)) for k, v in extra.items()})
    total = 0.0
    for name, beta in policy.betas.items():
        total += beta * psis.get(name, 0.0)
    return float(total)


def composite_score(h_tilde: float, f_sys: float, lam: float) -> float:
    """Eq. 4: Score(v) = λ h̃ + (1−λ) f̃_sys, guaranteed ∈ [0,1]."""
    s = lam * h_tilde + (1.0 - lam) * f_sys
    return float(np.clip(s, 0.0, 1.0))


def score_pool(
    variants: Sequence[Variant],
    window: Window,
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    extra_sys: Optional[Callable[[Variant], Mapping[str, float]]] = None,
) -> np.ndarray:
    """Score every variant in the pool (Algorithm 1, lines 6–8).

    ``calibrate`` is the §4.2.1 hook: it maps the *declared* h̃(v) to the
    calibrated ĥ(v) (e.g. via ``calibration.Calibrator.calibrate``).
    ``ages`` maps job_id → A_i(t) ∈ [0,1].
    """
    ages = ages or {}
    out = np.zeros(len(variants), dtype=np.float64)
    for idx, v in enumerate(variants):
        h = v.local_utility
        if calibrate is not None:
            h = calibrate(v, h)
        f = system_utility(
            v,
            window,
            policy,
            age=ages.get(v.job_id, 0.0),
            extra=extra_sys(v) if extra_sys else None,
        )
        out[idx] = composite_score(h, f, policy.lam)
    return out
