"""Temporal fairness / age-aware prioritization (paper §4.3).

A_i(t) ∈ [0,1] is a normalized, non-decreasing function of the waiting time
since job J_i last had any variant selected.  It enters the system-side score
as β_age · A_i(t) (see scoring.system_utility), gradually promoting deferred
jobs without a hard completion-time bound — exactly the paper's semantics.

We provide the age curve as a saturating exponential (smooth, bounded,
monotone; its time constant controls how fast starvation pressure builds)
plus linear and step alternatives for ablation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

__all__ = ["AgePolicy", "AgeTracker", "jain_index"]


@dataclass(frozen=True)
class AgePolicy:
    """Age curve A(wait) with saturation scale ``tau`` (time units)."""

    tau: float = 60.0
    kind: str = "exp"  # exp | linear | step

    def age(self, waiting: float) -> float:
        w = max(0.0, waiting)
        if self.kind == "exp":
            return 1.0 - math.exp(-w / max(self.tau, 1e-9))
        if self.kind == "linear":
            return min(1.0, w / max(self.tau, 1e-9))
        if self.kind == "step":
            return 1.0 if w >= self.tau else 0.0
        raise ValueError(f"unknown age kind {self.kind}")


class AgeTracker:
    """Tracks per-job last-selection times and produces A_i(t)."""

    def __init__(self, policy: AgePolicy = AgePolicy()):
        self.policy = policy
        self._last_selected: Dict[str, float] = {}

    def register_arrival(self, job_id: str, t: float) -> None:
        # a job that has never been selected ages from its arrival
        self._last_selected.setdefault(job_id, t)

    def mark_selected(self, job_id: str, t: float) -> None:
        self._last_selected[job_id] = t

    def remove(self, job_id: str) -> None:
        self._last_selected.pop(job_id, None)

    def age(self, job_id: str, t: float) -> float:
        last = self._last_selected.get(job_id)
        if last is None:
            return 0.0
        return self.policy.age(t - last)

    def ages(self, t: float) -> Dict[str, float]:
        return {j: self.policy.age(t - last) for j, last in self._last_selected.items()}


def jain_index(values) -> float:
    """Jain's fairness index over per-job outcomes (1 = perfectly fair)."""
    x = np.asarray(list(values), dtype=np.float64)
    x = x[np.isfinite(x)]
    if x.size == 0:
        return 1.0
    denom = x.size * np.sum(x * x)
    if denom <= 0:
        return 1.0
    return float(np.sum(x) ** 2 / denom)
