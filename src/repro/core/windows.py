"""Window derivation and announcement (paper §3.1, §5.1(c)) — round model.

The scheduler maintains a per-slice time–capacity map (committed execution
intervals) and derives contiguous idle gaps.  One **auction round** announces
ALL eligible gaps across all slices at once (:func:`announce_windows`); the
``WindowPolicy`` kinds are *orderings* over that set rather than single
picks:

* ``earliest``   — earliest start time first (the paper prototype's default,
                   "minimizing latency between announcement and generation").
* ``largest``    — largest gap first (fragmentation-averse).
* ``best_fit``   — smallest gap that still admits τ_min work first (packs
                   tight gaps before they expire).
* ``slack``      — gaps on the idlest slice in the horizon first.
* ``frag_aware`` — tightest capacity fit against the pending pool's
                   capacity-demand histogram first (anti-fragmentation:
                   big windows are not nibbled by jobs a small window
                   could serve).  The demand histogram is supplied by the
                   repartition layer (``core/repartition.py``); with no
                   demand attached the ordering degrades to
                   capacity-ascending (smallest slices first).

:func:`announce_window` (the legacy single-window API, paper A3: one w* per
iteration) is kept as the head of the same ordering and backs the
scheduler's ``step()`` compatibility wrapper.

Window announcement respects a preparation offset (§5.1(a) mitigation (i)):
announced windows start at least ``announce_offset`` after "now" so jobs have
time to generate variants.

Announced-but-unfilled windows are suppressed for a cooldown via
:class:`DeadWindowRegistry`, which matches window starts with an epsilon
tolerance — releases and early finishes perturb gap boundaries by float
drift, and an exact (slice_id, t_min) key would resurrect a dead window the
moment its start moved by 1e-12.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from .types import DEAD_WINDOW_EPS, SliceSpec, Window

__all__ = [
    "SliceTimeline",
    "WindowPolicy",
    "DeadWindowRegistry",
    "announce_window",
    "announce_windows",
]


class SliceTimeline:
    """Committed busy intervals on one slice, kept sorted and merged."""

    def __init__(self, spec: SliceSpec):
        self.spec = spec
        # disjoint, sorted busy intervals [(start, end)]
        self._busy: List[Tuple[float, float]] = []

    # -- mutation -----------------------------------------------------------
    def commit(self, start: float, end: float) -> None:
        if end <= start:
            raise ValueError("empty commitment")
        i = bisect.bisect_left(self._busy, (start, end))
        # check neighbours for overlap (commitments must be conflict-free)
        for j in (i - 1, i):
            if 0 <= j < len(self._busy):
                s, e = self._busy[j]
                if start < e - 1e-12 and s < end - 1e-12:
                    raise ValueError(
                        f"overlapping commitment [{start},{end}) on {self.spec.slice_id}"
                    )
        self._busy.insert(i, (start, end))
        self._merge()

    def release(self, start: float, end: float) -> None:
        """Carve [start, end) out of the busy set (failure / early finish).

        Implemented as interval subtraction: adjacent commitments may have
        been merged, so exact-match removal would be incorrect.
        """
        out: List[Tuple[float, float]] = []
        for s, e in self._busy:
            if e <= start + 1e-12 or s >= end - 1e-12:
                out.append((s, e))
                continue
            if s < start - 1e-12:
                out.append((s, start))
            if e > end + 1e-12:
                out.append((end, e))
        self._busy = out

    def _merge(self) -> None:
        merged: List[Tuple[float, float]] = []
        for s, e in self._busy:
            if merged and s <= merged[-1][1] + 1e-12:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        self._busy = merged

    # -- queries --------------------------------------------------------------
    def busy(self) -> Sequence[Tuple[float, float]]:
        return tuple(self._busy)

    def gaps(self, t_from: float, horizon: float) -> List[Tuple[float, float]]:
        """Idle [start, end) intervals within [t_from, t_from + horizon)."""
        t_end = t_from + horizon
        out: List[Tuple[float, float]] = []
        cur = t_from
        for s, e in self._busy:
            if e <= t_from:
                continue
            if s >= t_end:
                break
            if s > cur:
                out.append((cur, min(s, t_end)))
            cur = max(cur, e)
        if cur < t_end:
            out.append((cur, t_end))
        return [(s, e) for s, e in out if e - s > 1e-12]

    def idle_fraction(self, t_from: float, horizon: float) -> float:
        idle = sum(e - s for s, e in self.gaps(t_from, horizon))
        return idle / horizon if horizon > 0 else 0.0

    def busy_until(self, t: float) -> float:
        """End of the interval covering t (t itself if idle)."""
        for s, e in self._busy:
            if s <= t < e:
                return e
        return t


@dataclass(frozen=True)
class WindowPolicy:
    kind: str = "earliest"  # earliest | largest | best_fit | slack | frag_aware
    horizon: float = 1000.0  # lookahead for gap derivation
    announce_offset: float = 0.0  # §5.1(a)(i): bid-preparation time offset
    min_gap: float = 1.0  # don't announce gaps shorter than this (≈ τ_min)


class DeadWindowRegistry:
    """Announced-but-unfilled windows suppressed until a cooldown expires.

    Matching is epsilon-tolerant on the window start: a gap whose boundary
    drifted by float noise (release / early finish / re-merge) is still the
    same dead window.

    Invariants the round pipeline (core/pipeline.py) relies on:

    * settling a round only ever ADDS suppressions (``add``) — it never
      resurrects a window — so a speculative announcement can be validated
      by re-checking ``suppressed`` per window and *filtering*, without
      re-deriving gaps;
    * ``prune`` is deterministic in ``(registry state, now)`` and
      idempotent at a fixed ``now``, so speculative preparation may prune
      early for the next round's timestamp without changing what a serial
      preparation at that timestamp would see.
    """

    def __init__(self, eps: float = DEAD_WINDOW_EPS):
        self.eps = eps
        # slice_id -> [(t_min, expiry)]
        self._entries: Dict[str, List[Tuple[float, float]]] = {}

    def add(self, slice_id: str, t_min: float, expiry: float) -> None:
        entries = self._entries.setdefault(slice_id, [])
        for i, (t, _) in enumerate(entries):
            if abs(t - t_min) <= self.eps:
                entries[i] = (t, max(entries[i][1], expiry))
                return
        entries.append((t_min, expiry))

    def prune(self, now: float) -> None:
        for sid in list(self._entries):
            kept = [(t, e) for t, e in self._entries[sid] if e > now]
            if kept:
                self._entries[sid] = kept
            else:
                del self._entries[sid]

    def suppressed(self, slice_id: str, t_min: float) -> bool:
        return any(
            abs(t - t_min) <= self.eps for t, _ in self._entries.get(slice_id, ())
        )

    def drop_slice(self, slice_id: str) -> int:
        """Retire every entry for a slice that permanently left the pool.

        ``prune`` only shrinks entries by expiry, so repeated slice
        birth/death (repartition split/merge cycles reuse canonical slice
        ids) would otherwise accumulate suppressions that wrongly mute a
        NEW slice born under the same id.  Returns the number of entries
        dropped.
        """
        return len(self._entries.pop(slice_id, ()))

    def __len__(self) -> int:
        return sum(len(v) for v in self._entries.values())

    def clear(self) -> None:
        self._entries.clear()


ExcludeLike = Union[None, DeadWindowRegistry, Set[Tuple[str, float]]]


def _is_excluded(exclude: ExcludeLike, slice_id: str, t_min: float) -> bool:
    if exclude is None:
        return False
    if isinstance(exclude, DeadWindowRegistry):
        return exclude.suppressed(slice_id, t_min)
    # legacy float-keyed set (kept for external callers)
    return (slice_id, round(t_min, 9)) in exclude


def _tight_fit(capacity: float, demand: Optional[Sequence[float]]) -> float:
    """Slack between a slice's capacity and the tightest pending demand it
    can serve (``capacity`` itself when no demand fits: such a slice is
    smaller than every floor, so announcing it early risks stranding
    nothing — it competes on raw capacity against the fit slacks)."""
    if demand:
        fits = [capacity - d for d in demand if d <= capacity]
        if fits:
            return min(fits)
    return capacity


def announce_windows(
    slices: Dict[str, SliceTimeline],
    now: float,
    policy: WindowPolicy,
    *,
    exclude: ExcludeLike = None,
    demand: Optional[Sequence[float]] = None,
) -> List[Window]:
    """All eligible windows for this round, ordered by the policy key.

    Every idle gap of at least ``min_gap`` across every slice within the
    horizon becomes a window; the ``policy.kind`` determines the *order* the
    windows are presented in (ties broken by start time, then slice id, so
    the ordering is deterministic across runs).

    ``demand`` is the pending pool's capacity-demand histogram (a sequence
    of ``min_capacity`` requirements in bytes) and only affects the
    ``frag_aware`` ordering; all other kinds ignore it, so their keys are
    unchanged by its presence.
    """
    t0 = now + policy.announce_offset
    candidates: List[Tuple[tuple, Window]] = []  # (policy key, window)
    for sid in sorted(slices):
        tl = slices[sid]
        idle = None  # lazily computed once per slice for the "slack" kind
        fit = None  # lazily computed once per slice for "frag_aware"
        for s, e in tl.gaps(t0, policy.horizon):
            if e - s < policy.min_gap:
                continue
            if _is_excluded(exclude, sid, s):
                continue
            if policy.kind == "earliest":
                key = (s, -(e - s), sid)
            elif policy.kind == "largest":
                key = (-(e - s), s, sid)
            elif policy.kind == "best_fit":
                key = (e - s, s, sid)
            elif policy.kind == "slack":
                if idle is None:
                    idle = tl.idle_fraction(t0, policy.horizon)
                key = (-idle, s, sid)
            elif policy.kind == "frag_aware":
                if fit is None:
                    fit = _tight_fit(tl.spec.capacity_bytes, demand)
                key = (fit, s, -(e - s), sid)
            else:
                raise ValueError(f"unknown window policy {policy.kind}")
            w = Window(slice_id=sid, capacity=tl.spec.capacity_bytes, t_min=s, duration=e - s)
            candidates.append((key, w))
    candidates.sort(key=lambda c: c[0])
    return [w for _, w in candidates]


def announce_window(
    slices: Dict[str, SliceTimeline],
    now: float,
    policy: WindowPolicy,
    *,
    exclude: ExcludeLike = None,
    demand: Optional[Sequence[float]] = None,
) -> Optional[Window]:
    """Pick ONE window (legacy A3 semantics): head of the round ordering.

    Returns None when no gap of at least ``min_gap`` exists in the horizon.
    """
    ws = announce_windows(slices, now, policy, exclude=exclude, demand=demand)
    return ws[0] if ws else None
