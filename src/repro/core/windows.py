"""Window derivation and announcement policies (paper §3.1, §5.1(c)).

The scheduler maintains a per-slice time–capacity map (committed execution
intervals) and derives contiguous idle gaps.  Each JASDA iteration announces
ONE window w* = (s_k, c_k, t_min, Δt) chosen by a pluggable policy:

* ``earliest``   — earliest start time (the paper prototype's default,
                   "minimizing latency between announcement and generation").
* ``largest``    — largest gap first (fragmentation-averse).
* ``best_fit``   — smallest gap that still admits τ_min work (packs tight
                   gaps before they expire).
* ``slack``      — gap whose slice has the most idle fraction in the horizon.

Window announcement respects a preparation offset (§5.1(a) mitigation (i)):
announced windows start at least ``announce_offset`` after "now" so jobs have
time to generate variants.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .types import SliceSpec, Window

__all__ = ["SliceTimeline", "WindowPolicy", "announce_window"]


class SliceTimeline:
    """Committed busy intervals on one slice, kept sorted and merged."""

    def __init__(self, spec: SliceSpec):
        self.spec = spec
        # disjoint, sorted busy intervals [(start, end)]
        self._busy: List[Tuple[float, float]] = []

    # -- mutation -----------------------------------------------------------
    def commit(self, start: float, end: float) -> None:
        if end <= start:
            raise ValueError("empty commitment")
        i = bisect.bisect_left(self._busy, (start, end))
        # check neighbours for overlap (commitments must be conflict-free)
        for j in (i - 1, i):
            if 0 <= j < len(self._busy):
                s, e = self._busy[j]
                if start < e - 1e-12 and s < end - 1e-12:
                    raise ValueError(
                        f"overlapping commitment [{start},{end}) on {self.spec.slice_id}"
                    )
        self._busy.insert(i, (start, end))
        self._merge()

    def release(self, start: float, end: float) -> None:
        """Carve [start, end) out of the busy set (failure / early finish).

        Implemented as interval subtraction: adjacent commitments may have
        been merged, so exact-match removal would be incorrect.
        """
        out: List[Tuple[float, float]] = []
        for s, e in self._busy:
            if e <= start + 1e-12 or s >= end - 1e-12:
                out.append((s, e))
                continue
            if s < start - 1e-12:
                out.append((s, start))
            if e > end + 1e-12:
                out.append((end, e))
        self._busy = out

    def _merge(self) -> None:
        merged: List[Tuple[float, float]] = []
        for s, e in self._busy:
            if merged and s <= merged[-1][1] + 1e-12:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        self._busy = merged

    # -- queries --------------------------------------------------------------
    def busy(self) -> Sequence[Tuple[float, float]]:
        return tuple(self._busy)

    def gaps(self, t_from: float, horizon: float) -> List[Tuple[float, float]]:
        """Idle [start, end) intervals within [t_from, t_from + horizon)."""
        t_end = t_from + horizon
        out: List[Tuple[float, float]] = []
        cur = t_from
        for s, e in self._busy:
            if e <= t_from:
                continue
            if s >= t_end:
                break
            if s > cur:
                out.append((cur, min(s, t_end)))
            cur = max(cur, e)
        if cur < t_end:
            out.append((cur, t_end))
        return [(s, e) for s, e in out if e - s > 1e-12]

    def idle_fraction(self, t_from: float, horizon: float) -> float:
        idle = sum(e - s for s, e in self.gaps(t_from, horizon))
        return idle / horizon if horizon > 0 else 0.0

    def busy_until(self, t: float) -> float:
        """End of the interval covering t (t itself if idle)."""
        for s, e in self._busy:
            if s <= t < e:
                return e
        return t


@dataclass(frozen=True)
class WindowPolicy:
    kind: str = "earliest"  # earliest | largest | best_fit | slack
    horizon: float = 1000.0  # lookahead for gap derivation
    announce_offset: float = 0.0  # §5.1(a)(i): bid-preparation time offset
    min_gap: float = 1.0  # don't announce gaps shorter than this (≈ τ_min)


def announce_window(
    slices: Dict[str, SliceTimeline],
    now: float,
    policy: WindowPolicy,
    *,
    exclude: Optional[set] = None,
) -> Optional[Window]:
    """Pick ONE window to announce this iteration (A3: one w* per iteration).

    Returns None when no gap of at least ``min_gap`` exists in the horizon.
    ``exclude`` suppresses windows already announced and left unfilled this
    round-robin pass (avoids re-announcing a dead window forever).
    """
    exclude = exclude or set()
    t0 = now + policy.announce_offset
    candidates: List[Tuple[Window, float]] = []  # (window, policy key)
    for sid, tl in slices.items():
        for s, e in tl.gaps(t0, policy.horizon):
            if e - s < policy.min_gap:
                continue
            w = Window(slice_id=sid, capacity=tl.spec.capacity_bytes, t_min=s, duration=e - s)
            if (sid, round(s, 9)) in exclude:
                continue
            if policy.kind == "earliest":
                key = (s, -(e - s))
            elif policy.kind == "largest":
                key = (-(e - s), s)
            elif policy.kind == "best_fit":
                key = (e - s, s)
            elif policy.kind == "slack":
                key = (-tl.idle_fraction(t0, policy.horizon), s)
            else:
                raise ValueError(f"unknown window policy {policy.kind}")
            candidates.append((w, key))
    if not candidates:
        return None
    candidates.sort(key=lambda c: c[1])
    return candidates[0][0]
