"""Weighted Interval Scheduling (paper §4.4, `SelectBestCompatibleVariants`).

The per-window clearing step: given M candidate variants, each an interval
[t_start, t_end] with weight Score(v) ≥ 0, select the maximum-total-score
subset of pairwise non-overlapping intervals.

Classical DP after sorting by end time — O(M log M):

    p(j) = largest i < j with end_i <= start_j        (binary search)
    dp[j] = max(dp[j-1], w_j + dp[p(j)])

Three per-window implementations:

* :func:`wis_select`       — numpy host path (the scheduler's default).
* :func:`wis_select_jax`   — jit-able JAX path (sort + searchsorted +
                             ``lax.scan`` DP + ``lax.while_loop`` backtrack);
                             mirrored by the Pallas kernel ``kernels/wis_dp``.
* :func:`wis_brute_force`  — O(2^M) oracle for property tests.

Plus the BATCHED multi-window machinery behind the device-resident round
settle (the clearing-side twin of the PR-2 scoring engine):

* :class:`RoundSelector` packs every window's candidate set into a padded
  ``(W, L)`` sorted-lane layout once per round (:meth:`RoundSelector.pack`)
  and clears any subset of windows in ONE dispatch
  (:meth:`RoundSelector.select`), with three backends mirroring
  ``jasda_score``'s contract — host ``numpy`` (float64, byte-identical to
  the per-window loop by construction), jnp ``ref`` and the ``pallas``
  kernel (``kernels/wis_dp``).  Shapes are pow2-bucketed on both dims so
  drifting (W, M) rounds never retrace.
* :meth:`RoundSelector.predispatch` fuses selection behind the round's
  in-flight scoring dispatch (scores never round-trip through the host);
  the returned :class:`SettlePrefetch` materializes at settle time.
* :func:`make_round_selector` maps the ``SchedulerConfig.wis_impl`` knob to
  a selector (None → the historical per-window :func:`wis_select` loop).

Banned lanes are excluded by ZEROING their weights rather than re-packing:
under the strict ``>`` tie rule a zero-weight lane is never taken and its
presence shifts dp indices without changing any dp value, so zero-weight
banning is exactly equivalent to removing the lane (the conflict
resolution loop re-clears dirty windows from the retained buffers).

Intervals are treated as half-open [start, end): touching intervals
(end_i == start_j) are compatible, matching the paper's worked example where
(40,47) and (47,50) are both selected.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .types import OVERLAP_EPS

__all__ = [
    "wis_select",
    "wis_select_jax",
    "wis_brute_force",
    "total_weight",
    "RoundSelector",
    "SettlePrefetch",
    "PackedSettle",
    "make_round_selector",
    "predispatch_settle",
    "wis_select_batch",
]


def _validate(starts, ends, weights):
    starts = np.asarray(starts, dtype=np.float64)
    ends = np.asarray(ends, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    if not (starts.shape == ends.shape == weights.shape):
        raise ValueError("starts/ends/weights must have identical shapes")
    if np.any(ends < starts):
        raise ValueError("interval with end < start")
    if np.any(weights < -1e-12):
        raise ValueError("WIS optimality requires non-negative weights")
    return starts, ends, weights


def wis_select(
    starts: Sequence[float],
    ends: Sequence[float],
    weights: Sequence[float],
) -> Tuple[np.ndarray, float]:
    """Optimal WIS. Returns (selected original indices asc by end, total).

    O(M log M): numpy argsort + searchsorted + a single DP pass.
    """
    starts, ends, weights = _validate(starts, ends, weights)
    m = starts.shape[0]
    if m == 0:
        return np.zeros((0,), dtype=np.int64), 0.0

    order = np.argsort(ends, kind="stable")
    s, e, w = starts[order], ends[order], weights[order]

    # p[j]: number of intervals (in sorted order) ending <= s[j]; dp is
    # 1-indexed with dp[0] = 0 so p[j] indexes dp directly.
    p = np.searchsorted(e, s, side="right")

    dp = np.zeros(m + 1, dtype=np.float64)
    take = np.zeros(m, dtype=bool)
    for j in range(m):
        with_j = w[j] + dp[p[j]]
        if with_j > dp[j]:  # strict: prefer fewer intervals on ties
            dp[j + 1] = with_j
            take[j] = True
        else:
            dp[j + 1] = dp[j]

    # Backtrack.
    sel: List[int] = []
    j = m
    while j > 0:
        if take[j - 1]:
            sel.append(j - 1)
            j = p[j - 1]
        else:
            j -= 1
    sel_sorted = np.array(sel[::-1], dtype=np.int64)
    return order[sel_sorted], float(dp[m])


def wis_brute_force(
    starts: Sequence[float],
    ends: Sequence[float],
    weights: Sequence[float],
) -> Tuple[np.ndarray, float]:
    """Exhaustive oracle (use only for small M in tests)."""
    starts, ends, weights = _validate(starts, ends, weights)
    m = starts.shape[0]
    if m > 22:
        raise ValueError("brute force limited to M <= 22")
    best_mask, best_val = 0, 0.0
    for mask in range(1 << m):
        idx = [i for i in range(m) if mask >> i & 1]
        ok = True
        for a in range(len(idx)):
            for b in range(a + 1, len(idx)):
                i, j = idx[a], idx[b]
                if (starts[i] < ends[j] - OVERLAP_EPS
                        and starts[j] < ends[i] - OVERLAP_EPS):
                    ok = False
                    break
            if not ok:
                break
        if ok:
            val = float(sum(weights[i] for i in idx))
            if val > best_val + 1e-15:
                best_val, best_mask = val, mask
    sel = np.array([i for i in range(m) if best_mask >> i & 1], dtype=np.int64)
    return sel, best_val


def total_weight(weights: Sequence[float], selected: Sequence[int]) -> float:
    w = np.asarray(weights, dtype=np.float64)
    return float(w[np.asarray(selected, dtype=np.int64)].sum()) if len(selected) else 0.0


# ---------------------------------------------------------------------------
# JAX path — jit-able, fixed-size, mask-based (device-resident clearing)
# ---------------------------------------------------------------------------


def wis_select_jax(starts, ends, weights, valid=None):
    """Jit-able WIS over a fixed-size padded pool.

    Args:
      starts, ends, weights: (M,) float arrays (padded entries arbitrary).
      valid: optional (M,) bool mask; invalid entries are excluded.

    Returns:
      (selected_mask (M,) bool in ORIGINAL order, total_score scalar).

    The DP is a ``lax.scan`` over sorted intervals; backtracking is a
    ``lax.while_loop``.  Padded/invalid entries get weight 0 and a
    point-interval at +inf so they never affect the optimum.
    """
    import jax
    import jax.numpy as jnp

    starts = jnp.asarray(starts, dtype=jnp.float32)
    ends = jnp.asarray(ends, dtype=jnp.float32)
    weights = jnp.asarray(weights, dtype=jnp.float32)
    m = starts.shape[0]
    if valid is None:
        valid = jnp.ones((m,), dtype=bool)
    else:
        valid = jnp.asarray(valid, dtype=bool)

    big = jnp.float32(3.0e38)
    s = jnp.where(valid, starts, big)
    e = jnp.where(valid, ends, big)
    w = jnp.where(valid, weights, 0.0)

    order = jnp.argsort(e, stable=True)
    s_o, e_o, w_o = s[order], e[order], w[order]
    p = jnp.searchsorted(e_o, s_o, side="right")  # (M,) into dp[0..M]

    def dp_step(dp, j):
        with_j = w_o[j] + dp[p[j]]
        without_j = dp[j]
        take = with_j > without_j
        dp = dp.at[j + 1].set(jnp.where(take, with_j, without_j))
        return dp, take

    dp0 = jnp.zeros((m + 1,), dtype=jnp.float32)
    dp, take = jax.lax.scan(dp_step, dp0, jnp.arange(m))

    def backtrack(state):
        j, sel = state
        t = take[j - 1]
        sel = sel.at[j - 1].set(t)
        j = jnp.where(t, p[j - 1], j - 1)
        return j, sel

    def cond(state):
        return state[0] > 0

    sel_sorted = jnp.zeros((m,), dtype=bool)
    _, sel_sorted = jax.lax.while_loop(cond, backtrack, (jnp.int32(m), sel_sorted))

    sel_mask = jnp.zeros((m,), dtype=bool).at[order].set(sel_sorted)
    return sel_mask & valid, dp[m]


# ---------------------------------------------------------------------------
# Batched multi-window settle (device-resident clearing, paper §4.4 batched)
# ---------------------------------------------------------------------------

#: smallest jit-shape buckets for the batched dispatch: the window dim and
#: the lane dim both pad to powers of two (one executable per bucket pair)
MIN_ROW_BUCKET = 8
MIN_LANE_BUCKET = 32


def _bucket(n: int, lo: int) -> int:
    return max(lo, 1 << int(np.ceil(np.log2(max(n, 1)))))


class PackedSettle:
    """Retained padded buffers for one round's batched WIS dispatches.

    ``idx_sorted[k, j]`` is the pool index of window k's j-th candidate in
    ascending-end order (−1 on padded lanes); ``pred`` the predecessor
    table over that order; ``wmat`` the float64 selection weights in the
    same layout (0 on pads).  Sort order and predecessors are computed ONCE
    (float64, stable — identical to the per-window host path); banning only
    zeroes weights, so conflict-resolution re-clears re-dispatch straight
    from these buffers.
    """

    __slots__ = ("members", "idx_sorted", "pred", "wmat", "n_windows",
                 "lanes", "row_len", "_pred_rows")

    def __init__(self, members, idx_sorted, pred, wmat):
        self.members = members
        self.idx_sorted = idx_sorted
        self.pred = pred
        self.wmat = wmat
        self.n_windows = idx_sorted.shape[0]
        self.lanes = idx_sorted.shape[1]
        self.row_len = np.fromiter((len(m) for m in members), np.intp,
                                   count=len(members))
        # lazily materialized python predecessor lists (per-row scalar DP)
        self._pred_rows: list = [None] * self.n_windows

    def pred_row(self, k: int) -> list:
        row = self._pred_rows[k]
        if row is None:
            row = self._pred_rows[k] = self.pred[k, : self.row_len[k]].tolist()
        return row

    def fill_weights(self, sel_scores: np.ndarray) -> None:
        """Gather the (sorted-lane) weight matrix from per-pool scores."""
        sel_scores = np.asarray(sel_scores, np.float64)
        if sel_scores.size == 0:
            self.wmat = np.zeros(self.idx_sorted.shape, np.float64)
            return
        safe = np.clip(self.idx_sorted, 0, None)
        self.wmat = np.where(self.idx_sorted >= 0, sel_scores[safe], 0.0)


class SettlePrefetch:
    """An in-flight fused score→clear first pass (see RoundSelector).

    Holds the retained :class:`PackedSettle` plus the device selection mask
    the fused dispatch is computing; :meth:`materialize` blocks at the host
    boundary and returns (first_pass selections, packed buffers) for the
    fixed-point settle to continue from.

    ``transformed`` records whether the dispatch multiplied the gathered
    scores by the policy's selection transform
    (``ClearingPolicy.prefetch_transform``): a transformed prefetch is only
    valid for a settle that SELECTS on the matching transformed scores, and
    vice versa — ``fixed_point_settle`` checks the flag before adopting the
    first pass.
    """

    def __init__(self, packed: PackedSettle, raw_sel, selector: "RoundSelector",
                 transformed: bool = False):
        self.packed = packed
        self._raw = raw_sel
        self.selector = selector
        self.transformed = transformed

    def materialize(self, scores: np.ndarray):
        packed = self.packed
        try:
            sel = np.asarray(self._raw)[: packed.n_windows]
        except Exception as exc:
            # the fused dispatch died IN FLIGHT (device lost after the async
            # launch): surface it as a typed dispatch error so the settle
            # falls back to the unfused path, and mark the backend so the
            # sticky ladder never re-trusts it
            from ..kernels.common import KernelDispatchError

            health = getattr(self.selector, "health", None)
            backend = getattr(self.selector, "impl", "unknown")
            if health is not None and not isinstance(exc, KernelDispatchError):
                health.mark_failed(backend, f"prefetch materialize: {exc}")
            if isinstance(exc, KernelDispatchError):
                raise
            raise KernelDispatchError(
                backend, "settle_prefetch",
                tuple(packed.idx_sorted.shape), cause=exc) from exc
        first_pass = [
            [int(i) for i in packed.idx_sorted[k][np.flatnonzero(sel[k])]]
            for k in range(packed.n_windows)
        ]
        if packed.wmat is None:
            packed.fill_weights(scores)
        return first_pass, packed


def _batch_dp_backtrack_numpy(w: np.ndarray, pred: np.ndarray) -> np.ndarray:
    """Float64 batched DP + backtrack, vectorized across windows.

    Per-row arithmetic is EXACTLY :func:`wis_select`'s DP (same float64
    add and strict ``>``; ``max(dp[j], with_j)`` equals the reference's
    conditional copy bit-for-bit, ties included), so selections are
    byte-identical to the per-window host loop.  The python loop runs once
    per LANE for all windows instead of once per candidate per window, in
    lane-major (transposed) layout with preallocated outputs and flat-index
    gathers so each step is a handful of contiguous (W,)-sized kernels.
    """
    r, m = w.shape
    w_t = np.ascontiguousarray(w.T)  # (m, r): lane-major rows
    dp = np.zeros((m + 1, r), np.float64)
    dp1d = dp.reshape(-1)
    # flat offsets of dp[pred[j, row], row] in lane-major dp
    pf_t = np.ascontiguousarray(pred.T.astype(np.intp) * r
                                + np.arange(r, dtype=np.intp)[None, :])
    take_t = np.empty((m, r), bool)
    with_j = np.empty(r, np.float64)
    for j in range(m):
        np.add(w_t[j], dp1d[pf_t[j]], out=with_j)
        np.greater(with_j, dp[j], out=take_t[j])
        np.maximum(with_j, dp[j], out=dp[j + 1])
    # Backtrack with a skip table: prev_take[row, j] = largest position
    # j' ≤ j whose lane j'−1 was taken (0 if none).  The reference walk
    # decrements the cursor through non-taken stretches before selecting —
    # prev_take collapses each stretch into one gather, so every vectorized
    # iteration lands EXACTLY one selection per active row and the loop
    # runs max-selections-per-row times instead of max-lanes times.
    jj = np.arange(1, m + 1, dtype=np.intp)
    prev_take = np.zeros((r, m + 1), np.intp)
    np.maximum.accumulate(np.where(take_t.T, jj[None, :], 0), axis=1,
                          out=prev_take[:, 1:])
    sel = np.zeros((r, m), bool)
    rows = np.arange(r)
    cur = np.full(r, m, np.intp)
    while True:
        j = prev_take[rows, cur]
        act = j > 0
        if not act.any():
            break
        jm1 = np.maximum(j - 1, 0)
        sel[rows[act], jm1[act]] = True
        cur = np.where(act, pred[rows, jm1], 0)
    return sel


class RoundSelector:
    """Batched multi-window WIS selector (the device-resident settle).

    One instance per scheduler (``SchedulerConfig.wis_impl``); stateless
    apart from the backend choice, so it is shared freely across rounds and
    replays.  Also callable with the classic per-window ``(starts, ends,
    weights)`` signature (delegating to :func:`wis_select`) so code written
    against the scalar selector protocol keeps working.
    """

    batched = True

    def __init__(self, impl: str = "numpy", mesh=None, health=None):
        if impl not in ("numpy", "ref", "pallas"):
            raise ValueError(
                f"wis_impl must be one of 'numpy' | 'ref' | 'pallas', got {impl!r}")
        self.impl = impl
        # auction mesh (launch.mesh.make_auction_mesh): shards the window
        # rows of every batched dispatch; host backend has nothing to shard
        self.mesh = mesh if impl in ("ref", "pallas") else None
        # sticky per-backend health (kernels.common.BackendHealth), shared
        # with the scheduler's scoring dispatches: a failed device backend
        # degrades every future settle down the pallas → ref → numpy ladder
        self.health = health

    def _effective_impl(self) -> str:
        return self.health.resolve(self.impl) if self.health is not None \
            else self.impl

    @property
    def device(self) -> bool:
        return self._effective_impl() in ("ref", "pallas")

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        if self.mesh is not None:
            return f"RoundSelector({self.impl!r}, mesh={dict(self.mesh.shape)})"
        return f"RoundSelector({self.impl!r})"

    def __call__(self, starts, ends, weights):
        return wis_select(starts, ends, weights)

    # -- packing ---------------------------------------------------------------
    def pack(self, members, view, sel_scores: Optional[np.ndarray] = None) -> PackedSettle:
        """Pad every window's candidates into the (W, L) sorted-lane layout.

        ``members[k]`` lists window k's pool indices in pool order (the
        same order the per-window host path sees).  Device backends bucket
        lanes to a power of two so drifting per-window pool sizes reuse
        one executable; the host backend packs exactly (no jit cache to
        protect, shorter DP loop).
        """
        w = len(members)
        lens = np.fromiter((len(m) for m in members), np.intp, count=w)
        max_len = int(lens.max()) if w else 1
        lanes = (max(1, max_len) if self.impl == "numpy"
                 else _bucket(max_len, MIN_LANE_BUCKET))
        idx = np.full((w, lanes), -1, np.intp)
        total = int(lens.sum())
        if total:
            import itertools

            flat = np.fromiter(
                itertools.chain.from_iterable(members), np.intp, count=total)
            rows = np.repeat(np.arange(w, dtype=np.intp), lens)
            cum0 = np.concatenate([[0], np.cumsum(lens)[:-1]])
            lane = np.arange(total, dtype=np.intp) - np.repeat(cum0, lens)
            idx[rows, lane] = flat
        valid = idx >= 0
        if total and len(view):
            safe = np.clip(idx, 0, None)
            s = np.where(valid, view.t_start[safe], np.inf)
            e = np.where(valid, view.t_end[safe], np.inf)
        else:  # empty pool: all lanes padded (gathering would index-error)
            s = np.full((w, lanes), np.inf)
            e = np.full((w, lanes), np.inf)
        order = np.argsort(e, axis=1, kind="stable")
        e_s = np.take_along_axis(e, order, axis=1)
        s_s = np.take_along_axis(s, order, axis=1)
        pred = np.empty((w, lanes), np.int32)
        for k in range(w):
            pred[k] = np.searchsorted(e_s[k], s_s[k], side="right")
        idx_sorted = np.take_along_axis(idx, order, axis=1)
        packed = PackedSettle(members, idx_sorted, pred, None)
        if sel_scores is not None:
            packed.fill_weights(sel_scores)
        return packed

    # -- batched selection -----------------------------------------------------
    def select(self, packed: PackedSettle, rows, banned=None) -> List[List[int]]:
        """Clear the given windows in one dispatch → pool indices per row
        (ascending end time, matching :func:`wis_select`'s return order)."""
        return self.select_rows(packed, [(k, banned) for k in rows])

    #: the vectorized host DP pays off once the batch carries at least this
    #: many windows-worth of real lanes per lane step (below it, per-row
    #: scalar DP straight from the packed buffers is cheaper — no pow2 pad
    #: work, no per-step numpy kernel overhead)
    _VECTOR_MIN_ROWS = 6.0

    def select_rows(self, packed: PackedSettle, requests) -> List[List[int]]:
        """Like :meth:`select` but with a per-row banned mask — the form the
        GlobalAssignment lockstep replays use (rows from different candidate
        configurations share the packed buffers but not their bans)."""
        if not requests:
            return []
        if self.impl == "numpy":
            total = int(packed.row_len[[k for k, _ in requests]].sum())
            if total < self._VECTOR_MIN_ROWS * packed.lanes:
                # small batch (conflict re-clears, narrow rounds): scalar DP
                # per row from the retained sort/pred — identical selections
                return [self._select_row_scalar(packed, k, banned)
                        for k, banned in requests]
        rows = [k for k, _ in requests]
        idx_rows = packed.idx_sorted[rows]
        w = packed.wmat[rows]  # fancy indexing copies — safe to mutate
        first_banned = requests[0][1]
        if all(b is first_banned for _, b in requests):
            # common case (one shared ban state): one vectorized masking
            if first_banned is not None and first_banned.any():
                w[(idx_rows >= 0) & first_banned[np.clip(idx_rows, 0, None)]] = 0.0
        else:
            for r, (k, banned) in enumerate(requests):
                if banned is not None and banned.any():
                    bi = idx_rows[r]
                    w[r, (bi >= 0) & banned[np.clip(bi, 0, None)]] = 0.0
        sel = self._dispatch(w, packed.pred[rows])
        # single nonzero + row-split instead of W flatnonzero calls
        sel_rows, sel_lanes = np.nonzero(sel)
        pool_idx = idx_rows[sel_rows, sel_lanes]
        splits = np.searchsorted(sel_rows, np.arange(1, len(requests)))
        return [part.tolist() for part in np.split(pool_idx, splits)]

    @staticmethod
    def _select_row_scalar(packed: PackedSettle, k: int, banned) -> List[int]:
        """One window's WIS from the retained buffers, scalar python DP.

        Skips the re-sort the per-window host path pays on every re-clear
        (order and predecessors were fixed at pack time); python floats ARE
        IEEE float64, so the arithmetic is bit-identical to ``wis_select``.
        """
        n = int(packed.row_len[k])
        if n == 0:
            return []
        idx_row = packed.idx_sorted[k]
        w = packed.wmat[k, :n].tolist()
        if banned is not None and banned.any():
            bi = idx_row[:n]
            bm = (bi >= 0) & banned[np.clip(bi, 0, None)]
            for j in np.flatnonzero(bm):
                w[j] = 0.0
        p = packed.pred_row(k)
        dp = [0.0] * (n + 1)
        take = [False] * n
        for j in range(n):
            with_j = w[j] + dp[p[j]]
            if with_j > dp[j]:
                dp[j + 1] = with_j
                take[j] = True
            else:
                dp[j + 1] = dp[j]
        sel: List[int] = []
        j = n
        while j > 0:
            if take[j - 1]:
                sel.append(j - 1)
                j = p[j - 1]
            else:
                j -= 1
        sel.reverse()
        return [int(idx_row[s]) for s in sel]

    def _dispatch(self, w: np.ndarray, pred: np.ndarray) -> np.ndarray:
        impl = self._effective_impl()
        if impl == "numpy":
            return _batch_dp_backtrack_numpy(w, pred)
        # device path: pad the row dim to its pow2 bucket (zero rows clear
        # empty) so the jit cache is keyed on bucketed shapes only
        from ..kernels.common import KernelDispatchError
        from ..kernels.wis_dp import ops as wis_ops

        r = w.shape[0]
        rb = _bucket(r, MIN_ROW_BUCKET)
        wp, pp = w, pred
        if rb != r:
            wp = np.concatenate([w, np.zeros((rb - r, w.shape[1]), w.dtype)])
            pp = np.concatenate(
                [pred, np.zeros((rb - r, pred.shape[1]), pred.dtype)])
        # degradation ladder: a failing device backend is marked sick
        # (sticky) and the dispatch retries one rung down, ending at the
        # host float64 DP, which cannot fail
        while impl != "numpy":
            try:
                sel, _ = wis_ops.wis_settle_batch(
                    wp.astype(np.float32), pp, impl=impl, mesh=self.mesh)
                return np.asarray(sel)[:r]
            except KernelDispatchError as exc:
                if self.health is None:
                    raise
                self.health.mark_failed(impl, str(exc))
                impl = self.health.resolve(impl)
        return _batch_dp_backtrack_numpy(w, pred)

    # -- fused score→clear dispatch (device backends only) ---------------------
    def predispatch(self, n_windows: int, win_idx, view, handle,
                    transform=None) -> Optional["SettlePrefetch"]:
        """Dispatch the ban-free first-pass WIS against IN-FLIGHT scores.

        Called right after ``score_round_async`` while the scoring dispatch
        is still on the device stream: the selection weights are gathered
        from the device scores array, so the round's scores flow into
        clearing without a host round-trip, and the whole score→clear chain
        overlaps the next round's host preparation.  Host-only backends
        return None (nothing to fuse).

        ``transform`` (optional (M,) float32, aligned with the pool) is the
        clearing policy's selection-weight multiplier — gathered scores are
        multiplied in-dispatch, which is what lets score-transforming
        backends (FairShare's age boost) consume the fused path.
        """
        if not self.device:
            return None
        from .policy.base import _pool_members  # lazy: avoids import cycle

        members = _pool_members(n_windows, win_idx)
        packed = self.pack(members, view, None)
        rb = _bucket(n_windows, MIN_ROW_BUCKET)
        idx = packed.idx_sorted
        pred = packed.pred
        if rb != n_windows:
            pad = np.full((rb - n_windows, packed.lanes), -1, idx.dtype)
            idx = np.concatenate([idx, pad])
            pred = np.concatenate(
                [pred, np.zeros((rb - n_windows, packed.lanes), pred.dtype)])
        from ..kernels.wis_dp import ops as wis_ops

        tr = None
        if transform is not None:
            # pad to the bucket-padded device scores (padded rows are
            # masked lanes; 1.0 keeps the gather shape-stable)
            tr = np.ones(int(handle.device_scores.shape[0]), np.float32)
            tr[: len(transform)] = np.asarray(transform, np.float32)
        from ..kernels.common import KernelDispatchError

        try:
            sel, _ = wis_ops.wis_settle_fused(
                handle.device_scores, idx.astype(np.int32), idx >= 0, pred,
                impl=self._effective_impl(), mesh=self.mesh, transform=tr)
        except KernelDispatchError as exc:
            # speculation is optional: mark the backend sick and settle
            # without fusion (the settle half re-clears from host scores)
            if self.health is None:
                raise
            self.health.mark_failed(exc.backend, str(exc))
            return None
        return SettlePrefetch(packed, sel, self,
                              transformed=transform is not None)


def predispatch_settle(selector, backend, n_windows: int, win_idx, view,
                       handle, ages=None) -> Optional[SettlePrefetch]:
    """Dispatch the fused first-pass WIS iff every fusion condition holds.

    The ONE eligibility rule shared by every entry point (clear_round, the
    pipelined round stream, the scheduler's prepare half): the selector is
    a device-backed RoundSelector, the scoring dispatch is still in flight,
    and the clearing backend declares ``supports_prefetch``.  Backends that
    SELECT on transformed scores publish the transform through
    ``prefetch_transform(view, ages)`` (None = identity) and it is applied
    in-dispatch, so the fused first pass matches their selection weights.
    Returns None when any condition fails — callers settle without fusion,
    identically.
    """
    if (isinstance(selector, RoundSelector) and selector.device
            and handle is not None and handle.in_flight
            and getattr(backend, "supports_prefetch", False)):
        get_tr = getattr(backend, "prefetch_transform", None)
        transform = get_tr(view, ages) if get_tr is not None else None
        return selector.predispatch(n_windows, win_idx, view, handle,
                                    transform=transform)
    return None


def make_round_selector(impl: Optional[str], mesh=None, health=None):
    """Map the ``wis_impl`` knob (plus an optional auction mesh) to a selector.

    None → the historical per-window :func:`wis_select` host loop (the
    default: byte-identical, no device involvement); "numpy" → the batched
    float64 host backend (byte-identical by construction, one python DP
    loop per LANE instead of per candidate per window); "ref" / "pallas" →
    the device backends in ``kernels/wis_dp`` (float32 DP, fused score→
    clear dispatch).  ``mesh`` shards the device backends' window rows
    (``launch.mesh.make_auction_mesh``); host paths ignore it.
    """
    if impl is None:
        return wis_select
    return RoundSelector(impl, mesh=mesh, health=health)


def wis_select_batch(starts, ends, weights, valid=None, *, impl: str = "numpy"):
    """Batched multi-window WIS over padded (W, L) arrays (test/bench API).

    Returns ``(sel_mask (W, L) bool in ORIGINAL lane order, totals (W,))``.
    Semantically ``wis_select`` applied per row over the valid lanes;
    ``impl`` picks the host float64 path or a device backend.  Totals are
    recomputed on the host in float64 for all impls so they are directly
    comparable against the per-window reference.
    """
    starts = np.asarray(starts, np.float64)
    ends = np.asarray(ends, np.float64)
    weights = np.asarray(weights, np.float64)
    w, lanes = starts.shape
    if valid is None:
        valid = np.ones((w, lanes), bool)
    valid = np.asarray(valid, bool)

    sel = np.zeros((w, lanes), bool)
    if lanes == 0 or w == 0:
        return sel, np.zeros(w, np.float64)
    s = np.where(valid, starts, np.inf)
    e = np.where(valid, ends, np.inf)
    wt = np.where(valid, weights, 0.0)
    order = np.argsort(e, axis=1, kind="stable")
    e_s = np.take_along_axis(e, order, axis=1)
    s_s = np.take_along_axis(s, order, axis=1)
    w_s = np.take_along_axis(wt, order, axis=1)
    pred = np.empty((w, lanes), np.int32)
    for k in range(w):
        pred[k] = np.searchsorted(e_s[k], s_s[k], side="right")
    if impl == "numpy":
        sel_sorted = _batch_dp_backtrack_numpy(w_s, pred)
    else:
        from ..kernels.wis_dp import ops as wis_ops

        dev_sel, _ = wis_ops.wis_settle_batch(
            w_s.astype(np.float32), pred, impl=impl)
        sel_sorted = np.asarray(dev_sel)
    rows = np.repeat(np.arange(w), lanes).reshape(w, lanes)
    sel[rows, order] = sel_sorted
    sel &= valid
    totals = np.where(sel, weights, 0.0).sum(axis=1)
    return sel, totals
