"""Weighted Interval Scheduling (paper §4.4, `SelectBestCompatibleVariants`).

The per-window clearing step: given M candidate variants, each an interval
[t_start, t_end] with weight Score(v) ≥ 0, select the maximum-total-score
subset of pairwise non-overlapping intervals.

Classical DP after sorting by end time — O(M log M):

    p(j) = largest i < j with end_i <= start_j        (binary search)
    dp[j] = max(dp[j-1], w_j + dp[p(j)])

Three implementations:

* :func:`wis_select`       — numpy host path (the scheduler's default).
* :func:`wis_select_jax`   — jit-able JAX path (sort + searchsorted +
                             ``lax.scan`` DP + ``lax.while_loop`` backtrack);
                             mirrored by the Pallas kernel ``kernels/wis_dp``.
* :func:`wis_brute_force`  — O(2^M) oracle for property tests.

Intervals are treated as half-open [start, end): touching intervals
(end_i == start_j) are compatible, matching the paper's worked example where
(40,47) and (47,50) are both selected.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .types import OVERLAP_EPS

__all__ = ["wis_select", "wis_select_jax", "wis_brute_force", "total_weight"]


def _validate(starts, ends, weights):
    starts = np.asarray(starts, dtype=np.float64)
    ends = np.asarray(ends, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    if not (starts.shape == ends.shape == weights.shape):
        raise ValueError("starts/ends/weights must have identical shapes")
    if np.any(ends < starts):
        raise ValueError("interval with end < start")
    if np.any(weights < -1e-12):
        raise ValueError("WIS optimality requires non-negative weights")
    return starts, ends, weights


def wis_select(
    starts: Sequence[float],
    ends: Sequence[float],
    weights: Sequence[float],
) -> Tuple[np.ndarray, float]:
    """Optimal WIS. Returns (selected original indices asc by end, total).

    O(M log M): numpy argsort + searchsorted + a single DP pass.
    """
    starts, ends, weights = _validate(starts, ends, weights)
    m = starts.shape[0]
    if m == 0:
        return np.zeros((0,), dtype=np.int64), 0.0

    order = np.argsort(ends, kind="stable")
    s, e, w = starts[order], ends[order], weights[order]

    # p[j]: number of intervals (in sorted order) ending <= s[j]; dp is
    # 1-indexed with dp[0] = 0 so p[j] indexes dp directly.
    p = np.searchsorted(e, s, side="right")

    dp = np.zeros(m + 1, dtype=np.float64)
    take = np.zeros(m, dtype=bool)
    for j in range(m):
        with_j = w[j] + dp[p[j]]
        if with_j > dp[j]:  # strict: prefer fewer intervals on ties
            dp[j + 1] = with_j
            take[j] = True
        else:
            dp[j + 1] = dp[j]

    # Backtrack.
    sel: List[int] = []
    j = m
    while j > 0:
        if take[j - 1]:
            sel.append(j - 1)
            j = p[j - 1]
        else:
            j -= 1
    sel_sorted = np.array(sel[::-1], dtype=np.int64)
    return order[sel_sorted], float(dp[m])


def wis_brute_force(
    starts: Sequence[float],
    ends: Sequence[float],
    weights: Sequence[float],
) -> Tuple[np.ndarray, float]:
    """Exhaustive oracle (use only for small M in tests)."""
    starts, ends, weights = _validate(starts, ends, weights)
    m = starts.shape[0]
    if m > 22:
        raise ValueError("brute force limited to M <= 22")
    best_mask, best_val = 0, 0.0
    for mask in range(1 << m):
        idx = [i for i in range(m) if mask >> i & 1]
        ok = True
        for a in range(len(idx)):
            for b in range(a + 1, len(idx)):
                i, j = idx[a], idx[b]
                if (starts[i] < ends[j] - OVERLAP_EPS
                        and starts[j] < ends[i] - OVERLAP_EPS):
                    ok = False
                    break
            if not ok:
                break
        if ok:
            val = float(sum(weights[i] for i in idx))
            if val > best_val + 1e-15:
                best_val, best_mask = val, mask
    sel = np.array([i for i in range(m) if best_mask >> i & 1], dtype=np.int64)
    return sel, best_val


def total_weight(weights: Sequence[float], selected: Sequence[int]) -> float:
    w = np.asarray(weights, dtype=np.float64)
    return float(w[np.asarray(selected, dtype=np.int64)].sum()) if len(selected) else 0.0


# ---------------------------------------------------------------------------
# JAX path — jit-able, fixed-size, mask-based (device-resident clearing)
# ---------------------------------------------------------------------------


def wis_select_jax(starts, ends, weights, valid=None):
    """Jit-able WIS over a fixed-size padded pool.

    Args:
      starts, ends, weights: (M,) float arrays (padded entries arbitrary).
      valid: optional (M,) bool mask; invalid entries are excluded.

    Returns:
      (selected_mask (M,) bool in ORIGINAL order, total_score scalar).

    The DP is a ``lax.scan`` over sorted intervals; backtracking is a
    ``lax.while_loop``.  Padded/invalid entries get weight 0 and a
    point-interval at +inf so they never affect the optimum.
    """
    import jax
    import jax.numpy as jnp

    starts = jnp.asarray(starts, dtype=jnp.float32)
    ends = jnp.asarray(ends, dtype=jnp.float32)
    weights = jnp.asarray(weights, dtype=jnp.float32)
    m = starts.shape[0]
    if valid is None:
        valid = jnp.ones((m,), dtype=bool)
    else:
        valid = jnp.asarray(valid, dtype=bool)

    big = jnp.float32(3.0e38)
    s = jnp.where(valid, starts, big)
    e = jnp.where(valid, ends, big)
    w = jnp.where(valid, weights, 0.0)

    order = jnp.argsort(e, stable=True)
    s_o, e_o, w_o = s[order], e[order], w[order]
    p = jnp.searchsorted(e_o, s_o, side="right")  # (M,) into dp[0..M]

    def dp_step(dp, j):
        with_j = w_o[j] + dp[p[j]]
        without_j = dp[j]
        take = with_j > without_j
        dp = dp.at[j + 1].set(jnp.where(take, with_j, without_j))
        return dp, take

    dp0 = jnp.zeros((m + 1,), dtype=jnp.float32)
    dp, take = jax.lax.scan(dp_step, dp0, jnp.arange(m))

    def backtrack(state):
        j, sel = state
        t = take[j - 1]
        sel = sel.at[j - 1].set(t)
        j = jnp.where(t, p[j - 1], j - 1)
        return j, sel

    def cond(state):
        return state[0] > 0

    sel_sorted = jnp.zeros((m,), dtype=bool)
    _, sel_sorted = jax.lax.while_loop(cond, backtrack, (jnp.int32(m), sel_sorted))

    sel_mask = jnp.zeros((m,), dtype=bool).at[order].set(sel_sorted)
    return sel_mask & valid, dp[m]
