"""Round pipelining: overlap host bid preparation with device scoring.

JAX dispatches asynchronously: a batched scoring call returns immediately
with in-flight arrays, and the host only blocks when it reads the values.
Serial ``run_round`` wastes that window — it dispatches, then immediately
blocks to clear.  The :class:`RoundPipeline` double-buffers consecutive
rounds instead:

    dispatch k ─▶ [device: score round k      ]─▶ settle k ─▶ dispatch k+1 …
                  [host:   prepare round k+1  ]

While round k's scores are in flight, the host **speculatively** announces
windows and collects/packs bids for round k+1 (and even dispatches them).
Speculation is validated — never trusted — before use:

* every scheduler state mutation (commit, complete, fail, job/slice
  membership) bumps ``JasdaScheduler._epoch``; a speculative preparation
  whose epoch no longer matches is discarded (per-agent bid statistics are
  rolled back; variant ids are deterministic, so a fresh serial
  preparation is byte-identical to a never-speculated one);
* windows the settling round killed (cleared empty → dead-window
  suppression) do not bump the epoch — they only *remove* announcements —
  so the surviving preparation is FILTERED: the dead windows' bid groups
  are dropped and the pool re-packed/re-dispatched.  Bid generation is
  per-window independent (jobs.generate_variants_by_window), so the
  filtered pool equals what a fresh announcement would produce;
* the settle's RoundFeedback broadcast (the clearing→agent negotiation
  channel) is published AFTER speculation was taken, so a bidding
  strategy that adapts from it (observe_feedback → True) bumps the epoch
  exactly like a commitment: the pre-feedback speculative bids are
  discarded and regenerated serially against the adapted state.
  Stateless strategies (GreedyChunking) report no adaptation and keep
  speculation hitting — feedback consistency costs nothing unless a
  strategy actually uses the channel.

The result is provably identical to serial rounds (equivalence-tested
byte-for-byte), with the host work of round k+1 hidden behind round k's
device time whenever the state allows it — and a wasted-but-harmless
speculation (it overlapped a device wait) when it does not.

:func:`pipelined_clear_rounds` applies the same structure to a stateless
stream of (windows, pool) rounds — the form benchmarks and external
batch-auction drivers use — where every round is independent and the
overlap needs no speculation at all.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .clearing import assign_bids, settle_round
from .scoring import ScoringPolicy, score_round_async
from .types import RoundResult, Variant, Window
from .wis import make_round_selector, predispatch_settle

# NOTE: scheduler-level pipelining (RoundPipeline) needs no policy plumbing
# of its own — JasdaScheduler._settle_round dispatches through the
# scheduler's Policy.clearing backend, so speculation replays identically
# under ANY backend (settle is pure given its inputs).

__all__ = ["RoundPipeline", "pipelined_clear_rounds"]


class RoundPipeline:
    """Double-buffers a JasdaScheduler's auction rounds (see module doc).

    Drive it with :meth:`tick` once per round, passing the next round's
    time so the speculative preparation can start; call :meth:`flush` when
    done to roll back any outstanding speculation.  State lives on the
    scheduler — the pipeline only sequences prepare/settle halves.
    """

    # after this many consecutive discards, stop speculating until a round
    # settles without commitments (the state-stable regime where speculation
    # provably validates) — keeps the busy-auction overhead bounded
    MAX_CONSEC_DISCARDS = 3

    def __init__(self, scheduler):
        self.sched = scheduler
        self._spec = None  # speculative RoundPrep for the next tick
        self._consec_discards = 0
        # observability: how often speculation paid off / was filtered / lost
        self.stats = {"spec_hit": 0, "spec_filtered": 0, "spec_discarded": 0,
                      "serial_prep": 0}

    # -- public ----------------------------------------------------------------
    def tick(self, now: float, next_time: Optional[float] = None) -> Optional[RoundResult]:
        """Run the round at ``now``; speculatively prepare ``next_time``."""
        prep = self._take_validated(now)
        if prep is None:
            self.stats["serial_prep"] += 1
            prep = self.sched._prepare_round(now)
        # Overlap window: the current round's scores are (possibly) in
        # flight; prepare the next round's host half now.  Only worthwhile
        # when something is actually in flight — eager paths (empty round,
        # small-pool numpy) would pay the speculation cost with nothing to
        # hide it behind — and only while speculation has been validating
        # (adaptive back-off keeps busy-auction overhead bounded).
        self._spec = None
        speculate = (
            next_time is not None
            and self._in_flight(prep)
            and self._consec_discards < self.MAX_CONSEC_DISCARDS
        )
        if speculate:
            self._spec = self.sched._prepare_round(next_time, speculative=True)
        rr = self.sched._settle_round(prep)
        if rr is None or not rr.selected:
            # nothing committed: the state held still — re-arm speculation
            self._consec_discards = 0
        return rr

    def flush(self) -> None:
        """Discard outstanding speculation (restores agent bid statistics)."""
        if self._spec is not None:
            self._discard(self._spec)
            self._spec = None

    # -- speculation validation -------------------------------------------------
    @staticmethod
    def _in_flight(prep) -> bool:
        handle = getattr(prep, "handle", None)
        return handle is not None and handle.in_flight

    def _take_validated(self, now: float):
        """Return a usable preparation for ``now`` from speculation, or None.

        Valid   = epoch unchanged and no speculated window suppressed since.
        Filter  = epoch unchanged, some windows died: drop their bid groups,
                  re-pack and re-dispatch (bid stats re-derived).
        Discard = epoch changed (or wrong tick time): roll back stats.
        """
        spec, self._spec = self._spec, None
        if spec is None:
            return None
        if spec.now != now or spec.epoch != self.sched._epoch:
            self.stats["spec_discarded"] += 1
            self._consec_discards += 1
            self._discard(spec)
            return None
        reg = self.sched._dead_windows
        reg.prune(now)  # idempotent: speculation already pruned at `now`
        kept = [k for k, w in enumerate(spec.windows)
                if not reg.suppressed(w.slice_id, w.t_min)]
        if len(kept) == len(spec.windows):
            self.stats["spec_hit"] += 1
            self._consec_discards = 0
            return spec  # bit-identical to a serial preparation
        self.stats["spec_filtered"] += 1
        self._consec_discards = 0
        # Some speculated windows were killed by the round that settled in
        # between.  Timeline/agents/ages are untouched (epoch matched), so
        # the surviving windows' bids are exactly what a fresh announcement
        # would generate — drop the dead groups and redo pool/pack/dispatch.
        if spec.stats_snap is not None:
            for agent in spec.agents:
                agent.stats_restore(spec.stats_snap[agent.spec.job_id])
        spec.windows = [spec.windows[k] for k in kept]
        spec.bids = [[per_window[k] for k in kept] for per_window in spec.bids]
        for agent, per_window in zip(spec.agents, spec.bids):
            # re-apply the n_bids a serial generation over the surviving
            # windows would have counted (one per window with bids)
            agent.n_bids += sum(1 for vs in per_window if vs)
        if not spec.windows:
            return spec  # settles as an idle round (log row, None result)
        self.sched._finalize_prep(spec)
        return spec

    def _discard(self, spec) -> None:
        if spec.stats_snap is not None:
            for agent in spec.agents:
                agent.stats_restore(spec.stats_snap[agent.spec.job_id])


# ---------------------------------------------------------------------------
# Stateless round streams (benchmarks / batch-auction drivers)
# ---------------------------------------------------------------------------


def pipelined_clear_rounds(
    rounds: Sequence[Tuple[Sequence[Window], Sequence[Variant]]],
    policy: ScoringPolicy,
    *,
    ages=None,
    calibrate=None,
    score_impl: Optional[str] = None,
    recheck_theta: Optional[float] = None,
    per_agent_theta: bool = False,
    grid: int = 32,
    grid_cache=None,
    work_budget=None,
    clearing=None,
    wis_impl: Optional[str] = None,
    mesh=None,
) -> List[RoundResult]:
    """Clear a stream of independent rounds with dispatch/settle overlap.

    Equivalent to ``[clear_round(w, pool, policy, ...) for w, pool in
    rounds]`` (identical selections — asserted by the pipeline_overlap
    benchmark), but round k+1's host packing and round k's WIS clearing
    both run while round k(/k+1)'s device scoring is in flight.  Up to two
    rounds are queued on device at any time (double buffering).
    ``clearing`` selects the settle backend (``repro.core.policy.
    ClearingPolicy``; None = GreedyWIS) — the overlap structure is
    backend-agnostic because settle is pure given its inputs.

    ``wis_impl`` selects the settle-side WIS backend (see ``core.wis.
    make_round_selector``); with a device backend ("ref"/"pallas") each
    round's ban-free first WIS pass is dispatched right behind its scoring
    call — score→clear chain on the async stream — so the settle half
    overlaps the next round's host packing too.  ``mesh`` shards both
    device dispatches across an auction mesh (see ``clear_round``);
    pipelined+sharded rounds stay byte-identical to serial single-device.
    """
    results: List[RoundResult] = []
    pending = None  # (windows, fit, win_idx, view, handle, prefetch)
    selector = make_round_selector(wis_impl, mesh=mesh)
    from .clearing import _default_clearing

    backend = clearing if clearing is not None else _default_clearing()

    def dispatch(windows, pool):
        windows = list(windows)
        fit, win_idx, fit_view = assign_bids(windows, pool)
        handle = None
        prefetch = None
        if fit:
            handle = score_round_async(
                fit, windows, win_idx, policy,
                ages=ages, calibrate=calibrate, impl=score_impl,
                recheck_theta=recheck_theta, per_agent_theta=per_agent_theta,
                grid=grid, grid_cache=grid_cache,
                view=fit_view, mesh=mesh,
            )
            prefetch = predispatch_settle(
                selector, backend, len(windows), win_idx, fit_view, handle,
                ages=ages)
        return windows, fit, win_idx, fit_view, handle, prefetch

    def settle(entry):
        windows, fit, win_idx, fit_view, handle, prefetch = entry
        scores = handle.result() if handle is not None else np.zeros(0)
        return settle_round(windows, fit, win_idx, scores,
                            work_budget=work_budget, view=fit_view,
                            clearing=backend, ages=ages,
                            selector=selector, prefetch=prefetch)

    for windows, pool in rounds:
        entry = dispatch(windows, pool)  # host pack + async device dispatch
        if pending is not None:
            # settles round k-1 while round k computes on device
            results.append(settle(pending))
        pending = entry
    if pending is not None:
        results.append(settle(pending))
    return results
