"""JASDA core: the paper's contribution (§3–§4) as a composable library.

Layer map (paper section → module):
  §3.1 window announcement      → windows
  §3.2 TRP/FMP + variants       → trp, types
  §3.2–3.3 job-side bidding     → jobs, atomizer
  §4.2 scoring model            → scoring
  §4.2.1 calibration/trust      → calibration
  §4.3 temporal fairness        → fairness
  §4.4 WIS clearing             → wis, clearing
  clearing objective + presets  → policy (ClearingPolicy backends, Policy)
  bid-side negotiation          → negotiation (typed round messages,
                                  BiddingStrategy backends, RoundFeedback)
  §3/§4 interaction cycle       → scheduler
  §6(a) quantitative study      → simulator, baselines
  fault injection + recovery    → faults (beyond-paper robustness layer)
"""
from .types import (  # noqa: F401
    DEAD_WINDOW_EPS,
    TIME_EPS,
    ClearingResult,
    Commitment,
    JobSpec,
    JobState,
    PoolView,
    RoundResult,
    SliceSpec,
    Variant,
    Window,
    variants_to_arrays,
)
from .trp import (  # noqa: F401
    Phase,
    PhaseFMP,
    fmp_from_model,
    fmp_standard,
    fmp_static,
    is_safe,
    predict_duration,
    prob_exceed_grid,
    prob_exceed_union,
)
from .scoring import (  # noqa: F401
    POLICY_BALANCED,
    POLICY_QOS_FIRST,
    POLICY_UTILIZATION_FIRST,
    ScoreHandle,
    ScoringPolicy,
    composite_score,
    score_pool,
    score_round,
    score_round_async,
)
from .wis import (  # noqa: F401
    RoundSelector,
    make_round_selector,
    wis_brute_force,
    wis_select,
    wis_select_batch,
    wis_select_jax,
)
from .calibration import CalibrationConfig, Calibrator, per_variant_error, reliability  # noqa: F401
from .fairness import AgePolicy, AgeTracker, jain_index  # noqa: F401
from .windows import (  # noqa: F401
    DeadWindowRegistry,
    SliceTimeline,
    WindowPolicy,
    announce_window,
    announce_windows,
)
from .atomizer import AtomizerConfig, ChunkPlan, chunk_candidates  # noqa: F401
from .negotiation import (  # noqa: F401
    AdaptiveBidder,
    Award,
    BidBundle,
    BiddingStrategy,
    ConservativeSafety,
    GreedyChunking,
    LossReport,
    RoundFeedback,
    WindowAnnouncement,
    build_feedback,
)
from .faults import (  # noqa: F401
    FAULT_KINDS,
    AgentFault,
    AgentRespondError,
    AgentSilentError,
    FaultEvent,
    FaultInjector,
    FaultPlan,
)
from .jobs import AgentConfig, JobAgent  # noqa: F401
from .clearing import assign_bids, clear_round, clear_window, settle_round  # noqa: F401
from .policy import (  # noqa: F401
    ClearingPolicy,
    FairShare,
    GlobalAssignment,
    GreedyWIS,
    Policy,
)
from .scheduler import CommitRecord, JasdaScheduler, SchedulerConfig  # noqa: F401
from .repartition import (  # noqa: F401
    EnergyAware,
    EnergyModel,
    FragmentationAware,
    MigrationConfig,
    MigrationPlanner,
    Move,
    ProfileLattice,
    RepartitionCoordinator,
    RepartitionPolicy,
    RepartitionState,
    SliceProfile,
    StaticInventory,
    fragmentation_index,
)
from .pipeline import RoundPipeline, pipelined_clear_rounds  # noqa: F401
from .simulator import SimConfig, SimResult, make_workload, simulate  # noqa: F401
from .baselines import (  # noqa: F401
    AuctionScheduler,
    BackfillScheduler,
    BestFitScheduler,
    FifoScheduler,
)
