"""Dynamic repartitioning: the slice inventory as an online decision variable.

Every scenario before this module ran a FIXED slice inventory.  The MIG
literature treats partition layout as online state instead: fragmentation-
aware scheduling on shared GPUs (Ting et al., arXiv 2512.16099) and
energy-efficient dynamic repartitioning (Lipe et al., arXiv 2606.25082).
This module makes the JASDA pod behave the same way, while the auction
core barely changes — repartition events are just window births/deaths
through the machinery that already exists:

* a **profile lattice** (:class:`SliceProfile` / :class:`ProfileLattice`)
  constrains the shapes a slice may take: pow2 ``n_chips`` partitions of
  the pod, MIG-style, each with a ``power_watts`` figure that finally
  gives ψ_energy in ``core/scoring.py`` a real slice-side model;
* a **buddy layout** (:class:`RepartitionState`) maps every slice to an
  aligned pow2 chip interval of the pod, so split/merge legality is the
  classic buddy-allocator rule — merge only *siblings* (the two aligned
  halves of one parent interval), split only within the lattice — and
  split/merge products get canonical interval-derived ids
  (``p<offset>c<n>``) that stay bounded under repeated cycles;
* a **policy protocol** (:class:`RepartitionPolicy`) with three backends:
  :class:`StaticInventory` (default; proposes nothing, byte-identical to
  a run without the subsystem), :class:`FragmentationAware` (split/merge
  driven by :func:`fragmentation_index` over announced window capacities
  vs. the pending pool's ``min_capacity`` demand histogram, which also
  feeds the ``frag_aware`` ``WindowPolicy`` ordering), and
  :class:`EnergyAware` (consolidate-and-power-gate idle slices, λ_energy
  per profile);
* a **coordinator** (:class:`RepartitionCoordinator`) that executes moves
  safely BETWEEN rounds: busy slices drain first (the move waits up to
  ``drain_grace`` ticks for outstanding commitments to settle), then the
  slice leaves through ``revoke_slice`` — commit-log ``lost`` rows,
  ``LOSS_SLICE_FAILED`` feedback — exactly like a slice failure; merged-
  away ids retire their ``DeadWindowRegistry`` entries
  (:meth:`DeadWindowRegistry.drop_slice`) so a slice reborn later under
  the same canonical id starts clean; every mutation goes through
  scheduler methods that bump the state epoch, so pipelined speculation
  stays byte-identical; new slices announce through the normal
  ``add_slice`` path; and the whole coordinator is picklable plain data,
  so repartition state rides crash checkpoints with the rest of the run.

Integration knobs: ``SimConfig.repartition`` / ``simulate(...)`` in
``core/simulator.py`` and ``ServiceConfig.repartition`` (periodic
``_REPARTITION`` events on the service's :class:`EventHeap`) in
``service/engine.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .types import SliceSpec

__all__ = [
    "SliceProfile",
    "ProfileLattice",
    "RepartitionState",
    "Move",
    "RepartitionContext",
    "RepartitionPolicy",
    "StaticInventory",
    "FragmentationAware",
    "EnergyAware",
    "EnergyModel",
    "RepartitionCoordinator",
    "MigrationConfig",
    "MigrationPlanner",
    "fragmentation_index",
]

GB = 1024.0**3


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


# ---------------------------------------------------------------------------
# profile lattice
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SliceProfile:
    """One legal slice shape: a pow2 ``n_chips`` partition of the pod.

    ``power_watts`` is the busy-power draw of a slice instantiated from
    this profile; ``idle_watts`` the draw while the slice is live but has
    nothing running.  A power-gated slice draws nothing.
    """

    n_chips: int
    capacity_bytes: float
    power_watts: float
    idle_watts: float = 0.0

    def __post_init__(self):
        if not _is_pow2(self.n_chips):
            raise ValueError(f"profile n_chips must be pow2, got {self.n_chips}")
        if self.capacity_bytes <= 0:
            raise ValueError("profile capacity must be positive")
        if self.idle_watts > self.power_watts:
            raise ValueError("idle_watts cannot exceed power_watts")

    @property
    def name(self) -> str:
        return f"{self.n_chips}c"


@dataclass(frozen=True)
class ProfileLattice:
    """The set of legal slice shapes, indexed by ``n_chips``.

    Split legality: a profile splits only when the half-size profile is
    in the lattice.  Merge legality: two slices merge only when they are
    buddy *siblings* (checked by :class:`RepartitionState`) AND the
    double-size profile is in the lattice.
    """

    profiles: Tuple[SliceProfile, ...]

    def __post_init__(self):
        sizes = [p.n_chips for p in self.profiles]
        if not sizes:
            raise ValueError("lattice needs at least one profile")
        if len(set(sizes)) != len(sizes):
            raise ValueError("duplicate profile sizes in lattice")
        object.__setattr__(
            self, "profiles",
            tuple(sorted(self.profiles, key=lambda p: p.n_chips)))

    # -- lookup -------------------------------------------------------------
    def profile_for(self, n_chips: int) -> SliceProfile:
        for p in self.profiles:
            if p.n_chips == n_chips:
                return p
        raise KeyError(f"no {n_chips}-chip profile in lattice "
                       f"(have {[p.n_chips for p in self.profiles]})")

    def has(self, n_chips: int) -> bool:
        return any(p.n_chips == n_chips for p in self.profiles)

    @property
    def max_power(self) -> float:
        return max(p.power_watts for p in self.profiles)

    # -- move legality ------------------------------------------------------
    def can_split(self, n_chips: int) -> bool:
        return n_chips > 1 and self.has(n_chips) and self.has(n_chips // 2)

    def can_merge(self, n_chips: int) -> bool:
        return self.has(n_chips) and self.has(n_chips * 2)

    def spec_for(self, slice_id: str, n_chips: int, *,
                 template: Optional[SliceSpec] = None) -> SliceSpec:
        """Instantiate a :class:`SliceSpec` of a lattice profile.

        ``template`` donates the per-chip hardware figures (flops, HBM
        bandwidth, speed) so split/merge products inherit the pod's
        hardware model rather than the SliceSpec defaults.
        """
        p = self.profile_for(n_chips)
        if template is not None:
            return replace(template, slice_id=slice_id,
                           capacity_bytes=p.capacity_bytes, n_chips=n_chips)
        return SliceSpec(slice_id=slice_id, capacity_bytes=p.capacity_bytes,
                         n_chips=n_chips)

    # -- constructors -------------------------------------------------------
    @classmethod
    def default(cls, *, chip_capacity_gb: float = 5.0, max_chips: int = 8,
                watts_per_chip: float = 350.0,
                idle_fraction: float = 0.15) -> "ProfileLattice":
        """A full pow2 ladder 1..max_chips with linear capacity/power."""
        if not _is_pow2(max_chips):
            raise ValueError("max_chips must be pow2")
        profs = []
        n = 1
        while n <= max_chips:
            w = watts_per_chip * n
            profs.append(SliceProfile(
                n_chips=n, capacity_bytes=chip_capacity_gb * n * GB,
                power_watts=w, idle_watts=idle_fraction * w))
            n <<= 1
        return cls(tuple(profs))

    @classmethod
    def infer(cls, specs: Sequence[SliceSpec], *,
              watts_per_chip: float = 350.0,
              idle_fraction: float = 0.15) -> "ProfileLattice":
        """Derive a lattice from an existing inventory.

        Per-chip capacity is taken from the inventory (it must be
        consistent across slices — the buddy layout needs one chip unit);
        the ladder spans 1 chip up to the pod's pow2 envelope.
        """
        if not specs:
            raise ValueError("cannot infer a lattice from an empty inventory")
        per_chip = {round(s.capacity_bytes / max(1, s.n_chips), 3) for s in specs}
        if len(per_chip) != 1:
            raise ValueError(
                f"inconsistent per-chip capacity across inventory: {sorted(per_chip)}")
        chip_cap = per_chip.pop()
        pod = _next_pow2(sum(max(1, s.n_chips) for s in specs))
        return cls.default(chip_capacity_gb=chip_cap / GB, max_chips=pod,
                           watts_per_chip=watts_per_chip,
                           idle_fraction=idle_fraction)


# ---------------------------------------------------------------------------
# buddy layout
# ---------------------------------------------------------------------------

def canonical_id(offset: int, n_chips: int) -> str:
    """Interval-derived slice id: bounded and deterministic under repeated
    split/merge cycles (the same interval always rebuilds the same id)."""
    return f"p{offset}c{n_chips}"


@dataclass
class RepartitionState:
    """Buddy-allocator view of the pod: slice id -> aligned chip interval.

    Invariants: every interval is ``(offset, n_chips)`` with pow2
    ``n_chips`` and ``offset % n_chips == 0``; live + gated intervals are
    pairwise disjoint.  Gated slices keep their interval (their chips are
    powered off, not reassigned) and their spec, so an ungate restores
    them exactly.
    """

    pod_chips: int
    intervals: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    gated: Dict[str, SliceSpec] = field(default_factory=dict)
    idle_streak: Dict[str, int] = field(default_factory=dict)

    @classmethod
    def adopt(cls, specs: Sequence[SliceSpec],
              lattice: ProfileLattice) -> "RepartitionState":
        """Deterministically place an existing inventory on the pod.

        Largest slices first (ties by id), first-fit at the lowest aligned
        offset — the placement is a pure function of the inventory, so two
        runs adopting the same slices agree on every buddy relationship.
        """
        pod = _next_pow2(sum(max(1, s.n_chips) for s in specs))
        state = cls(pod_chips=pod)
        taken: List[Tuple[int, int]] = []
        for s in sorted(specs, key=lambda s: (-s.n_chips, s.slice_id)):
            n = max(1, s.n_chips)
            if not _is_pow2(n):
                raise ValueError(
                    f"slice {s.slice_id} has non-pow2 n_chips={s.n_chips}; "
                    "the buddy layout needs pow2 slices")
            off = 0
            while off + n <= pod:
                if all(off + n <= o or off >= o + m for o, m in taken):
                    break
                off += n
            else:
                raise ValueError(f"inventory does not fit a {pod}-chip pod")
            taken.append((off, n))
            state.intervals[s.slice_id] = (off, n)
        return state

    # -- buddy relations ----------------------------------------------------
    def interval(self, slice_id: str) -> Tuple[int, int]:
        return self.intervals[slice_id]

    def buddy_of(self, slice_id: str) -> Optional[str]:
        """The sibling slice id, if the buddy interval is live as ONE slice."""
        off, n = self.intervals[slice_id]
        boff = off ^ n
        for sid, (o, m) in self.intervals.items():
            if o == boff and m == n and sid != slice_id:
                return sid
        return None

    def mergeable_pairs(self, lattice: ProfileLattice,
                        live=None) -> List[Tuple[str, str]]:
        """All sibling pairs whose merge is lattice-legal, largest first,
        deterministic order.  ``live`` restricts candidates to slices
        currently in the scheduler pool (a fault-revoked slice keeps its
        interval but cannot merge until repaired)."""
        out = []
        seen = set()
        for sid in sorted(self.intervals):
            if sid in seen or sid in self.gated:
                continue
            if live is not None and sid not in live:
                continue
            b = self.buddy_of(sid)
            if b is None or b in self.gated:
                continue
            if live is not None and b not in live:
                continue
            _, n = self.intervals[sid]
            if lattice.can_merge(n):
                seen.add(sid)
                seen.add(b)
                out.append(tuple(sorted((sid, b))))
        out.sort(key=lambda p: (-self.intervals[p[0]][1], p))
        return out

    # -- move application (layout only; the coordinator drives the pool) ----
    def split_ids(self, slice_id: str) -> Tuple[str, str]:
        off, n = self.intervals[slice_id]
        if n < 2:
            raise ValueError(f"{slice_id} is a 1-chip slice; cannot split")
        h = n // 2
        return canonical_id(off, h), canonical_id(off + h, h)

    def apply_split(self, slice_id: str) -> Tuple[Tuple[str, int], Tuple[str, int]]:
        off, n = self.intervals.pop(slice_id)
        h = n // 2
        a, b = canonical_id(off, h), canonical_id(off + h, h)
        self.intervals[a] = (off, h)
        self.intervals[b] = (off + h, h)
        self.idle_streak.pop(slice_id, None)
        return (a, h), (b, h)

    def apply_merge(self, a: str, b: str) -> Tuple[str, int]:
        (oa, na), (ob, nb) = self.intervals[a], self.intervals[b]
        if na != nb or (oa ^ na) != ob:
            raise ValueError(
                f"{a} and {b} are not buddy siblings "
                f"({(oa, na)} vs {(ob, nb)}); merge only siblings")
        off = min(oa, ob)
        parent = canonical_id(off, 2 * na)
        del self.intervals[a]
        del self.intervals[b]
        self.intervals[parent] = (off, 2 * na)
        self.idle_streak.pop(a, None)
        self.idle_streak.pop(b, None)
        return parent, 2 * na


# ---------------------------------------------------------------------------
# fragmentation metric
# ---------------------------------------------------------------------------

def fragmentation_index(capacities: Sequence[float],
                        demands: Sequence[Tuple[float, float]]) -> float:
    """Demand-weighted stranded-work fraction, in [0, 1].

    ``capacities`` are the live announceable window capacities (windows
    inherit their slice's capacity, so the live slice capacities ARE the
    announcement-side histogram); ``demands`` is the pending pool's
    capacity-demand histogram as ``(remaining_work, min_capacity)`` rows.
    The index is the fraction of pending work whose ``min_capacity`` no
    single live slice can satisfy — work stranded purely by partition
    LAYOUT, the quantity a merge can recover (Ting et al.'s notion of
    fragmented-but-free capacity, adapted to the auction's window model).
    """
    total = sum(w for w, _ in demands)
    if total <= 0.0:
        return 0.0
    cmax = max(capacities, default=0.0)
    stranded = sum(w for w, mc in demands if mc > cmax)
    return stranded / total


# ---------------------------------------------------------------------------
# policy protocol + backends
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Move:
    """One repartition action; ``targets`` are the consumed slice ids."""

    kind: str  # "split" | "merge" | "gate" | "ungate"
    targets: Tuple[str, ...]


@dataclass(frozen=True)
class RepartitionContext:
    """Read-only snapshot a policy decides from (built by the coordinator)."""

    now: float
    specs: Mapping[str, SliceSpec]  # live inventory
    busy: frozenset  # slice ids with outstanding/running work
    gated: Mapping[str, SliceSpec]
    # pending pool: (remaining biddable work, min_capacity) per live job
    demand: Tuple[Tuple[float, float], ...]
    fragmentation: float
    backlog_work: float
    idle_streak: Mapping[str, int]
    lattice: ProfileLattice
    state: RepartitionState


class RepartitionPolicy:
    """Protocol: propose moves for one repartition tick.

    Implementations must be picklable (they ride crash checkpoints) and
    deterministic in the context — the coordinator calls ``propose`` at
    most once per tick and executes moves in list order.
    """

    name = "abstract"
    #: when True the coordinator attaches an :class:`EnergyModel` to the
    #: scheduler so ψ_energy scores placements by profile power draw
    energy_score = False

    def propose(self, ctx: RepartitionContext) -> List[Move]:
        raise NotImplementedError

    def window_demand(self, ctx: RepartitionContext) -> Optional[Tuple[float, ...]]:
        """Capacity-demand histogram for ``frag_aware`` announcement
        ordering (None = leave the scheduler's ordering input unchanged)."""
        return None


@dataclass(frozen=True)
class StaticInventory(RepartitionPolicy):
    """The default: never repartition.  A run with this policy is
    byte-identical to one without the repartition subsystem at all (the
    coordinator proposes nothing, touches nothing, bumps no epochs)."""

    name = "static"

    def propose(self, ctx: RepartitionContext) -> List[Move]:
        return []


@dataclass(frozen=True)
class FragmentationAware(RepartitionPolicy):
    """Split/merge driven by the stranded-work fragmentation index.

    Merge pressure: when more than ``merge_threshold`` of pending work is
    stranded (its ``min_capacity`` exceeds every live slice), merge the
    largest lattice-legal sibling pair — repeatedly, one move per tick,
    climbing the lattice until a slice big enough exists.  Split
    pressure: when nothing is stranded but the queue is crowded (more
    than ``split_queue_factor`` pending jobs per live slice), split the
    largest slice whose halves still satisfy every pending
    ``min_capacity`` — more windows per round, no new stranding.
    """

    name = "frag"
    merge_threshold: float = 0.05
    split_queue_factor: float = 4.0

    def propose(self, ctx: RepartitionContext) -> List[Move]:
        if ctx.fragmentation > self.merge_threshold:
            pairs = ctx.state.mergeable_pairs(ctx.lattice, live=ctx.specs)
            if pairs:
                return [Move("merge", pairs[0])]
            return []
        if not ctx.demand or ctx.fragmentation > 0.0:
            return []
        n_live = len(ctx.specs)
        if len(ctx.demand) <= self.split_queue_factor * max(1, n_live):
            return []
        max_mc = max(mc for _, mc in ctx.demand)
        best = None
        for sid in sorted(ctx.specs, key=lambda s: (-ctx.specs[s].n_chips, s)):
            n = ctx.specs[sid].n_chips
            if not ctx.lattice.can_split(n):
                continue
            half = ctx.lattice.profile_for(n // 2)
            if half.capacity_bytes >= max_mc:
                best = sid
                break
        return [Move("split", (best,))] if best else []

    def window_demand(self, ctx: RepartitionContext) -> Optional[Tuple[float, ...]]:
        return tuple(sorted({mc for _, mc in ctx.demand if mc > 0.0}))


@dataclass(frozen=True)
class EnergyAware(RepartitionPolicy):
    """Consolidate-and-power-gate idle slices (Lipe et al.'s direction).

    A slice idle for ``gate_after`` consecutive repartition ticks is a
    gating candidate; candidates are gated one per tick in order of
    λ_energy-weighted idle draw (biggest saving first), always keeping
    ``min_active`` slices live.  Idle sibling pairs consolidate (merge)
    before gating, so the pod gates big units rather than stranding
    half-parents.  When backlog per live slice exceeds
    ``ungate_backlog``, gated slices return (largest first) through the
    normal announcement path.  ``lam_energy`` scales each profile's draw
    in the gating order (per-profile λ_energy; default 1.0).
    """

    name = "energy"
    energy_score = True
    gate_after: int = 2
    min_active: int = 1
    ungate_backlog: float = 50.0
    lam_energy: Optional[Tuple[Tuple[str, float], ...]] = None

    def _lam(self, profile: SliceProfile) -> float:
        if self.lam_energy:
            for name, lam in self.lam_energy:
                if name == profile.name:
                    return lam
        return 1.0

    def propose(self, ctx: RepartitionContext) -> List[Move]:
        n_live = len(ctx.specs)
        # ungate first: backlog outranks savings
        if ctx.gated and ctx.backlog_work > self.ungate_backlog * max(1, n_live):
            sid = max(sorted(ctx.gated), key=lambda s: ctx.gated[s].capacity_bytes)
            return [Move("ungate", (sid,))]
        idle = [s for s in sorted(ctx.specs)
                if s not in ctx.busy
                and ctx.idle_streak.get(s, 0) >= self.gate_after]
        # consolidate: merge an idle sibling pair before gating it
        for a, b in ctx.state.mergeable_pairs(ctx.lattice, live=ctx.specs):
            if a in idle and b in idle:
                return [Move("merge", (a, b))]
        if n_live <= self.min_active:
            return []
        if not idle:
            return []

        def saving(sid: str) -> float:
            p = ctx.lattice.profile_for(ctx.specs[sid].n_chips)
            return self._lam(p) * p.idle_watts

        idle.sort(key=lambda s: (-saving(s), s))
        return [Move("gate", (idle[0],))]


# ---------------------------------------------------------------------------
# ψ_energy slice-side model
# ---------------------------------------------------------------------------

@dataclass
class EnergyModel:
    """Per-slice power map feeding ψ_energy in the scoring objective.

    ψ_energy(v) = 1 − watts(slice(v)) / peak — the §3.2 energy feature
    shape (``SystemFeatures.energy`` with E = watts·duration and
    E_max = peak·duration; the duration cancels), so placements on
    low-power profiles score higher.  Attached to the scheduler by the
    coordinator whenever the active policy sets ``energy_score``; the
    scheduler folds the term into settled scores on the host (the clip in
    Eq. 3 is slack there: Σβ ≤ 1 keeps f_sys in range), which keeps the
    batched device dispatch untouched.
    """

    watts: Dict[str, float]
    peak: float

    def psi(self, slice_id: str) -> float:
        w = self.watts.get(slice_id, self.peak)
        if self.peak <= 0.0:
            return 0.0
        return min(1.0, max(0.0, 1.0 - w / self.peak))


# ---------------------------------------------------------------------------
# the graceful revocation ladder: migrate → preempt-with-credit → revoke
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MigrationConfig:
    """Knobs of the :class:`MigrationPlanner` revocation ladder.

    ``migration_budget`` bounds how many commitments one evacuation may
    re-place (migration re-commits timelines and re-scores nothing, but
    each move is still an epoch bump + feedback row — unbounded migration
    of a hot slice could thrash).  ``horizon`` is the placement lookahead
    scanned on each candidate slice, ``duration_margin`` the safety factor
    on the residual's predicted runtime (the original declarations are
    conservative quantiles; the successor keeps that headroom so it does
    not trade revocation loss for overrun loss).
    """

    migration_budget: int = 4
    horizon: float = 200.0
    duration_margin: float = 1.25


class MigrationPlanner:
    """Walks the migrate → preempt-with-credit → revoke-lossy ladder.

    One :meth:`evacuate` call handles everything committed to a dying
    slice, per commitment and in deterministic order:

    1. **migrate** — while the migration budget lasts, try to re-place the
       commitment's residual work on a compatible surviving slice
       (capacity ≥ the job's ``min_capacity``, θ-safety via the agent's
       own memoized check, an idle gap big enough within the horizon, not
       dead-window suppressed, not overlapping the job's own wins) through
       ``scheduler.migrate_commitment``;
    2. **preempt with credit** — a RUNNING commitment whose job declares a
       ``preempt_granularity`` keeps its completed granules through
       ``scheduler.preempt`` (calibration ingests the observed partial
       speed); only the residual re-enters the biddable pool;
    3. **revoke lossy** — whatever remains takes the historical
       slice-failure path (``fail_running`` + ``revoke_slice`` +
       ``drop_pending``), progress torched.

    Rungs 1–2 broadcast ONE out-of-round ``build_migration_feedback`` to
    the affected agents (``MIGRATED`` award/loss pairs + ``preempted``
    losses); like sheds it does NOT replace ``scheduler.last_feedback``.
    With ``migration_budget=0`` and every ``preempt_granularity`` at 0 the
    ladder degenerates to exactly the historical three-call sequence —
    byte-identical, which is what lets the planner ride every entry point
    (fault path, repartition drain, service policing) unconditionally.

    Picklable plain data; checkpointed in the same pickle graph as the
    scheduler whose Variant identities it manipulates.
    """

    def __init__(self, scheduler, config: Optional[MigrationConfig] = None):
        self.scheduler = scheduler
        self.config = config if config is not None else MigrationConfig()
        self.n_migrated = 0
        self.n_preempted = 0
        self.n_lost = 0
        self.work_credited = 0.0

    # -- placement search ----------------------------------------------------
    def _find_placement(self, agent, residual: float, exclude: str,
                        now: float, activation: float):
        """Earliest feasible (t_start, slice_id, duration) for the residual,
        deterministic (slices scanned in sorted order, earliest gap first,
        ties by slice id) — or None when nothing fits in the horizon."""
        sched = self.scheduler
        cfg = self.config
        best = None
        for sid in sorted(sched.slices):
            if sid == exclude:
                continue
            tl = sched.slices[sid]
            spec = tl.spec
            if spec.capacity_bytes < agent.spec.min_capacity:
                continue
            if not agent.is_safe_on(spec.capacity_bytes):
                continue
            thr = agent.throughput_on(spec.capacity_bytes, spec.n_chips) * spec.speed
            if thr <= 0.0:
                continue
            need = (activation + residual / thr) * cfg.duration_margin
            for s, e in tl.gaps(now, cfg.horizon):
                start = max(s, now)
                if e - start < need - 1e-12:
                    continue
                if sched._dead_windows.suppressed(sid, s):
                    continue
                if agent._overlaps_own(start, need):
                    continue
                if best is None or (start, sid) < (best[0], best[1]):
                    best = (start, sid, need)
                break  # earliest feasible gap per slice is enough
        return best

    # -- the ladder ----------------------------------------------------------
    def evacuate(self, slice_id: str, now: float, ex=None) -> Dict[str, int]:
        """Walk the ladder over everything committed to ``slice_id``, then
        revoke the slice.  Returns per-rung counts for the caller's
        metrics (``migrated`` / ``preempted`` / ``lost``)."""
        import numpy as np

        from .negotiation.messages import build_migration_feedback
        from .types import Window

        sched = self.scheduler
        budget = self.config.migration_budget
        run = ex.running.get(slice_id) if ex is not None else None
        doomed = sorted(
            (c for c in sched.commitments if c.variant.slice_id == slice_id),
            key=lambda c: (c.variant.t_start, c.variant.variant_id))
        old_tl = sched.slices.get(slice_id)
        old_cap = old_tl.spec.capacity_bytes if old_tl is not None else 0.0
        migrations: List[tuple] = []
        preemptions: List[tuple] = []
        n_migrated = n_preempted = 0
        for c in doomed:
            v = c.variant
            agent = sched.agents.get(v.job_id)
            payload = v.payload if isinstance(v.payload, dict) else {}
            work = float(payload.get("work", 0.0))
            activation = float(payload.get("activation", 0.0))
            is_running = run is not None and run[0] is v
            credited = 0.0
            observed = None
            if is_running and agent is not None:
                g = float(agent.spec.preempt_granularity)
                actual_end = run[1]
                if g > 0.0 and now > v.t_start:
                    frac = float(np.clip(
                        (now - v.t_start) / max(actual_end - v.t_start, 1e-9),
                        0.0, 1.0))
                    credited = min(work, float(int((work * frac) / g)) * g)
                if credited > 0.0:
                    # the observed PARTIAL speed (the same truth-scaling
                    # complete() uses): speed from the full actual runtime,
                    # progress from the credited fraction
                    truth = dict(payload.get("true_features",
                                             v.declared_features))
                    observed = dict(truth)
                    ratio = float(np.clip(
                        v.duration / max(actual_end - v.t_start, 1e-9),
                        0.0, 1.0))
                    if "jct" in observed:
                        observed["jct"] = float(np.clip(
                            observed["jct"] * ratio, 0.0, 1.0))
                    if "progress" in observed:
                        observed["progress"] = float(np.clip(
                            observed["progress"] * (credited / max(work, 1e-9)),
                            0.0, 1.0))
            residual = work - credited
            old_w = Window(slice_id, old_cap, v.t_start, v.duration)
            # rung 1: migrate the residual to a surviving slice
            if budget > 0 and agent is not None and residual > 1e-9:
                placed = self._find_placement(
                    agent, residual, slice_id, now, activation)
                if placed is not None:
                    t0, sid2, need = placed
                    new_v = sched.migrate_commitment(
                        v, now, slice_id=sid2, t_start=t0, duration=need,
                        residual_work=residual, credited_work=credited,
                        observed_features=observed)
                    if new_v is not None:
                        budget -= 1
                        n_migrated += 1
                        self.work_credited += credited
                        if ex is not None:
                            if is_running:
                                ex.running.pop(slice_id, None)
                                run = None
                            ex.pending = [p for p in ex.pending if p is not v]
                            ex.pending.append(new_v)
                        cap2 = sched.slices[sid2].spec.capacity_bytes
                        migrations.append((
                            v.job_id, v.variant_id, new_v.variant_id,
                            old_w, Window(sid2, cap2, t0, need), c.score))
                        continue
            # rung 2: preempt with granule credit (running chunks only)
            if is_running and credited > 0.0:
                sched.preempt(v, now, work_done=credited,
                              observed_features=observed)
                n_preempted += 1
                self.work_credited += credited
                if ex is not None:
                    ex.running.pop(slice_id, None)
                run = None
                preemptions.append((v.job_id, v.variant_id, old_w))
                continue
            # rung 3: left for the lossy revocation below
        if migrations or preemptions:
            fb = build_migration_feedback(
                now, migrations, preemptions, sched.calibrator)
            for job_id in sorted(set(fb.losses) | set(fb.awards)):
                agent = sched.agents.get(job_id)
                if agent is not None:
                    agent.observe_feedback(fb)
        # the historical slice-failure path mops up whatever is left
        if ex is not None:
            ex.fail_running(slice_id, now)
        lost = sched.revoke_slice(slice_id, now)
        if ex is not None:
            ex.drop_pending(slice_id)
        self.n_migrated += n_migrated
        self.n_preempted += n_preempted
        self.n_lost += len(lost)
        return {"migrated": n_migrated, "preempted": n_preempted,
                "lost": len(lost)}


# ---------------------------------------------------------------------------
# coordinator: safe execution between rounds
# ---------------------------------------------------------------------------

class RepartitionCoordinator:
    """Owns the layout state and executes policy moves between rounds.

    Drain-first protocol: a move whose target slices still have
    outstanding commitments (or a variant running/queued in the
    execution plumbing) waits, re-checked every tick, up to
    ``drain_grace`` ticks; past that the targets are revoked —
    ``fail_running`` + ``revoke_slice`` + ``drop_pending``, the exact
    slice-failure path, with commit-log ``lost`` rows and
    ``LOSS_SLICE_FAILED`` feedback.  Merged-away and gated ids retire
    their dead-window entries so canonical-id rebirth starts clean.

    Everything here is picklable plain data; the coordinator is included
    in simulator/service crash checkpoints next to the scheduler it
    references (one combined pickle graph, preserving identity).
    """

    MAX_TRACE = 4096
    # class-level fallback so coordinators restored from pre-migration
    # checkpoints (plain __dict__ pickling) still resolve the attribute
    migration = None

    def __init__(self, scheduler, policy: RepartitionPolicy, *,
                 lattice: Optional[ProfileLattice] = None,
                 drain_grace: int = 2,
                 migration: Optional[MigrationPlanner] = None):
        self.scheduler = scheduler
        self.policy = policy
        # revocation ladder for forced drains (None = the historical
        # fail_running + revoke_slice + drop_pending lossy path)
        self.migration = migration
        specs = [tl.spec for tl in scheduler.slices.values()]
        self.lattice = lattice if lattice is not None else ProfileLattice.infer(specs)
        self.state = RepartitionState.adopt(specs, self.lattice)
        self.drain_grace = int(drain_grace)
        # moves waiting for their targets to drain: [(move, ticks_waited)]
        self.draining: List[Tuple[Move, int]] = []
        self.n_splits = 0
        self.n_merges = 0
        self.n_gates = 0
        self.n_ungates = 0
        self.n_forced = 0  # drains that ended in revocation
        self.energy_joules = 0.0
        self.frag_trace: List[Tuple[float, float]] = []
        self._last_tick: Optional[float] = None
        if self.policy.energy_score:
            self._attach_energy_model()

    # -- energy -------------------------------------------------------------
    def _attach_energy_model(self) -> None:
        watts = {}
        for sid in self.state.intervals:
            if sid in self.state.gated:
                continue
            _, n = self.state.intervals[sid]
            watts[sid] = self.lattice.profile_for(n).power_watts
        self.scheduler.energy_model = EnergyModel(
            watts=watts, peak=self.lattice.max_power)

    def _account_energy(self, now: float, busy: frozenset) -> None:
        """Tick-sampled energy proxy: busy slices draw profile power, idle
        live slices draw idle power, gated slices draw nothing."""
        if self._last_tick is not None:
            dt = now - self._last_tick
            if dt > 0:
                for sid, (_, n) in self.state.intervals.items():
                    if sid in self.state.gated:
                        continue
                    p = self.lattice.profile_for(n)
                    self.energy_joules += dt * (
                        p.power_watts if sid in busy else p.idle_watts)
        self._last_tick = now

    # -- context ------------------------------------------------------------
    def _busy_set(self, ex=None) -> frozenset:
        sched = self.scheduler
        busy = {c.variant.slice_id for c in sched.commitments}
        if ex is not None:
            busy.update(ex.running.keys())
            busy.update(v.slice_id for v in ex.pending)
        return frozenset(busy)

    def _context(self, now: float, busy: frozenset) -> RepartitionContext:
        sched = self.scheduler
        specs = {sid: tl.spec for sid, tl in sched.slices.items()}
        demand = tuple(
            (a.biddable_work, a.spec.min_capacity)
            for _, a in sorted(sched.agents.items())
            if a.biddable_work > 0.0)
        frag = fragmentation_index(
            [s.capacity_bytes for s in specs.values()], demand)
        for sid in specs:
            if sid in busy:
                self.state.idle_streak[sid] = 0
            else:
                self.state.idle_streak[sid] = self.state.idle_streak.get(sid, 0) + 1
        return RepartitionContext(
            now=now, specs=specs, busy=busy, gated=dict(self.state.gated),
            demand=demand, fragmentation=frag,
            backlog_work=sum(w for w, _ in demand),
            idle_streak=dict(self.state.idle_streak),
            lattice=self.lattice, state=self.state)

    # -- the tick -----------------------------------------------------------
    def tick(self, now: float, ex=None) -> List[Move]:
        """One repartition opportunity between rounds; returns executed moves."""
        busy = self._busy_set(ex)
        self._account_energy(now, busy)
        ctx = self._context(now, busy)
        if len(self.frag_trace) < self.MAX_TRACE:
            self.frag_trace.append((now, ctx.fragmentation))
        demand = self.policy.window_demand(ctx)
        if demand is not None and self.scheduler.policy.window.kind == "frag_aware":
            self.scheduler.set_window_demand(demand)
        queued, self.draining = self.draining, []
        in_flight = {t for m, _ in queued for t in m.targets}
        proposed = [m for m in self.policy.propose(ctx)
                    if not (set(m.targets) & in_flight)]
        executed: List[Move] = []
        for move, waited in queued + [(m, 0) for m in proposed]:
            if self._execute(move, now, ex, busy, waited):
                executed.append(move)
        if executed and self.policy.energy_score:
            self._attach_energy_model()
        return executed

    def _execute(self, move: Move, now: float, ex, busy: frozenset,
                 waited: int) -> bool:
        self._validate(move)
        # capture specs up front: a forced revoke below removes the slice
        specs = {t: self.scheduler.slices[t].spec for t in move.targets
                 if t in self.scheduler.slices}
        stuck = [t for t in move.targets
                 if move.kind != "ungate" and t in busy]
        if stuck:
            if waited < self.drain_grace:
                self.draining.append((move, waited + 1))
                return False
            for sid in stuck:  # drain grace exhausted: revocation ladder
                if self.migration is not None:
                    self.migration.evacuate(sid, now, ex)
                else:  # historical lossy slice-failure path
                    if ex is not None:
                        ex.fail_running(sid, now)
                    self.scheduler.revoke_slice(sid, now)
                    if ex is not None:
                        ex.drop_pending(sid)
                self.n_forced += 1
        if move.kind == "split":
            self._do_split(move.targets[0], now, specs[move.targets[0]])
        elif move.kind == "merge":
            self._do_merge(move.targets[0], move.targets[1], now,
                           specs[move.targets[0]])
        elif move.kind == "gate":
            self._do_gate(move.targets[0], now, specs[move.targets[0]])
        elif move.kind == "ungate":
            self._do_ungate(move.targets[0])
        return True

    def _validate(self, move: Move) -> None:
        if move.kind not in ("split", "merge", "gate", "ungate"):
            raise ValueError(f"unknown repartition move kind {move.kind!r}")
        pool = self.state.gated if move.kind == "ungate" else self.scheduler.slices
        for t in move.targets:
            if t not in pool:
                raise ValueError(f"{move.kind} target {t!r} is not available")
            if t not in self.state.intervals:
                raise ValueError(f"{move.kind} target {t!r} has no buddy interval")
        if move.kind == "split":
            _, n = self.state.intervals[move.targets[0]]
            if not self.lattice.can_split(n):
                raise ValueError(
                    f"split of {move.targets[0]} ({n} chips) leaves the lattice")
        elif move.kind == "merge":
            a, b = move.targets
            _, n = self.state.intervals[a]
            if not self.lattice.can_merge(n):
                raise ValueError(f"merge of {a}+{b} leaves the lattice")
            if self.state.buddy_of(a) != b:
                raise ValueError(f"{a} and {b} are not buddy siblings")

    # -- move bodies (every scheduler call below bumps the state epoch, so
    # pipelined speculation against the old inventory is discarded) ---------
    def _retire(self, slice_id: str, now: float) -> None:
        """Remove a slice that is permanently leaving (merge/split/gate):
        drop + dead-window retirement; drained slices have no commitments
        left so nothing is lost, and force-revoked ones already broadcast
        their losses above."""
        self.scheduler.retire_slice(slice_id, now)

    def _do_split(self, slice_id: str, now: float, spec: SliceSpec) -> None:
        tmpl = replace(spec, speed=1.0)
        self._retire(slice_id, now)
        for cid, n in self.state.apply_split(slice_id):
            self.scheduler.add_slice(
                self.lattice.spec_for(cid, n, template=tmpl))
        self.n_splits += 1

    def _do_merge(self, a: str, b: str, now: float, spec: SliceSpec) -> None:
        tmpl = replace(spec, speed=1.0)
        self._retire(a, now)
        self._retire(b, now)
        pid, n = self.state.apply_merge(a, b)
        self.scheduler.add_slice(self.lattice.spec_for(pid, n, template=tmpl))
        self.n_merges += 1

    def _do_gate(self, slice_id: str, now: float, spec: SliceSpec) -> None:
        self._retire(slice_id, now)
        self.state.gated[slice_id] = spec
        self.state.idle_streak.pop(slice_id, None)
        self.n_gates += 1

    def _do_ungate(self, slice_id: str) -> None:
        spec = self.state.gated.pop(slice_id)
        self.scheduler.add_slice(spec)
        self.n_ungates += 1

    # -- reporting ----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        out = {
            "n_splits": self.n_splits,
            "n_merges": self.n_merges,
            "n_gates": self.n_gates,
            "n_ungates": self.n_ungates,
            "n_forced": self.n_forced,
            "energy_joules": self.energy_joules,
            "n_live": len(self.scheduler.slices),
            "n_gated": len(self.state.gated),
        }
        if self.migration is not None:
            out["n_migrated"] = self.migration.n_migrated
            out["n_preempted"] = self.migration.n_preempted
        return out
