"""Auction-round clearing (paper §4.4, Algorithm 1, batched across windows).

The round model generalizes the paper's per-window iteration: one round
announces ALL open windows, pools every job's bids, scores the pooled set in
ONE batched dispatch (scoring.score_round → kernels/jasda_score), then runs
the optimal WIS selection per window plus cross-window conflict resolution:

    1:    announce W = {w_1..w_K} to all jobs          (windows.py)
    4:    each job generates eligible variants over W  (jobs.py)
    6-8:  Score(v) = λ ĥ(v) + (1−λ) f̃_sys(v)           (one batched call)
    11:   V = ∪_J ∪_w V_{J,w}
    12:   per window: Ŝ_w = SelectBestCompatibleVariants(V_w, Score)
    12b:  cross-window resolution — a job winning overlapping intervals on
          two slices (or more total work than it has) keeps only its
          best-scored wins; freed capacity is re-cleared within the round
          until a fixed point (bans grow monotonically, so ≤ |V| passes).
    13:   commit ∪_w Ŝ_w, update layout and statistics (scheduler.py)

Steps 12/12b — the clearing OBJECTIVE — are owned by a pluggable
:class:`repro.core.policy.ClearingPolicy` backend: :func:`clear_round` and
:func:`settle_round` dispatch through the ``clearing`` argument (default
``GreedyWIS``, byte-identical to the historical hardwired path) rather than
baking one strategy in.  See ``repro.core.policy`` for the shipped backends
(``GreedyWIS`` / ``GlobalAssignment`` / ``FairShare``) and the unified
``Policy`` presets.

:func:`clear_window` is the single-window special case (the paper's original
Algorithm 1) and remains the numpy reference path; the scheduler's ``step()``
compatibility wrapper and the equivalence tests pin round == legacy on one
window.  All functions are pure given their inputs; state mutation (commit,
age updates, calibration) is the scheduler's job.
"""
from __future__ import annotations

from typing import Callable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .scoring import ScoringPolicy, score_pool, score_round_async
from .types import (OVERLAP_EPS, TIME_EPS, ClearingResult, PoolView,
                    RoundResult, Variant, Window)
from .wis import make_round_selector, predispatch_settle, wis_select

__all__ = ["clear_window", "clear_round", "assign_bids", "settle_round"]


def clear_window(
    window: Window,
    variants: Sequence[Variant],
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    extra_sys: Optional[Callable[[Variant], Mapping[str, float]]] = None,
    selector: Callable = wis_select,
) -> ClearingResult:
    """Score the pooled bids and clear w* optimally (Algorithm 1 lines 6–12).

    ``selector`` is pluggable so benchmarks can swap the numpy DP for the
    JAX/Pallas paths; all return identical selections (tested).
    """
    variants = [v for v in variants if _fits(v, window)]
    if not variants:
        return ClearingResult(
            window=window, selected=(), scores=(), total_score=0.0, n_bids=0
        )

    scores = score_pool(
        variants, window, policy, ages=ages, calibrate=calibrate, extra_sys=extra_sys
    )
    starts = np.array([v.t_start for v in variants])
    ends = np.array([v.t_end for v in variants])
    sel_idx, total = selector(starts, ends, scores)
    sel_set = set(int(i) for i in np.asarray(sel_idx))
    selected = [variants[i] for i in sorted(sel_set, key=lambda i: variants[i].t_start)]
    rejected = [v for i, v in enumerate(variants) if i not in sel_set]
    return ClearingResult(
        window=window,
        selected=tuple(selected),
        scores=tuple(float(scores[i]) for i in sorted(sel_set, key=lambda i: variants[i].t_start)),
        total_score=float(total),
        n_bids=len(variants),
        rejected=tuple(rejected),
    )


def _fits(v: Variant, w: Window, eps: float = TIME_EPS) -> bool:
    """Clearing-side sanity: variant must lie inside the announced window."""
    return (
        v.slice_id == w.slice_id
        and v.t_start >= w.t_min - eps
        and v.t_end <= w.t_end + eps
        and v.duration > 0
    )


def _overlap(a: Variant, b: Variant, eps: float = OVERLAP_EPS) -> bool:
    return a.t_start < b.t_end - eps and b.t_start < a.t_end - eps


def assign_bids(
    windows: Sequence[Window],
    variants: Sequence[Variant],
    view: Optional[PoolView] = None,
) -> Tuple[List[Variant], np.ndarray, PoolView]:
    """Assign each pooled bid to the (unique) window containing it.

    Windows on one slice are disjoint idle gaps, so a variant fits at most
    one; first-fit in window order keeps the assignment deterministic.
    Vectorized over the pool: builds (or reuses) a :class:`PoolView` and
    tests containment per window with numpy masks instead of a
    per-variant python loop.  Returns ``(fit, win_idx, fit_view)`` — the
    fitting subset in pool order, the window index each bid targets, and
    the aligned struct-of-arrays view the downstream pack/WIS stages reuse.
    """
    if view is None:
        view = PoolView.build(variants)
    m = len(view)
    if m == 0:
        return [], np.zeros(0, np.intp), view
    slice_code = {w.slice_id: None for w in windows}
    for i, sid in enumerate(slice_code):
        slice_code[sid] = i
    codes = np.asarray(
        [slice_code.get(s, -1) for s in view.slice_ids], np.intp
    )
    eps = TIME_EPS
    assigned = np.full(m, -1, np.intp)
    for k, w in enumerate(windows):
        mask = (
            (assigned < 0)
            & (codes == slice_code[w.slice_id])
            & (view.t_start >= w.t_min - eps)
            & (view.t_end <= w.t_end + eps)
            & (view.duration > 0)
        )
        assigned[mask] = k
    fit_idx = np.nonzero(assigned >= 0)[0]
    fit_view = view.take(fit_idx)
    return fit_view.variants, assigned[fit_idx], fit_view


def _empty_round(windows: Sequence[Window]) -> RoundResult:
    empty = [
        ClearingResult(window=w, selected=(), scores=(), total_score=0.0, n_bids=0)
        for w in windows
    ]
    return RoundResult(tuple(windows), tuple(empty), (), (), 0.0, 0)


def _default_clearing():
    """Module-level GreedyWIS singleton (lazy: avoids an import cycle)."""
    global _GREEDY
    if _GREEDY is None:
        from .policy import GreedyWIS

        _GREEDY = GreedyWIS()
    return _GREEDY


_GREEDY = None


def clear_round(
    windows: Sequence[Window],
    variants: Sequence[Variant],
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    selector: Callable = wis_select,
    work_budget: Optional[Mapping[str, float]] = None,
    score_impl: Optional[str] = None,
    recheck_theta: Optional[float] = None,
    per_agent_theta: bool = False,
    grid: int = 32,
    grid_cache=None,
    clearing=None,
    wis_impl: Optional[str] = None,
    mesh=None,
) -> RoundResult:
    """Clear one batched auction round over ALL announced windows.

    Scores the pooled bids in a single batched dispatch, then settles the
    round through the ``clearing`` backend (a ``repro.core.policy.
    ClearingPolicy``; default ``GreedyWIS`` — per-window WIS plus greedy
    cross-window conflict resolution, byte-identical to the historical
    behavior).  ``work_budget`` maps job_id → biddable work so a job never
    wins more total work than it has.

    ``recheck_theta`` re-verifies safety condition (a) in-dispatch against
    each bid's own window capacity (scoring.score_round);
    ``per_agent_theta`` uses each bid's OWN agent θ (``Variant.theta``)
    instead of one scheduler-wide bound.  ``grid_cache`` reuses FMP grid
    discretizations across rounds.  The dispatch/settle halves are exposed
    separately (:func:`assign_bids`, scoring's ``score_round_async``,
    :func:`settle_round`) so the round pipeline can overlap them across
    consecutive rounds.

    ``wis_impl`` selects the settle-side WIS backend (overrides
    ``selector``): None = the per-window host loop, "numpy" = batched host
    float64, "ref"/"pallas" = the device-resident batched settle
    (``kernels/wis_dp``).  With a device backend the ban-free first WIS
    pass is FUSED behind the scoring dispatch — selection weights are
    gathered from the still-in-flight device scores, no host round-trip.

    ``mesh`` (a ``jax.sharding.Mesh``, e.g. ``launch.mesh.
    make_auction_mesh()``) shards the pooled-bid axis of the scoring
    dispatch and the window axis of the device settle across devices via
    ``shard_map`` — byte-identical to single-device clearing (cross-window
    conflict resolution stays host-side and global).  Only meaningful with
    a device ``wis_impl``/``score_impl``; ignored by host paths.

    Returns a :class:`RoundResult`; ``results`` aligns with ``windows``.
    """
    windows = list(windows)
    if not windows:
        return RoundResult((), (), (), (), 0.0, 0)
    if wis_impl is not None:
        selector = make_round_selector(wis_impl, mesh=mesh)

    fit, win_idx, fit_view = assign_bids(windows, variants)
    if not fit:
        return _empty_round(windows)

    # -- one batched scoring call over the pooled bids (lines 6–8) ------------
    handle = score_round_async(
        fit, windows, win_idx, policy,
        ages=ages, calibrate=calibrate, impl=score_impl,
        recheck_theta=recheck_theta, per_agent_theta=per_agent_theta,
        grid=grid, grid_cache=grid_cache,
        view=fit_view, mesh=mesh,
    )
    backend = clearing if clearing is not None else _default_clearing()
    prefetch = predispatch_settle(
        selector, backend, len(windows), win_idx, fit_view, handle,
        ages=ages)
    return settle_round(
        windows, fit, win_idx, handle.result(),
        selector=selector, work_budget=work_budget, view=fit_view,
        clearing=backend, ages=ages, prefetch=prefetch,
    )


def settle_round(
    windows: Sequence[Window],
    fit: Sequence[Variant],
    win_idx: Sequence[int],
    scores: np.ndarray,
    *,
    selector: Callable = wis_select,
    work_budget: Optional[Mapping[str, float]] = None,
    view: Optional[PoolView] = None,
    clearing=None,
    ages: Optional[Mapping[str, float]] = None,
    prefetch=None,
) -> RoundResult:
    """The post-scores half of :func:`clear_round`, dispatched through the
    ``clearing`` backend (default ``GreedyWIS``): WIS per window plus
    cross-window conflict resolution (Algorithm 1 line 12 and step 12b).
    Pure given its inputs; the pipeline calls it once the in-flight scores
    of a dispatched round materialize.  ``view`` (the struct-of-arrays form
    of ``fit`` from :func:`assign_bids`) lets the per-window WIS passes
    gather interval arrays instead of re-walking the variant objects;
    ``ages`` feeds fairness-aware backends (ignored by ``GreedyWIS``).
    ``prefetch`` (an in-flight fused first-pass WIS from
    ``RoundSelector.predispatch``) is forwarded only to backends that
    declare ``supports_prefetch`` — custom backends with the original
    settle signature keep working unchanged.
    """
    backend = clearing if clearing is not None else _default_clearing()
    kw = {}
    if prefetch is not None and getattr(backend, "supports_prefetch", False):
        kw["prefetch"] = prefetch
    return backend.settle(
        windows, fit, win_idx, scores,
        selector=selector, work_budget=work_budget, view=view, ages=ages,
        **kw,
    )
