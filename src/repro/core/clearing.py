"""Auction-round clearing (paper §4.4, Algorithm 1, batched across windows).

The round model generalizes the paper's per-window iteration: one round
announces ALL open windows, pools every job's bids, scores the pooled set in
ONE batched dispatch (scoring.score_round → kernels/jasda_score), then runs
the optimal WIS selection per window plus cross-window conflict resolution:

    1:    announce W = {w_1..w_K} to all jobs          (windows.py)
    4:    each job generates eligible variants over W  (jobs.py)
    6-8:  Score(v) = λ ĥ(v) + (1−λ) f̃_sys(v)           (one batched call)
    11:   V = ∪_J ∪_w V_{J,w}
    12:   per window: Ŝ_w = SelectBestCompatibleVariants(V_w, Score)
    12b:  cross-window resolution — a job winning overlapping intervals on
          two slices (or more total work than it has) keeps only its
          best-scored wins; freed capacity is re-cleared within the round
          until a fixed point (bans grow monotonically, so ≤ |V| passes).
    13:   commit ∪_w Ŝ_w, update layout and statistics (scheduler.py)

:func:`clear_window` is the single-window special case (the paper's original
Algorithm 1) and remains the numpy reference path; the scheduler's ``step()``
compatibility wrapper and the equivalence tests pin round == legacy on one
window.  Both functions are pure given their inputs; state mutation (commit,
age updates, calibration) is the scheduler's job.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .scoring import ScoringPolicy, score_pool, score_round_async
from .types import ClearingResult, PoolView, RoundResult, Variant, Window
from .wis import wis_select

__all__ = ["clear_window", "clear_round", "assign_bids", "settle_round"]


def clear_window(
    window: Window,
    variants: Sequence[Variant],
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    extra_sys: Optional[Callable[[Variant], Mapping[str, float]]] = None,
    selector: Callable = wis_select,
) -> ClearingResult:
    """Score the pooled bids and clear w* optimally (Algorithm 1 lines 6–12).

    ``selector`` is pluggable so benchmarks can swap the numpy DP for the
    JAX/Pallas paths; all return identical selections (tested).
    """
    variants = [v for v in variants if _fits(v, window)]
    if not variants:
        return ClearingResult(
            window=window, selected=(), scores=(), total_score=0.0, n_bids=0
        )

    scores = score_pool(
        variants, window, policy, ages=ages, calibrate=calibrate, extra_sys=extra_sys
    )
    starts = np.array([v.t_start for v in variants])
    ends = np.array([v.t_end for v in variants])
    sel_idx, total = selector(starts, ends, scores)
    sel_set = set(int(i) for i in np.asarray(sel_idx))
    selected = [variants[i] for i in sorted(sel_set, key=lambda i: variants[i].t_start)]
    rejected = [v for i, v in enumerate(variants) if i not in sel_set]
    return ClearingResult(
        window=window,
        selected=tuple(selected),
        scores=tuple(float(scores[i]) for i in sorted(sel_set, key=lambda i: variants[i].t_start)),
        total_score=float(total),
        n_bids=len(variants),
        rejected=tuple(rejected),
    )


def _fits(v: Variant, w: Window, eps: float = 1e-9) -> bool:
    """Clearing-side sanity: variant must lie inside the announced window."""
    return (
        v.slice_id == w.slice_id
        and v.t_start >= w.t_min - eps
        and v.t_end <= w.t_end + eps
        and v.duration > 0
    )


def _overlap(a: Variant, b: Variant, eps: float = 1e-12) -> bool:
    return a.t_start < b.t_end - eps and b.t_start < a.t_end - eps


def assign_bids(
    windows: Sequence[Window],
    variants: Sequence[Variant],
    view: Optional[PoolView] = None,
) -> Tuple[List[Variant], np.ndarray, PoolView]:
    """Assign each pooled bid to the (unique) window containing it.

    Windows on one slice are disjoint idle gaps, so a variant fits at most
    one; first-fit in window order keeps the assignment deterministic.
    Vectorized over the pool: builds (or reuses) a :class:`PoolView` and
    tests containment per window with numpy masks instead of a
    per-variant python loop.  Returns ``(fit, win_idx, fit_view)`` — the
    fitting subset in pool order, the window index each bid targets, and
    the aligned struct-of-arrays view the downstream pack/WIS stages reuse.
    """
    if view is None:
        view = PoolView.build(variants)
    m = len(view)
    if m == 0:
        return [], np.zeros(0, np.intp), view
    slice_code = {w.slice_id: None for w in windows}
    for i, sid in enumerate(slice_code):
        slice_code[sid] = i
    codes = np.asarray(
        [slice_code.get(s, -1) for s in view.slice_ids], np.intp
    )
    eps = 1e-9
    assigned = np.full(m, -1, np.intp)
    for k, w in enumerate(windows):
        mask = (
            (assigned < 0)
            & (codes == slice_code[w.slice_id])
            & (view.t_start >= w.t_min - eps)
            & (view.t_end <= w.t_end + eps)
            & (view.duration > 0)
        )
        assigned[mask] = k
    fit_idx = np.nonzero(assigned >= 0)[0]
    fit_view = view.take(fit_idx)
    return fit_view.variants, assigned[fit_idx], fit_view


def _empty_round(windows: Sequence[Window]) -> RoundResult:
    empty = [
        ClearingResult(window=w, selected=(), scores=(), total_score=0.0, n_bids=0)
        for w in windows
    ]
    return RoundResult(tuple(windows), tuple(empty), (), (), 0.0, 0)


def clear_round(
    windows: Sequence[Window],
    variants: Sequence[Variant],
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    selector: Callable = wis_select,
    work_budget: Optional[Mapping[str, float]] = None,
    score_impl: Optional[str] = None,
    recheck_theta: Optional[float] = None,
    grid: int = 32,
    grid_cache=None,
) -> RoundResult:
    """Clear one batched auction round over ALL announced windows.

    Scores the pooled bids in a single batched dispatch, runs WIS per window,
    then resolves cross-window conflicts: a job that wins overlapping
    intervals on two slices keeps only its best-scored win, and (when
    ``work_budget`` maps job_id → biddable work) a job never wins more total
    work than it has — over-budget wins are revoked cheapest-first.  Windows
    that lose a winner are re-cleared against their remaining candidates
    within the round, iterating to a fixed point.

    ``recheck_theta`` re-verifies safety condition (a) in-dispatch against
    each bid's own window capacity (scoring.score_round); ``grid_cache``
    reuses FMP grid discretizations across rounds.  The dispatch/settle
    halves are exposed separately (:func:`assign_bids`, scoring's
    ``score_round_async``, :func:`settle_round`) so the round pipeline can
    overlap them across consecutive rounds.

    Returns a :class:`RoundResult`; ``results`` aligns with ``windows``.
    """
    windows = list(windows)
    if not windows:
        return RoundResult((), (), (), (), 0.0, 0)

    fit, win_idx, fit_view = assign_bids(windows, variants)
    if not fit:
        return _empty_round(windows)

    # -- one batched scoring call over the pooled bids (lines 6–8) ------------
    handle = score_round_async(
        fit, windows, win_idx, policy,
        ages=ages, calibrate=calibrate, impl=score_impl,
        recheck_theta=recheck_theta, grid=grid, grid_cache=grid_cache,
        view=fit_view,
    )
    return settle_round(
        windows, fit, win_idx, handle.result(),
        selector=selector, work_budget=work_budget, view=fit_view,
    )


def settle_round(
    windows: Sequence[Window],
    fit: Sequence[Variant],
    win_idx: Sequence[int],
    scores: np.ndarray,
    *,
    selector: Callable = wis_select,
    work_budget: Optional[Mapping[str, float]] = None,
    view: Optional[PoolView] = None,
) -> RoundResult:
    """The post-scores half of :func:`clear_round`: WIS per window plus
    cross-window conflict resolution to a fixed point (Algorithm 1 line 12
    and step 12b).  Pure given its inputs; the pipeline calls it once the
    in-flight scores of a dispatched round materialize.  ``view`` (the
    struct-of-arrays form of ``fit`` from :func:`assign_bids`) lets the
    per-window WIS passes gather interval arrays instead of re-walking the
    variant objects.
    """
    windows = list(windows)
    if not fit:
        return _empty_round(windows)
    if view is None:
        view = PoolView.build(fit)

    members: List[List[int]] = [[] for _ in windows]  # window -> pool indices
    for i, k in enumerate(win_idx):
        members[k].append(i)

    banned = np.zeros(len(fit), dtype=bool)
    selected_per_window: List[List[int]] = [[] for _ in windows]
    dirty = list(range(len(windows)))
    n_conflicts = 0

    def _reclear(k: int) -> None:
        idx = [i for i in members[k] if not banned[i]]
        if not idx:
            selected_per_window[k] = []
            return
        ia = np.asarray(idx, np.intp)
        sel, _ = selector(view.t_start[ia], view.t_end[ia], scores[ia])
        selected_per_window[k] = [idx[int(j)] for j in np.asarray(sel)]

    # fixed point: each pass bans ≥ 1 variant or terminates, so the loop is
    # bounded by the pool size
    while True:
        for k in dirty:
            _reclear(k)
        dirty = []

        # per-job win lists across all windows, best score first
        wins_by_job: Dict[str, List[int]] = {}
        for k, sel in enumerate(selected_per_window):
            for i in sel:
                wins_by_job.setdefault(fit[i].job_id, []).append(i)
        newly_banned = False
        for job_id, wins in wins_by_job.items():
            if len(wins) < 2 and work_budget is None:
                continue
            wins.sort(key=lambda i: (-scores[i], fit[i].t_start, win_idx[i]))
            kept: List[int] = []
            used_work = 0.0
            budget = None
            if work_budget is not None:
                budget = work_budget.get(job_id)
            for i in wins:
                drop = any(_overlap(fit[i], fit[j]) and win_idx[i] != win_idx[j]
                           for j in kept)
                if not drop and budget is not None:
                    work = float(fit[i].payload["work"]) if fit[i].payload else 0.0
                    if used_work + work > budget + 1e-9:
                        drop = True
                    else:
                        used_work += work
                if drop:
                    banned[i] = True
                    newly_banned = True
                    n_conflicts += 1
                    if win_idx[i] not in dirty:
                        dirty.append(win_idx[i])
                else:
                    kept.append(i)
        if not newly_banned:
            break

    # -- package per-window results + the flattened commit set ----------------
    results: List[ClearingResult] = []
    all_selected: List[Variant] = []
    all_scores: List[float] = []
    for k, w in enumerate(windows):
        sel = sorted(selected_per_window[k], key=lambda i: fit[i].t_start)
        sel_set = set(sel)
        rejected = tuple(fit[i] for i in members[k] if i not in sel_set)
        results.append(
            ClearingResult(
                window=w,
                selected=tuple(fit[i] for i in sel),
                scores=tuple(float(scores[i]) for i in sel),
                total_score=float(sum(scores[i] for i in sel)),
                n_bids=len(members[k]),
                rejected=rejected,
            )
        )
        all_selected.extend(fit[i] for i in sel)
        all_scores.extend(float(scores[i]) for i in sel)
    return RoundResult(
        windows=tuple(windows),
        results=tuple(results),
        selected=tuple(all_selected),
        scores=tuple(all_scores),
        total_score=float(sum(all_scores)),
        n_bids=len(fit),
        n_conflicts=n_conflicts,
    )
