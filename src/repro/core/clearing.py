"""Per-window clearing (paper §4.4, Algorithm 1).

One JASDA iteration over an announced window w*:

    1:  announce w* to all jobs
    4:  each job generates eligible variants V_i (jobs.py)
    6-8: Score(v) = λ ĥ(v) + (1−λ) f̃_sys(v)   (scoring.py + calibration.py)
    11: V = ∪ V_i
    12: Ŝ = SelectBestCompatibleVariants(V, Score)   (wis.py — optimal WIS)
    13: commit Ŝ, update layout and statistics

The function is pure given its inputs; state mutation (commit, age updates,
calibration) is the scheduler's job.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Mapping, Optional, Sequence

import numpy as np

from .scoring import ScoringPolicy, score_pool
from .types import ClearingResult, Variant, Window
from .wis import wis_select

__all__ = ["clear_window"]


def clear_window(
    window: Window,
    variants: Sequence[Variant],
    policy: ScoringPolicy,
    *,
    ages: Optional[Mapping[str, float]] = None,
    calibrate: Optional[Callable[[Variant, float], float]] = None,
    extra_sys: Optional[Callable[[Variant], Mapping[str, float]]] = None,
    selector: Callable = wis_select,
) -> ClearingResult:
    """Score the pooled bids and clear w* optimally (Algorithm 1 lines 6–12).

    ``selector`` is pluggable so benchmarks can swap the numpy DP for the
    JAX/Pallas paths; all return identical selections (tested).
    """
    variants = [v for v in variants if _fits(v, window)]
    if not variants:
        return ClearingResult(
            window=window, selected=(), scores=(), total_score=0.0, n_bids=0
        )

    scores = score_pool(
        variants, window, policy, ages=ages, calibrate=calibrate, extra_sys=extra_sys
    )
    starts = np.array([v.t_start for v in variants])
    ends = np.array([v.t_end for v in variants])
    sel_idx, total = selector(starts, ends, scores)
    sel_set = set(int(i) for i in np.asarray(sel_idx))
    selected = [variants[i] for i in sorted(sel_set, key=lambda i: variants[i].t_start)]
    rejected = [v for i, v in enumerate(variants) if i not in sel_set]
    return ClearingResult(
        window=window,
        selected=tuple(selected),
        scores=tuple(float(scores[i]) for i in sorted(sel_set, key=lambda i: variants[i].t_start)),
        total_score=float(total),
        n_bids=len(variants),
        rejected=tuple(rejected),
    )


def _fits(v: Variant, w: Window, eps: float = 1e-9) -> bool:
    """Clearing-side sanity: variant must lie inside the announced window."""
    return (
        v.slice_id == w.slice_id
        and v.t_start >= w.t_min - eps
        and v.t_end <= w.t_end + eps
        and v.duration > 0
    )
