"""Training substrate: optimizers, schedules, train-step factory."""
from .optimizer import adafactor, adamw, apply_updates, clip_by_global_norm, global_norm  # noqa: F401
from .schedule import constant, warmup_cosine  # noqa: F401
from .trainer import make_eval_step, make_train_step  # noqa: F401
