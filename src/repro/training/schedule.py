"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * (step + 1.0) / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
