"""Optimizers from scratch (no optax): AdamW and Adafactor.

Both follow the (init, update) transformation contract:

    state  = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

AdamW keeps f32 (m, v) — 8 bytes/param of state.  Adafactor factors the
second moment into row/col statistics (~0 bytes/param) and skips momentum —
the fit-critical choice for llama3-405b on 256 chips (DESIGN.md §5).
Optimizer state inherits each parameter's sharding (same tree structure, so
the params' NamedShardings apply; factored stats drop the factored dim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "adafactor", "apply_updates", "global_norm", "clip_by_global_norm", "Optimizer"]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr: Callable, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** step_f
        bc2 = 1.0 - b2 ** step_f
        lr_t = lr(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * _decay_mask(p) * p.astype(jnp.float32))
            return u, m, v

        # per-leaf updates chained with optimization_barrier: forces XLA to
        # finish (and free) one leaf's f32 temporaries before starting the
        # next — peak temp memory is one leaf, not the whole tree
        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        us, ms, vs = [], [], []
        prev = None
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            if prev is not None:
                g, _ = jax.lax.optimization_barrier((g, prev))
            u, m2, v2 = upd(g, m, v, p)
            u = u.astype(p.dtype)  # updates tree in param dtype (memory)
            prev = u
            us.append(u); ms.append(m2); vs.append(v2)
        return (tdef.unflatten(us),
                {"m": tdef.unflatten(ms), "v": tdef.unflatten(vs)})

    return Optimizer(init, update)


def _decay_mask(p) -> float:
    """No weight decay for vectors/scalars (norm scales, biases, gates)."""
    return 1.0 if p.ndim >= 2 else 0.0


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------


def adafactor(lr: Callable, *, eps1: float = 1e-30, eps2: float = 1e-3,
              clip_threshold: float = 1.0, decay_rate: float = 0.8,
              weight_decay: float = 0.0) -> Optimizer:
    """Shazeer & Stern 2018, factored over the two largest dims.

    State per ≥2-D param: row stats (shape minus last dim) + col stats
    (shape minus second-to-last dim); 1-D params fall back to full v.
    """

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        rho = 1.0 - step_f ** (-decay_rate)
        lr_t = lr(step)

        def one(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(p):
                vr = rho * st["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * st["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of v
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                vhat = (vr[..., None] / jnp.maximum(denom[..., None], eps1)) \
                    * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps1))
                new_st = {"vr": vr, "vc": vc}
            else:
                v = rho * st["v"] + (1 - rho) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps1))
                new_st = {"v": v}
            # update clipping (RMS of update ≤ clip_threshold)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            scale = jnp.maximum(
                eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))))
            upd = -lr_t * scale * u
            if weight_decay:
                upd = upd - lr_t * weight_decay * _decay_mask(p) * p.astype(jnp.float32)
            return upd, new_st

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["stats"])
        flat_p = tdef.flatten_up_to(params)
        outs = []
        prev = None
        for g, s, p in zip(flat_g, flat_s, flat_p):
            if prev is not None:  # chain: free one leaf's temps before next
                g, _ = jax.lax.optimization_barrier((g, prev))
            u, st = one(g, s, p)
            u = u.astype(p.dtype)  # updates tree in param dtype (memory)
            prev = u
            outs.append((u, st))
        updates = tdef.unflatten([o[0] for o in outs])
        stats = tdef.unflatten([o[1] for o in outs])
        return updates, {"stats": stats}

    return Optimizer(init, update)
