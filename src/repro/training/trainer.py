"""Train-step factory: grad accumulation, clipping, optimizer, metrics.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) → (params, opt_state, metrics)
suitable for jax.jit with in/out shardings.  The global batch is split into
``microbatches`` chunks accumulated with lax.scan (bounds activation memory;
remat happens inside the model).  Loss/grads are computed in f32.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import Optimizer, apply_updates, clip_by_global_norm

__all__ = ["make_train_step", "make_eval_step", "make_accum_steps"]


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    rules=None,
    microbatches: int = 1,
    attn_impl: str = "auto",
    remat: bool = True,
    clip_norm: Optional[float] = 1.0,
    accum_dtype=jnp.float32,
) -> Callable:
    def loss_fn(params, mb):
        return model.loss_fn(params, mb, rules=rules, impl=attn_impl,
                             remat=remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                x = x.reshape((microbatches, b // microbatches) + x.shape[1:])
                if rules is not None:
                    # keep the batch dim sharded through the reshape —
                    # without this GSPMD may replicate the microbatch stream
                    from jax.sharding import NamedSharding, PartitionSpec
                    ba = rules.batch_axes if rules.batch_axes else None
                    spec = PartitionSpec(None, ba, *([None] * (x.ndim - 2)))
                    x = jax.lax.with_sharding_constraint(
                        x, NamedSharding(rules.mesh, spec))
                return x

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss_sum, grads), _ = jax.lax.scan(
                accum, (jnp.float32(0.0), zeros), micro)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        gnorm = jnp.float32(0.0)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": step + 1}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, *, rules=None, attn_impl: str = "auto"):
    def eval_step(params, batch):
        return model.loss_fn(params, batch, rules=rules, impl=attn_impl,
                             remat=False)
    return eval_step


def make_accum_steps(
    model: Model,
    optimizer: Optimizer,
    *,
    rules=None,
    attn_impl: str = "auto",
    remat: bool = True,
    clip_norm: Optional[float] = 1.0,
    accum_dtype=jnp.bfloat16,
    microbatches: int = 1,
):
    """External gradient accumulation: two jits instead of one.

    The fused in-jit scan holds TWO gradient trees (carry + current) plus
    optimizer temporaries at peak — for 405B-class models that alone blows
    the per-device HBM.  Splitting into

        micro_step(params, grad_acc, micro_batch) → (grad_acc, loss)
        apply_step(params, opt_state, grads, step) → (params, opt_state, metrics)

    lets the caller donate ``grad_acc`` (true in-place accumulation across
    dispatches) so each jit peaks at ONE gradient tree.  This is the
    production pattern for the largest assigned configs (llama3-405b,
    llama-3.2-vision-90b).
    """
    def loss_fn(params, mb):
        return model.loss_fn(params, mb, rules=rules, impl=attn_impl,
                             remat=remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def micro_step(params, grad_acc, micro_batch):
        loss, g = grad_fn(params, micro_batch)
        grad_acc = jax.tree.map(
            lambda a, b: a + b.astype(accum_dtype), grad_acc, g)
        return grad_acc, loss

    def apply_step(params, opt_state, grads, step):
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        gnorm = jnp.float32(0.0)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, {"grad_norm": gnorm, "step": step + 1}

    return micro_step, apply_step
