"""Workload adapter: token-level serving requests through the auction.

The serving engine's docstring states the JASDA integration contract —
"a serving burst is a *job*" — and the streaming service (PR 8) left the
adapter as its carried item.  This module closes the loop WITHOUT
touching either side: a :class:`~repro.serving.engine.Request` maps to a
:class:`~repro.core.types.JobSpec` whose work and memory footprint are
linear token models (prefill work per prompt token + decode work per new
token; KV-cache bytes per token on top of a base residency), and
:class:`ServingArrivals` replays a fixed ``(arrival_time, Request)``
trace through the :class:`~repro.service.arrivals.ArrivalProcess`
machinery, so :class:`~repro.service.engine.JasdaService` drives the
full admit → announce → award → complete timeline for every request.

The trace adapter draws NOTHING from the rng — job synthesis is a pure
function of the request — so two services replaying the same trace are
byte-identical regardless of seed, and the stream pickles mid-trace with
the rest of a service checkpoint (the cursor is an index).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from ..core.trp import fmp_standard
from ..core.types import JobSpec
from .engine import Request
from ..service.arrivals import ArrivalProcess, DeadlineExpired, JobArrival

__all__ = ["ServingArrivals", "request_job_spec"]

_GB = 1 << 30


def request_job_spec(
    req: Request,
    t: float,
    *,
    prefill_work_per_token: float = 0.1,
    decode_work_per_token: float = 0.5,
    kv_gb_per_token: float = 0.01,
    base_mem_gb: float = 2.0,
    deadline_factor: Optional[float] = None,
    prefix: str = "req-",
) -> JobSpec:
    """One serving request as an auction job (linear token cost model).

    Work = prefill·|prompt| + decode·max_new_tokens; steady memory =
    base + kv·(|prompt| + max_new_tokens).  ``deadline_factor`` (optional)
    sets a QoS deadline at ``t + factor × work`` — the serving-side SLO
    expressed in the auction's own deadline machinery.
    """
    n_prompt = int(len(req.prompt))
    n_new = int(req.max_new_tokens)
    work = prefill_work_per_token * n_prompt + decode_work_per_token * n_new
    steady = (base_mem_gb + kv_gb_per_token * (n_prompt + n_new)) * _GB
    fmp = fmp_standard(0.5 * steady, steady, 0.05 * steady, rel_sigma=0.02)
    deadline = t + deadline_factor * work if deadline_factor else None
    return JobSpec(
        job_id=f"{prefix}{req.request_id}",
        arrival_time=t,
        total_work=float(work),
        fmp=fmp,
        qos_deadline=deadline,
        metadata={
            "request_id": req.request_id,
            "prompt_tokens": n_prompt,
            "max_new_tokens": n_new,
        },
    )


class ServingArrivals(ArrivalProcess):
    """Replay a fixed serving trace as an open-loop arrival stream.

    ``requests`` is a sequence of ``(arrival_time, Request)``; events are
    emitted in ``(time, request_id)`` order through the inherited
    ``take_until`` cursor.  Deterministic: no rng draws.
    """

    name = "serving"

    def __init__(
        self,
        requests: Sequence[Tuple[float, Request]],
        *,
        prefill_work_per_token: float = 0.1,
        decode_work_per_token: float = 0.5,
        kv_gb_per_token: float = 0.01,
        base_mem_gb: float = 2.0,
        deadline_factor: Optional[float] = None,
        prefix: str = "req-",
        **kw,
    ):
        trace = sorted(requests, key=lambda r: (r[0], r[1].request_id))
        # a finite t_end is load-bearing: the base take_until loop only
        # exhausts when the next arrival EXCEEDS it
        kw.setdefault("t_end", trace[-1][0] if trace else 0.0)
        super().__init__(prefix=prefix, **kw)
        self.prefill_work_per_token = prefill_work_per_token
        self.decode_work_per_token = decode_work_per_token
        self.kv_gb_per_token = kv_gb_per_token
        self.base_mem_gb = base_mem_gb
        self.deadline_factor = deadline_factor
        self._trace = trace
        self._i = 0

    def _next_arrival(self, prev_t: float) -> float:
        if self._i >= len(self._trace):
            return self.t_end + 1.0  # exhausts the stream
        return max(prev_t, self._trace[self._i][0])

    def _draw_job(self, ta: float) -> None:
        _, req = self._trace[self._i]
        self._i += 1
        self._n += 1
        spec = request_job_spec(
            req, ta,
            prefill_work_per_token=self.prefill_work_per_token,
            decode_work_per_token=self.decode_work_per_token,
            kv_gb_per_token=self.kv_gb_per_token,
            base_mem_gb=self.base_mem_gb,
            deadline_factor=self.deadline_factor,
            prefix=self.prefix,
        )
        self._stage(ta, JobArrival(ta, spec))
        if spec.qos_deadline is not None:
            self._stage(spec.qos_deadline,
                        DeadlineExpired(spec.qos_deadline, spec.job_id))
