"""Serving substrate: slot-based continuous batching engine."""
from .engine import Request, ServeConfig, ServingEngine  # noqa: F401
from .adapter import ServingArrivals, request_job_spec  # noqa: F401
