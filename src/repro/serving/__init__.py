"""Serving substrate: slot-based continuous batching engine."""
from .engine import Request, ServeConfig, ServingEngine  # noqa: F401
