"""Serving engine: continuous batching over a slot-based KV cache.

A fixed pool of B slots shares one stacked cache; requests claim a free
slot, are prefilled individually (cache rows scattered into their slot),
and all active slots decode together each step with a per-slot position
vector.  Finished slots (EOS or max_new_tokens) free immediately and the
next queued request claims them — classic continuous batching.

JASDA integration: a serving burst is a *job* whose subjob variants are
"decode N steps for the active slot set"; the executor (core/executor.py)
bids those into announced windows.  The engine itself is scheduler-free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model

__all__ = ["Request", "ServeConfig", "ServingEngine"]


@dataclass
class Request:
    request_id: str
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 4
    max_seq: int = 256
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig, *, rules=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rules = rules
        B, T = cfg.batch_slots, cfg.max_seq
        self.cache = model.init_cache(B, T)
        self.cross_stack = None
        self.positions = np.zeros((B,), np.int32)  # next write index per slot
        self.last_token = np.zeros((B,), np.int32)
        self.slots: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self._rng = np.random.default_rng(cfg.seed)

        self._decode = jax.jit(
            lambda p, tok, idx, cache: model.decode_step(
                p, tok, idx, cache, rules=rules))
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, rules=rules,
                                          max_seq=cfg.max_seq))

    # -- request lifecycle ------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _claim_slots(self) -> None:
        for b in range(self.cfg.batch_slots):
            if self.slots[b] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into_slot(b, req)

    def _prefill_into_slot(self, b: int, req: Request) -> None:
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, cache1, _ = self._prefill(self.params, prompt)
        # scatter the single-row cache into slot b of the shared cache
        def place(shared, single):
            return shared.at[:, b].set(single[:, 0])
        self.cache = jax.tree.map(place, self.cache, cache1)
        self.slots[b] = req
        self.positions[b] = len(req.prompt)
        self.last_token[b] = int(self._pick(np.asarray(logits)[0]))
        req.output.append(int(self.last_token[b]))

    def _pick(self, logits: np.ndarray) -> int:
        if self.cfg.greedy:
            return int(np.argmax(logits))
        z = logits / max(self.cfg.temperature, 1e-6)
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    # -- one decode tick ----------------------------------------------------
    def step(self) -> int:
        """Prefill waiting requests into free slots, decode all active ones.

        Returns the number of active slots after the step.
        """
        self._claim_slots()
        active = [b for b in range(self.cfg.batch_slots) if self.slots[b] is not None]
        if not active:
            return 0
        tok = jnp.asarray(self.last_token, jnp.int32)
        idx = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode(self.params, tok, idx, self.cache)
        logits = np.asarray(logits)
        for b in active:
            req = self.slots[b]
            nxt = self._pick(logits[b])
            req.output.append(nxt)
            self.positions[b] += 1
            self.last_token[b] = nxt
            hit_eos = req.eos_id is not None and nxt == req.eos_id
            full = len(req.output) >= req.max_new_tokens or \
                self.positions[b] >= self.cfg.max_seq - 1
            if hit_eos or full:
                req.done = True
                self.slots[b] = None  # slot freed; cache row is overwritten
        return sum(1 for s in self.slots if s is not None)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                return
