"""Model assembly: one Model class covering all families.

Execution paths:
  * ``forward``      — full-sequence logits (training / eval).
  * ``prefill``      — full sequence, returns last-position logits + cache.
  * ``decode_step``  — one token against a cache (serving inner loop).

Depth is handled by lax.scan over stacked superblocks (O(1) HLO in depth)
with optional jax.checkpoint (remat) around each superblock.  Caches are
pytrees with a leading superblock axis, scanned alongside the params.

Attention caches:
  * dense/enc-dec/vlm self-attn — linear cache (B, Tmax, Hkv, hd), written
    at ``index`` via dynamic_update_slice.
  * hybrid local-attn — RING cache of size ``window`` with per-slot
    positions (stale slots overwritten; masking uses stored positions, so
    causal+window semantics hold for any index).
  * mamba / rglru — O(1) recurrent state (conv tail + ssm/lru state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, constrain, layer_norm, mlp, rms_norm, rope,
                     softmax_cross_entropy)
from .moe import moe_ffn
from .params import init_params, param_specs
from .rglru import rglru_decode_step, rglru_seq
from .ssm import mamba_decode_step, mamba_seq

__all__ = ["Model"]


def _norm(cfg, x, p, name):
    if cfg.family == "encdec":
        return layer_norm(x, p[f"{name}_scale"], p[f"{name}_bias"], cfg.norm_eps)
    return rms_norm(x, p[f"{name}_scale"], cfg.norm_eps)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(self.cfg, key)

    def specs(self):
        return param_specs(self.cfg)

    # =========================================================================
    # attention building blocks (single layer; leading L stripped by scan)
    # =========================================================================
    def _project_qkv(self, p, hq, hkv=None):
        cfg = self.cfg
        src = hkv if hkv is not None else hq
        q = jnp.einsum("bsd,dhk->bshk", hq, p["wq"])
        k = jnp.einsum("btd,dhk->bthk", src, p["wk"])
        v = jnp.einsum("btd,dhk->bthk", src, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        return q, k, v

    def _attn_out(self, p, out):
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"])

    def _self_attn(self, p, h, positions, *, cache=None, index=None,
                   causal=True, window=None, rules=None, impl="auto"):
        """Returns (attn_out, new_cache or None)."""
        cfg = self.cfg
        q, k, v = self._project_qkv(p, h)
        q = constrain(q, rules, "bshk")
        k = constrain(k, rules, "btkk")
        v = constrain(v, rules, "btkk")
        use_rope = cfg.family != "encdec"
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)

        new_cache = None
        k_pos = positions
        if cache is not None and "slot_pos" in cache:
            # ring cache (windowed local attention)
            w = cache["k"].shape[1]
            s = k.shape[1]
            if s > w:  # prefill longer than the window: keep the last w
                k_w, v_w, pos_w = k[:, -w:], v[:, -w:], positions[:, -w:]
            else:
                k_w, v_w, pos_w = k, v, positions
            slots = pos_w % w
            upd = jax.vmap(lambda c, sl, val: c.at[sl].set(val))
            ck = upd(cache["k"], slots, k_w.astype(cache["k"].dtype))
            cv = upd(cache["v"], slots, v_w.astype(cache["v"].dtype))
            cp = upd(cache["slot_pos"], slots, pos_w)
            new_cache = {"k": ck, "v": cv, "slot_pos": cp}
            if h.shape[1] == 1:  # decode reads from the ring
                k, v, k_pos = ck, cv, cp
            # prefill: attend over the in-flight full k/v (already causal+win)
        elif cache is not None:
            # linear cache: prefill writes a block at scalar `index`; decode
            # (S == 1) writes per-batch rows at a (B,) index vector so
            # continuous batching can hold slots at different depths.
            if k.shape[1] == 1 and getattr(index, "ndim", 0) == 1:
                upd = jax.vmap(lambda c, i, val: jax.lax.dynamic_update_slice_in_dim(
                    c, val, i, axis=0))
                ck = upd(cache["k"], index, k.astype(cache["k"].dtype))
                cv = upd(cache["v"], index, v.astype(cache["v"].dtype))
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), index, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), index, axis=1)
            new_cache = {"k": ck, "v": cv}
            if h.shape[1] == 1 or index is not None:
                t = ck.shape[1]
                k, v = ck, cv
                if k.dtype != cfg.dtype:  # low-precision cache (e.g. f8)
                    k, v = k.astype(cfg.dtype), v.astype(cfg.dtype)
                k_pos = jnp.broadcast_to(jnp.arange(t), (h.shape[0], t))
            k = constrain(k, rules, "btkk")
            v = constrain(v, rules, "btkk")

        # Ulysses-style context parallelism for headdim-sharded archs
        # (head counts not divisible by the model axis): all-to-all the
        # queries from hd-sharded to seq-sharded/full-head layout so the
        # softmax needs no partial-sum all-reduce; k/v gather fully (GQA
        # keeps them small).  Decode (S == 1) keeps the psum path.
        ulysses = (rules is not None and rules.attn_shard == "headdim"
                   and q.shape[1] > 1)
        if ulysses:
            q = constrain(q, rules, "bshk_seq")
            k = constrain(k, rules, "btkk_full")
            v = constrain(v, rules, "btkk_full")
        out = attention(
            q, k, v, q_positions=positions, k_positions=k_pos,
            causal=causal, window=window, impl=impl, rules=rules)
        if ulysses:
            out = constrain(out, rules, "bshk_seq")
        out = constrain(out, rules, "bshk")
        return self._attn_out(p, out), new_cache

    def _cross_attn(self, p, h, cross_kv, rules=None, impl="auto"):
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        if self.cfg.qkv_bias:
            q = q + p["bq"]
        k, v = cross_kv["k"], cross_kv["v"]
        b, s, t = h.shape[0], h.shape[1], k.shape[1]
        out = attention(
            q, k, v,
            q_positions=jnp.zeros((b, s), jnp.int32),
            k_positions=jnp.zeros((b, t), jnp.int32),
            causal=False, impl=impl, rules=rules)
        return self._attn_out(p, out)

    def _mlp_res(self, p, x, rules, gate=None):
        cfg = self.cfg
        h = _norm(cfg, x, p, "ln2")
        out = mlp(h, p["mlp"], gated=cfg.gated_mlp, act=cfg.act, rules=rules)
        if gate is not None:
            out = (out * jnp.tanh(gate)).astype(x.dtype)
        return x + constrain(out, rules, "btd")

    # =========================================================================
    # one block of a given kind
    # =========================================================================
    def _apply_block(self, kind, p, x, positions, *, cache=None, index=None,
                     cross_kv=None, rules=None, impl="auto",
                     aux=None, decode=False):
        cfg = self.cfg
        new_cache = None
        if kind in ("attn", "moe"):
            h = _norm(cfg, x, p, "ln1")
            window = cfg.window if cfg.family == "hybrid" else None
            out, new_cache = self._self_attn(
                p["attn"], h, positions, cache=cache, index=index,
                causal=True, window=window, rules=rules, impl=impl)
            x = x + constrain(out, rules, "btd")
            if kind == "attn":
                x = self._mlp_res(p, x, rules)
            else:
                h2 = _norm(cfg, x, p, "ln2")
                moe_out, aux_l = moe_ffn(
                    h2, p["moe"], top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor,
                    act=cfg.act, gated=cfg.gated_mlp, rules=rules)
                x = x + constrain(moe_out, rules, "btd")
                if aux is not None:
                    aux = aux + aux_l
        elif kind == "cross":
            h = _norm(cfg, x, p, "ln1")
            out = self._cross_attn(p["attn"], h, cross_kv, rules=rules, impl=impl)
            gated = (out * jnp.tanh(p["attn"]["gate_attn"])).astype(x.dtype)
            x = x + constrain(gated, rules, "btd")
            x = self._mlp_res(p, x, rules, gate=p["gate_mlp"])
        elif kind == "mamba":
            h = _norm(cfg, x, p, "ln1")
            if decode:
                out, new_cache = mamba_decode_step(
                    h[:, 0], p["mamba"], cfg, cache, rules=rules)
                out = out[:, None]
            elif cache is not None:  # prefill: also emit the decode state
                out, new_cache = mamba_seq(h, p["mamba"], cfg, rules=rules,
                                           return_cache=True)
            else:
                out = mamba_seq(h, p["mamba"], cfg, rules=rules)
            x = x + constrain(out, rules, "btd")
        elif kind == "rglru":
            h = _norm(cfg, x, p, "ln1")
            if decode:
                out, new_cache = rglru_decode_step(
                    h[:, 0], p["rglru"], cfg, cache, rules=rules)
                out = out[:, None]
            elif cache is not None:  # prefill: also emit the decode state
                out, new_cache = rglru_seq(h, p["rglru"], cfg, rules=rules,
                                           return_cache=True)
            else:
                out = rglru_seq(h, p["rglru"], cfg, rules=rules)
            x = x + constrain(out, rules, "btd")
            x = self._mlp_res(p, x, rules)
        else:
            raise ValueError(kind)
        return x, new_cache, aux

    # =========================================================================
    # superblock stack (scan over depth)
    # =========================================================================
    def _run_stack(self, stack_params, x, positions, *, kinds, cache=None,
                   index=None, cross_kv_stack=None, rules=None, impl="auto",
                   decode=False, remat=True):
        use_cache = cache is not None
        use_cross = cross_kv_stack is not None

        def superblock(x, p_sb, cache_sb, cross_sb):
            # opaque barrier: stops XLA hoisting convert(saved-stack-slice)
            # out of the backward loop as a whole-stack f32 copy (a CPU-LICM
            # space/time trade that doubles remat-save memory)
            x = jax.lax.optimization_barrier(x)
            new_caches = {}
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(kinds):
                name = f"b{i}_{kind}"
                c = cache_sb.get(name) if cache_sb else None
                ckv = cross_sb if kind == "cross" else None
                x, nc, aux = self._apply_block(
                    kind, p_sb[name], x, positions, cache=c, index=index,
                    cross_kv=ckv, rules=rules, impl=impl, aux=aux,
                    decode=decode)
                if nc is not None:
                    new_caches[name] = nc
            x = constrain(x, rules, "btd")
            return x, new_caches, aux

        if remat:
            superblock = jax.checkpoint(
                superblock, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())

        def body(x, layer):
            p_sb = layer[0]
            i = 1
            cache_sb = None
            cross_sb = None
            if use_cache:
                cache_sb = layer[i]; i += 1
            if use_cross:
                cross_sb = layer[i]; i += 1
            x, ncache, aux = superblock(x, p_sb, cache_sb, cross_sb)
            return x, (ncache, aux)

        xs: Tuple = (stack_params,)
        if use_cache:
            xs = xs + (cache,)
        if use_cross:
            xs = xs + (cross_kv_stack,)
        x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
        return x, (new_cache if use_cache or not decode else None), jnp.sum(auxs)

    def _run_tail(self, tail_params, x, positions, *, cache=None, index=None,
                  rules=None, impl="auto", decode=False):
        """Remainder layers (hybrid: 38 % 3 = 2): single-layer stacks."""
        cfg = self.cfg
        new_caches = {}
        for i, kind in enumerate(cfg.superblock[: cfg.n_tail]):
            name = f"t{i}_{kind}"
            p = jax.tree.map(lambda a: a[0], tail_params[name])
            c = jax.tree.map(lambda a: a[0], cache[name]) if cache else None
            x, nc, _ = self._apply_block(
                kind, p, x, positions, cache=c, index=index, rules=rules,
                impl=impl, decode=decode)
            if nc is not None:
                new_caches[name] = jax.tree.map(lambda a: a[None], nc)
        return x, new_caches

    # =========================================================================
    # embedding / head
    # =========================================================================
    def embed(self, params, tokens, positions):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        if "pos_embed" in params:  # whisper decoder: learned/sinusoidal table
            x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(cfg.dtype)
        return x

    def unembed(self, params, x, rules=None):
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        return constrain(logits, rules, "btv")

    def _final_norm(self, params, x):
        cfg = self.cfg
        if cfg.family == "encdec":
            return layer_norm(x, params["final_norm"], params["final_norm_bias"],
                              cfg.norm_eps)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    # =========================================================================
    # encoder / cross-attention memory
    # =========================================================================
    def encode(self, params, frames, rules=None, impl="auto", remat=True):
        cfg = self.cfg
        enc = params["encoder"]
        x = frames.astype(cfg.dtype) + \
            enc["pos_embed"][None, : frames.shape[1]].astype(cfg.dtype)
        b, t, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(t), (b, t))

        def body(x, p):
            h = _norm(cfg, x, p, "ln1")
            out, _ = self._self_attn(p["attn"], h, pos, causal=False,
                                     rules=rules, impl=impl)
            x = x + out
            x = self._mlp_res(p, x, rules)
            return x, None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, enc["blocks"])
        return layer_norm(x, enc["final_norm"], enc["final_norm_bias"],
                          cfg.norm_eps)

    def cross_kv(self, params, memory, rules=None):
        """Precompute cross-attn K/V: {"k","v"} stacked (L_cross, B, T, Hkv, hd)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            stack = params["cross"]["attn"]
        else:  # vlm
            idx = len(cfg.superblock) - 1
            stack = params["blocks"][f"b{idx}_cross"]["attn"]

        def one(wk, wv, bk, bv):
            k = jnp.einsum("btd,dhk->bthk", memory, wk)
            v = jnp.einsum("btd,dhk->bthk", memory, wv)
            if bk is not None:
                k, v = k + bk, v + bv
            return {"k": k, "v": v}

        if cfg.qkv_bias:
            out = jax.vmap(one)(stack["wk"], stack["wv"], stack["bk"], stack["bv"])
        else:
            out = jax.vmap(lambda a, b: one(a, b, None, None))(stack["wk"], stack["wv"])
        return {k: constrain(v, rules, "xbtkk") for k, v in out.items()}

    # =========================================================================
    # full forward (training / eval)
    # =========================================================================
    def forward(self, params, tokens, *, memory=None, rules=None,
                impl="auto", remat=True, positions=None):
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = self.embed(params, tokens, positions)
        x = constrain(x, rules, "btd")

        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "encdec":
            enc_out = self.encode(params, memory, rules=rules, impl=impl,
                                  remat=remat)
            cross_stack = self.cross_kv(params, enc_out, rules=rules)
            x, _ = self._run_encdec_decoder(
                params, x, positions, cross_stack, rules=rules, impl=impl,
                remat=remat, cache=None, index=None)
        else:
            cross_stack = None
            if cfg.family == "vlm":
                cross_stack = self.cross_kv(params, memory.astype(cfg.dtype),
                                            rules=rules)
            x, _, aux = self._run_stack(
                params["blocks"], x, positions, kinds=cfg.superblock,
                cross_kv_stack=cross_stack, rules=rules, impl=impl,
                remat=remat)
            if "tail" in params:
                x, _ = self._run_tail(params["tail"], x, positions,
                                      rules=rules, impl=impl)
        x = self._final_norm(params, x)
        return self.unembed(params, x, rules), aux

    def _run_encdec_decoder(self, params, x, positions, cross_stack, *,
                            rules, impl, remat=True, cache=None, index=None,
                            decode=False):
        cfg = self.cfg
        use_cache = cache is not None

        def layer(x, p_self, p_cross, ckv, c):
            h = _norm(cfg, x, p_self, "ln1")
            out, nc = self._self_attn(p_self["attn"], h, positions,
                                      cache=c, index=index, causal=True,
                                      rules=rules, impl=impl)
            x = x + out
            hx = _norm(cfg, x, p_cross, "lnx")
            x = x + self._cross_attn(p_cross["attn"], hx, ckv, rules=rules,
                                     impl=impl)
            x = self._mlp_res(p_self, x, rules)
            return x, nc

        if remat:
            layer = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.nothing_saveable)

        blocks = params["blocks"]["b0_attn"]
        cross_p = {"lnx_scale": params["cross"]["lnx_scale"],
                   "lnx_bias": params["cross"]["lnx_bias"],
                   "attn": params["cross"]["attn"]}

        def body(x, xs):
            if use_cache:
                p_self, p_cross, ckv, c = xs
            else:
                p_self, p_cross, ckv = xs
                c = None
            return layer(x, p_self, p_cross, ckv, c)

        xs = (blocks, cross_p, cross_stack) + ((cache,) if use_cache else ())
        x, new_cache = jax.lax.scan(body, x, xs)
        return x, (new_cache if use_cache else None)

    # =========================================================================
    # loss
    # =========================================================================
    def loss_fn(self, params, batch, *, rules=None, impl="auto", remat=True):
        cfg = self.cfg
        logits, aux = self.forward(
            params, batch["tokens"], memory=batch.get("memory"),
            rules=rules, impl=impl, remat=remat)
        loss = softmax_cross_entropy(
            logits, batch["labels"], real_vocab=cfg.vocab_size, rules=rules)
        if cfg.family == "moe":
            loss = loss + cfg.router_aux_weight * aux
        return loss

    # =========================================================================
    # serving
    # =========================================================================
    def init_cache(self, batch: int, max_seq: int, dtype=None) -> Dict[str, Any]:
        cfg = self.cfg
        if isinstance(dtype, str):
            dtype = jnp.dtype(dtype)
        dtype = dtype or cfg.dtype
        L = cfg.n_super

        def sub(kind, n):
            if kind in ("attn", "moe"):
                t = min(cfg.window, max_seq) if cfg.family == "hybrid" else max_seq
                c = {"k": jnp.zeros((n, batch, t, cfg.n_kv_heads, cfg.hd), dtype),
                     "v": jnp.zeros((n, batch, t, cfg.n_kv_heads, cfg.hd), dtype)}
                if cfg.family == "hybrid":
                    c["slot_pos"] = jnp.full((n, batch, t), -(10**9), jnp.int32)
                return c
            if kind == "mamba":
                return {"conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                        "ssm": jnp.zeros((n, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}
            if kind == "rglru":
                return {"conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, cfg.lru_dim), dtype),
                        "h": jnp.zeros((n, batch, cfg.lru_dim), jnp.float32)}
            if kind == "cross":
                return None  # handled via cross_stack
            raise ValueError(kind)

        blocks = {}
        for i, kind in enumerate(cfg.superblock):
            c = sub(kind, L)
            if c is not None:
                blocks[f"b{i}_{kind}"] = c
        cache = {"blocks": blocks}
        if cfg.n_tail:
            cache["tail"] = {
                f"t{i}_{kind}": sub(kind, 1)
                for i, kind in enumerate(cfg.superblock[: cfg.n_tail])
            }
        return cache

    def decode_step(self, params, token, index, cache, *, cross_stack=None,
                    rules=None, impl="auto"):
        """token (B,), index scalar or (B,) → (logits (B, Vp), new cache)."""
        cfg = self.cfg
        b = token.shape[0]
        index = jnp.asarray(index, jnp.int32)
        if index.ndim == 0:
            positions = jnp.broadcast_to(index, (b, 1)).astype(jnp.int32)
        else:
            positions = index[:, None]
        x = self.embed(params, token[:, None], positions)
        x = constrain(x, rules, "btd")

        if cfg.family == "encdec":
            x, new_blocks = self._run_encdec_decoder(
                params, x, positions, cross_stack, rules=rules, impl=impl,
                remat=False, cache=cache["blocks"]["b0_attn"], index=index,
                decode=True)
            new_cache = {"blocks": {"b0_attn": new_blocks}}
        else:
            x, new_blocks, _ = self._run_stack(
                params["blocks"], x, positions, kinds=cfg.superblock,
                cache=cache["blocks"], index=index,
                cross_kv_stack=cross_stack, rules=rules, impl=impl,
                decode=True, remat=False)
            new_cache = {"blocks": new_blocks}
            if "tail" in params:
                x, new_tail = self._run_tail(
                    params["tail"], x, positions, cache=cache.get("tail"),
                    index=index, rules=rules, impl=impl, decode=True)
                new_cache["tail"] = new_tail
        x = self._final_norm(params, x)
        logits = self.unembed(params, x, rules)
        return logits[:, 0], new_cache

    def prefill(self, params, tokens, *, memory=None, rules=None, impl="auto",
                max_seq=None):
        """Run the prompt; returns (last logits, cache, cross_stack).

        ``max_seq`` sizes the cache for subsequent decode steps (≥ prompt).
        """
        cfg = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = self.embed(params, tokens, positions)
        x = constrain(x, rules, "btd")
        cache0 = self.init_cache(b, max_seq or s)

        cross_stack = None
        if cfg.family == "encdec":
            enc_out = self.encode(params, memory, rules=rules, impl=impl,
                                  remat=False)
            cross_stack = self.cross_kv(params, enc_out, rules=rules)
            x, new_blocks = self._run_encdec_decoder(
                params, x, positions, cross_stack, rules=rules, impl=impl,
                remat=False, cache=cache0["blocks"]["b0_attn"], index=0)
            cache = {"blocks": {"b0_attn": new_blocks}}
        else:
            if cfg.family == "vlm":
                cross_stack = self.cross_kv(params, memory.astype(cfg.dtype),
                                            rules=rules)
            x, new_blocks, _ = self._run_stack(
                params["blocks"], x, positions, kinds=cfg.superblock,
                cache=cache0["blocks"], index=0, cross_kv_stack=cross_stack,
                rules=rules, impl=impl, remat=False)
            cache = {"blocks": new_blocks}
            if "tail" in params:
                x, new_tail = self._run_tail(
                    params["tail"], x, positions, cache=cache0.get("tail"),
                    index=0, rules=rules, impl=impl)
                cache["tail"] = new_tail
        x = self._final_norm(params, x)
        logits = self.unembed(params, x[:, -1:], rules)
        return logits[:, 0], cache, cross_stack
