"""Core layer primitives (pure functions over param dicts).

All functions take an optional ``rules`` (distributed.sharding.ShardingRules
or None).  ``rules.act(x, kind)`` applies a with_sharding_constraint; with
rules=None everything is unconstrained (CPU smoke tests).

Attention implementations:
  * ``full``     — materialized logits; fine for short seq / decode.
  * ``chunked``  — lax.map over q chunks, full-T softmax per chunk; bounds
                   transient memory to O(cq·T) — the GSPMD-safe flash
                   equivalent used in the sharded dry-run.
  * ``triangle`` — static python loop over q chunks with a growing causal
                   k-extent: halves causal FLOPs at ~n_chunks× HLO size
                   (hillclimb option).
  * ``pallas``   — kernels/flash_attention (TPU executions only).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import flash_attention

__all__ = [
    "rms_norm", "layer_norm", "rope", "attention", "mlp",
    "softmax_cross_entropy", "constrain",
]

NEG_INF = -1e30


def constrain(x, rules, kind: str):
    return rules.act(x, kind) if rules is not None else x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (half-rotation, llama convention)
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask(q_pos, k_pos, causal, window):
    """(B,S),(B,T) → (B,S,T) boolean visibility mask."""
    b, s = q_pos.shape
    t = k_pos.shape[1]
    m = jnp.ones((b, s, t), dtype=bool)
    if causal:
        m = m & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        m = m & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    return m


def _sdpa_full(q, k, v, q_pos, k_pos, *, causal, window, scale, rules):
    """q (B,S,H,hd), k/v (B,T,Hkv,hd) — GQA via head grouping."""
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    ) * scale  # (B, Hkv, g, S, T)
    mask = _mask(q_pos, k_pos, causal, window)  # (B, S, T)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, hd)


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, causal, window, scale, rules,
                  chunk_q: int):
    b, s, hq, hd = q.shape
    n = max(1, s // chunk_q)
    if s % chunk_q:
        n, chunk_q = 1, s

    def one(args):
        qc, qpc = args
        return _sdpa_full(qc, k, v, qpc, k_pos, causal=causal, window=window,
                          scale=scale, rules=rules)

    qs = q.reshape(b, n, chunk_q, hq, hd).swapaxes(0, 1)
    qps = q_pos.reshape(b, n, chunk_q).swapaxes(0, 1)
    out = jax.lax.map(one, (qs, qps))  # (n, B, cq, H, hd)
    return out.swapaxes(0, 1).reshape(b, s, hq, hd)


def _sdpa_triangle(q, k, v, q_pos, k_pos, *, causal, window, scale, rules,
                   chunk_q: int):
    """Static q-chunk loop; k extent grows with the chunk (causal-only)."""
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    n = max(1, s // chunk_q)
    if s % chunk_q:
        return _sdpa_full(q, k, v, q_pos, k_pos, causal=causal, window=window,
                          scale=scale, rules=rules)
    outs = []
    prefix = t - s  # cache prefix before q[0] (0 for self-attn training)
    for i in range(n):
        qc = q[:, i * chunk_q:(i + 1) * chunk_q]
        qpc = q_pos[:, i * chunk_q:(i + 1) * chunk_q]
        k_hi = prefix + (i + 1) * chunk_q
        k_lo = 0
        if window is not None:
            k_lo = max(0, prefix + i * chunk_q - window + 1)
            k_lo = (k_lo // chunk_q) * chunk_q  # align for layout stability
        kc, vc = k[:, k_lo:k_hi], v[:, k_lo:k_hi]
        kpc = k_pos[:, k_lo:k_hi]
        outs.append(_sdpa_full(qc, kc, vc, qpc, kpc, causal=causal,
                               window=window, scale=scale, rules=rules))
    return jnp.concatenate(outs, axis=1)


def attention(
    q, k, v,
    *,
    q_positions,  # (B, S)
    k_positions,  # (B, T)
    causal: bool = True,
    window: Optional[int] = None,
    impl: str = "auto",
    chunk_q: int = 256,  # bounds the (B,H,cq,T) logits transient
    rules=None,
    scale: Optional[float] = None,
):
    """Dispatching scaled-dot-product attention. Layouts: (B, S, H, hd)."""
    b, s, hq, hd = q.shape
    t = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    if impl == "auto":
        impl = "full" if (s * t <= 4096 * 4096 or s == 1) else "chunked"
    if impl == "pallas":
        out = flash_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=causal, window=window, scale=scale,
            q_offset=t - s, impl="pallas",
        ).swapaxes(1, 2)
        return out
    if impl == "full":
        return _sdpa_full(q, k, v, q_positions, k_positions, causal=causal,
                          window=window, scale=scale, rules=rules)
    if impl == "chunked":
        return _sdpa_chunked(q, k, v, q_positions, k_positions, causal=causal,
                             window=window, scale=scale, rules=rules,
                             chunk_q=chunk_q)
    if impl == "triangle":
        return _sdpa_triangle(q, k, v, q_positions, k_positions, causal=causal,
                              window=window, scale=scale, rules=rules,
                              chunk_q=chunk_q)
    raise ValueError(f"unknown attention impl {impl}")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp(x, p, *, gated: bool, act: str, rules=None):
    """Gated (SwiGLU) or plain two-matrix FFN. x: (B, S, D)."""
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = _act(g, act) * u
    else:
        h = _act(jnp.einsum("bsd,df->bsf", x, p["w_up"]) + p.get("b_up", 0.0), act)
    h = constrain(h, rules, "btf")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, *, real_vocab: int, rules=None):
    """Mean CE over tokens; padded vocab entries are masked out.

    logits: (B, S, Vp) in model dtype; computed in f32 via logsumexp.
    labels: (B, S) int32 (−1 = ignore).
    """
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if real_vocab < vp:
        pad_mask = jnp.arange(vp) >= real_vocab
        logits = jnp.where(pad_mask, NEG_INF, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
