"""Parameter templates: single source of truth for shapes, shardings, inits.

A template is a nested dict of ``P`` leaves.  From one template we derive
  * ``init_params``  — actual arrays (traceable; used by smoke tests/examples
    and by jax.eval_shape for the dry-run),
  * ``param_specs``  — a matching pytree of logical PartitionSpecs, where
    axis entries are LOGICAL names ("fsdp", "model", None) resolved to mesh
    axes by distributed.sharding.

Sharding conventions (model axis = 16 on the production mesh):
  * attention: heads on "model" when divisible (attn_shard="heads"), else
    head_dim on "model" (attn_shard="headdim"); kv heads shard only when
    divisible, else replicated (GQA kv ≤ model-axis).
  * MLP: d_ff on "model"; MoE: experts on "model" (moe_shard="expert") or
    expert-FFN dim on "model" (moe_shard="ffn", for E % 16 ≠ 0).
  * FSDP: the d_model dim of every big matrix on "fsdp".
  * embeddings: vocab on "model", d_model on "fsdp".
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

__all__ = ["P", "build_template", "init_params", "param_specs"]


@dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    spec: Tuple  # logical names per dim: "fsdp" | "model" | None
    init: str = "normal"  # normal | zeros | ones | alog | dtbias | lam | pos
    fan_in: Optional[int] = None  # stddev = 1/sqrt(fan_in); default shape[-2]
    dtype: Any = None  # None → cfg.dtype; norms/scalars force f32


# ---------------------------------------------------------------------------
# Template builders
# ---------------------------------------------------------------------------


def _attn_tpl(cfg: ModelConfig, L: int, *, cross: bool = False) -> Dict[str, P]:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ax = cfg.model_axis_size
    if cfg.attn_shard == "heads":
        q_spec = (None, "fsdp", "model", None)
        kv_spec = (None, "fsdp", "model" if Hkv % ax == 0 else None, None)
        o_spec = (None, "model", None, "fsdp")
        bq_spec = (None, "model", None)
        bkv_spec = (None, "model" if Hkv % ax == 0 else None, None)
    else:  # headdim
        q_spec = (None, "fsdp", None, "model")
        kv_spec = (None, "fsdp", None, "model")
        o_spec = (None, None, "model", "fsdp")
        bq_spec = (None, None, "model")
        bkv_spec = (None, None, "model")
    t = {
        "wq": P((L, D, H, hd), q_spec, fan_in=D),
        "wk": P((L, D, Hkv, hd), kv_spec, fan_in=D),
        "wv": P((L, D, Hkv, hd), kv_spec, fan_in=D),
        "wo": P((L, H, hd, D), o_spec, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        t["bq"] = P((L, H, hd), bq_spec, init="zeros")
        t["bk"] = P((L, Hkv, hd), bkv_spec, init="zeros")
        t["bv"] = P((L, Hkv, hd), bkv_spec, init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = P((L, hd), (None, None), init="zeros", dtype=jnp.float32)
        t["k_norm"] = P((L, hd), (None, None), init="zeros", dtype=jnp.float32)
    if cross:
        t["gate_attn"] = P((L,), (None,), init="zeros", dtype=jnp.float32)
    return t


def _mlp_tpl(cfg: ModelConfig, L: int) -> Dict[str, P]:
    D, F = cfg.d_model, cfg.d_ff
    t = {
        "w_up": P((L, D, F), (None, "fsdp", "model"), fan_in=D),
        "w_down": P((L, F, D), (None, "model", "fsdp"), fan_in=F),
    }
    if cfg.gated_mlp:
        t["w_gate"] = P((L, D, F), (None, "fsdp", "model"), fan_in=D)
    if cfg.family == "encdec":  # whisper carries biases
        t["b_up"] = P((L, F), (None, "model"), init="zeros")
        t["b_down"] = P((L, D), (None, None), init="zeros")
    return t


def _norm_tpl(cfg: ModelConfig, L: int, name: str) -> Dict[str, P]:
    D = cfg.d_model
    t = {f"{name}_scale": P((L, D), (None, None), init="zeros", dtype=jnp.float32)}
    if cfg.family == "encdec":  # LayerNorm (scale+bias); others are RMSNorm
        t[f"{name}_bias"] = P((L, D), (None, None), init="zeros", dtype=jnp.float32)
    return t


def _moe_tpl(cfg: ModelConfig, L: int) -> Dict[str, P]:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_expert
    if cfg.moe_shard == "expert":
        up_spec = (None, "model", "fsdp", None)
        down_spec = (None, "model", None, "fsdp")
    else:  # ffn: shard the expert-FFN dim (E not divisible by mesh axis)
        up_spec = (None, None, "fsdp", "model")
        down_spec = (None, None, "model", "fsdp")
    return {
        "router": P((L, D, E), (None, "fsdp", None), fan_in=D, dtype=jnp.float32),
        "w_gate": P((L, E, D, Fe), up_spec, fan_in=D),
        "w_up": P((L, E, D, Fe), up_spec, fan_in=D),
        "w_down": P((L, E, Fe, D), down_spec, fan_in=Fe),
    }


def _mamba_tpl(cfg: ModelConfig, L: int) -> Dict[str, P]:
    D, Dm, N, K, R = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv,
                      cfg.dt_rank_actual)
    return {
        "in_proj": P((L, D, 2, Dm), (None, "fsdp", None, "model"), fan_in=D),
        "conv_w": P((L, K, Dm), (None, None, "model"), fan_in=K),
        "conv_b": P((L, Dm), (None, "model"), init="zeros"),
        "x_proj": P((L, Dm, R + 2 * N), (None, "model", None), fan_in=Dm),
        "dt_proj": P((L, R, Dm), (None, None, "model"), fan_in=R),
        "dt_bias": P((L, Dm), (None, "model"), init="dtbias", dtype=jnp.float32),
        "a_log": P((L, Dm, N), (None, "model", None), init="alog", dtype=jnp.float32),
        "d_skip": P((L, Dm), (None, "model"), init="ones", dtype=jnp.float32),
        "out_proj": P((L, Dm, D), (None, "model", "fsdp"), fan_in=Dm),
    }


def _rglru_tpl(cfg: ModelConfig, L: int) -> Dict[str, P]:
    D, Dr, K = cfg.d_model, cfg.lru_dim, cfg.ssm_conv
    nb = max(1, Dr // 256)  # block-diagonal gate projections (Griffin)
    bs = Dr // nb
    return {
        "in_x": P((L, D, Dr), (None, "fsdp", "model"), fan_in=D),
        "in_gate": P((L, D, Dr), (None, "fsdp", "model"), fan_in=D),
        "conv_w": P((L, K, Dr), (None, None, "model"), fan_in=K),
        "conv_b": P((L, Dr), (None, "model"), init="zeros"),
        "gate_r": P((L, nb, bs, bs), (None, "model", None, None), fan_in=bs),
        "gate_i": P((L, nb, bs, bs), (None, "model", None, None), fan_in=bs),
        "gate_r_b": P((L, Dr), (None, "model"), init="zeros"),
        "gate_i_b": P((L, Dr), (None, "model"), init="zeros"),
        "lam": P((L, Dr), (None, "model"), init="lam", dtype=jnp.float32),
        "out_proj": P((L, Dr, D), (None, "model", "fsdp"), fan_in=Dr),
    }


def _block_tpl(cfg: ModelConfig, kind: str, L: int) -> Dict[str, Any]:
    if kind == "attn":
        return {
            **_norm_tpl(cfg, L, "ln1"), "attn": _attn_tpl(cfg, L),
            **_norm_tpl(cfg, L, "ln2"), "mlp": _mlp_tpl(cfg, L),
        }
    if kind == "moe":
        return {
            **_norm_tpl(cfg, L, "ln1"), "attn": _attn_tpl(cfg, L),
            **_norm_tpl(cfg, L, "ln2"), "moe": _moe_tpl(cfg, L),
        }
    if kind == "mamba":
        return {**_norm_tpl(cfg, L, "ln1"), "mamba": _mamba_tpl(cfg, L)}
    if kind == "rglru":
        return {
            **_norm_tpl(cfg, L, "ln1"), "rglru": _rglru_tpl(cfg, L),
            **_norm_tpl(cfg, L, "ln2"), "mlp": _mlp_tpl(cfg, L),
        }
    if kind == "cross":
        return {
            **_norm_tpl(cfg, L, "ln1"), "attn": _attn_tpl(cfg, L, cross=True),
            **_norm_tpl(cfg, L, "ln2"), "mlp": _mlp_tpl(cfg, L),
            "gate_mlp": P((L,), (None,), init="zeros", dtype=jnp.float32),
        }
    raise ValueError(kind)


def build_template(cfg: ModelConfig) -> Dict[str, Any]:
    D, Vp = cfg.d_model, cfg.padded_vocab
    tpl: Dict[str, Any] = {
        "embed": P((Vp, D), ("model", "fsdp"), fan_in=1),
        "final_norm": _norm_tpl(cfg, 1, "out")["out_scale"],
    }
    tpl["final_norm"] = P((D,), (None,), init="zeros", dtype=jnp.float32)
    if cfg.family == "encdec":
        tpl["final_norm_bias"] = P((D,), (None,), init="zeros", dtype=jnp.float32)
    if not cfg.tie_embeddings:
        tpl["unembed"] = P((D, Vp), ("fsdp", "model"), fan_in=D)
    if cfg.max_pos_embed:
        tpl["pos_embed"] = P((cfg.max_pos_embed, D), (None, "fsdp"), init="pos")

    # superblock stacks
    sb = cfg.superblock
    n_super, n_tail = cfg.n_super, cfg.n_tail
    stack: Dict[str, Any] = {}
    for i, kind in enumerate(sb):
        stack[f"b{i}_{kind}"] = _block_tpl(cfg, kind, n_super)
    tpl["blocks"] = stack
    if n_tail:
        tail: Dict[str, Any] = {}
        for i, kind in enumerate(sb[:n_tail]):
            tail[f"t{i}_{kind}"] = _block_tpl(cfg, kind, 1)
        tpl["tail"] = tail

    if cfg.family == "encdec":
        Le = cfg.n_encoder_layers
        tpl["encoder"] = {
            "pos_embed": P((cfg.encoder_seq, D), (None, "fsdp"), init="pos"),
            "blocks": _block_tpl(cfg, "attn", Le),
            "final_norm": P((D,), (None,), init="zeros", dtype=jnp.float32),
            "final_norm_bias": P((D,), (None,), init="zeros", dtype=jnp.float32),
        }
        # decoder cross-attention stack (parallel to self-attn stack)
        tpl["cross"] = {
            **_norm_tpl(cfg, cfg.n_layers, "lnx"),
            "attn": _attn_tpl(cfg, cfg.n_layers),
        }
    return tpl


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------


def _init_leaf(p: P, key, cfg: ModelConfig):
    dtype = p.dtype or cfg.dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        fan = p.fan_in if p.fan_in else (p.shape[-2] if len(p.shape) >= 2 else p.shape[-1])
        std = 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)
    if p.init == "alog":  # mamba: A = -exp(a_log), a_log = log(1..N)
        l, dm, n = p.shape
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, p.shape).astype(dtype)
    if p.init == "dtbias":  # softplus^-1 of dt ~ LogUniform[1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if p.init == "lam":  # RG-LRU Λ: a^c ∈ [0.9, 0.999], a = sigmoid(Λ), c=8
        u = jax.random.uniform(key, p.shape, jnp.float32, 0.9, 0.999)
        a = u ** (1.0 / 8.0)
        return jnp.log(a / (1 - a)).astype(dtype)
    if p.init == "pos":  # sinusoidal table
        s, d = p.shape
        pos = np.arange(s)[:, None]
        i = np.arange(d)[None, :]
        angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
        tab = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
        return jnp.asarray(tab, dtype)
    raise ValueError(p.init)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    tpl = build_template(cfg)
    leaves, treedef = jax.tree.flatten(tpl, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(p, k, cfg) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    tpl = build_template(cfg)
    return jax.tree.map(
        lambda p: p.spec, tpl, is_leaf=lambda x: isinstance(x, P)
    )
