"""Mamba-1 selective SSM block (falcon-mamba architecture).

Sequence path (training/prefill) uses the associative/pallas linear scan
from kernels/linear_scan over the flattened (Dm·N) state channels; the
decode path is the O(1) single-token state update.

Causal depthwise conv1d (K taps) is expressed as K shifted adds — cheap,
GSPMD-transparent, and exactly matching the decode-side ring buffer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.linear_scan.ops import linear_scan
from .layers import constrain

__all__ = ["mamba_seq", "mamba_decode_step", "causal_conv1d", "conv_step"]


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  prefix: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x (B,S,C), w (K,C), b (C); prefix (B,K-1,C) carries decode state."""
    k = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)  # (B, S+K-1, C)
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(k):
        out = out + w[i] * jax.lax.dynamic_slice_in_dim(xp, i, s, axis=1)
    return out + b


def conv_step(x_t: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              prefix: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token conv. x_t (B,C); prefix (B,K-1,C) → (y, new_prefix)."""
    k = w.shape[0]
    window = jnp.concatenate([prefix, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:, :]


def _ssm_inputs(x_conv, p, cfg):
    """Shared Δ/B/C computation. x_conv (B,S,Dm) post-conv post-silu."""
    R, N = cfg.dt_rank_actual, cfg.ssm_state
    proj = jnp.einsum("bsd,dr->bsr", x_conv, p["x_proj"])  # (B,S,R+2N)
    dt_r, b_ssm, c_ssm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (B,S,Dm)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (Dm,N)
    return dt, a, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def mamba_seq(x: jnp.ndarray, p: Dict, cfg, *, rules=None,
              scan_impl: Optional[str] = None, return_cache: bool = False):
    """Full-sequence mamba mixer. x (B,S,D) → (B,S,D) [, decode cache]."""
    B, S, D = x.shape
    Dm, N = cfg.d_inner, cfg.ssm_state
    K = cfg.ssm_conv
    xz = jnp.einsum("bsd,dcm->bscm", x, p["in_proj"])  # (B,S,2,Dm)
    x1_raw, z = xz[:, :, 0], xz[:, :, 1]
    x1_raw = constrain(x1_raw, rules, "btm")
    x1 = jax.nn.silu(causal_conv1d(x1_raw, p["conv_w"], p["conv_b"]))

    dt, a, b_ssm, c_ssm = _ssm_inputs(x1, p, cfg)
    # discretize: ā = exp(dt·A) (B,S,Dm,N); b̄x = dt·x ⊗ B
    da = jnp.exp(dt[..., None] * a)  # (B,S,Dm,N)
    dbx = (dt * x1.astype(jnp.float32))[..., None] * b_ssm[:, :, None, :]
    h, hT = linear_scan(
        da.reshape(B, S, Dm * N), dbx.reshape(B, S, Dm * N), impl=scan_impl
    )
    h = h.reshape(B, S, Dm, N)
    y = jnp.einsum("bsdn,bsn->bsd", h, c_ssm) + p["d_skip"] * x1.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, rules, "btm")
    out = jnp.einsum("bsm,md->bsd", y, p["out_proj"])
    if not return_cache:
        return out
    pad = jnp.zeros((B, K - 1, Dm), x1_raw.dtype)
    conv_tail = jnp.concatenate([pad, x1_raw], axis=1)[:, -(K - 1):]
    return out, {"conv": conv_tail, "ssm": hT.reshape(B, Dm, N).astype(jnp.float32)}


def mamba_decode_step(
    x_t: jnp.ndarray,  # (B, D) single token
    p: Dict,
    cfg,
    cache: Dict,  # {"conv": (B,K-1,Dm), "ssm": (B,Dm,N) f32}
    *,
    rules=None,
) -> Tuple[jnp.ndarray, Dict]:
    xz = jnp.einsum("bd,dcm->bcm", x_t, p["in_proj"])
    x1, z = xz[:, 0], xz[:, 1]  # (B, Dm)
    xc, new_conv = conv_step(x1, p["conv_w"], p["conv_b"], cache["conv"])
    xc = jax.nn.silu(xc)

    dt, a, b_ssm, c_ssm = _ssm_inputs(xc[:, None, :], p, cfg)
    dt, b_ssm, c_ssm = dt[:, 0], b_ssm[:, 0], c_ssm[:, 0]
    da = jnp.exp(dt[..., None] * a)  # (B,Dm,N)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * b_ssm[:, None, :]
    h = da * cache["ssm"] + dbx  # (B,Dm,N)
    y = jnp.einsum("bdn,bn->bd", h, c_ssm) + p["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    out = jnp.einsum("bm,md->bd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": h}
