"""RG-LRU recurrent block (Griffin / RecurrentGemma).

    r_t = σ(blockdiag(W_r) x_t + b_r)          recurrence gate
    i_t = σ(blockdiag(W_i) x_t + b_i)          input gate
    a_t = a^(c·r_t),  a = σ(Λ),  c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The sequence path reuses kernels/linear_scan; decode is the O(1) update.
The full temporal-mixing block is: in_x branch → conv1d(K) → RG-LRU,
gated by gelu(in_gate branch), then out-projected (Griffin figure 2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.linear_scan.ops import linear_scan
from .layers import constrain
from .ssm import causal_conv1d, conv_step

__all__ = ["rglru_seq", "rglru_decode_step"]

_C = 8.0


def _gates(x, p):
    """Block-diagonal gate projections. x (..., Dr) → r, i (..., Dr)."""
    nb, bs, _ = p["gate_r"].shape
    xb = x.reshape(x.shape[:-1] + (nb, bs)).astype(jnp.float32)
    r = jnp.einsum("...nb,nbc->...nc", xb, p["gate_r"].astype(jnp.float32))
    i = jnp.einsum("...nb,nbc->...nc", xb, p["gate_i"].astype(jnp.float32))
    r = r.reshape(x.shape) + p["gate_r_b"]
    i = i.reshape(x.shape) + p["gate_i_b"]
    return jax.nn.sigmoid(r), jax.nn.sigmoid(i)


def _log_a(p):
    # log a = log σ(Λ) = -softplus(-Λ)
    return -jax.nn.softplus(-p["lam"].astype(jnp.float32))


def rglru_seq(x: jnp.ndarray, p: Dict, cfg, *, rules=None,
              scan_impl: Optional[str] = None, return_cache: bool = False):
    """x (B,S,D) → (B,S,D): conv + RG-LRU branch × gelu gate branch."""
    B, S, _ = x.shape
    K = cfg.ssm_conv
    xr_raw = jnp.einsum("bsd,dm->bsm", x, p["in_x"])  # (B,S,Dr)
    xg = jnp.einsum("bsd,dm->bsm", x, p["in_gate"])
    xr_raw = constrain(xr_raw, rules, "btm")
    xr = causal_conv1d(xr_raw, p["conv_w"], p["conv_b"])

    r, i = _gates(xr, p)
    log_a_t = _C * r * _log_a(p)  # (B,S,Dr), ≤ 0
    a_t = jnp.exp(log_a_t)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a_t), 1e-12)) \
        * i * xr.astype(jnp.float32)
    h, hT = linear_scan(a_t, gated_in, impl=scan_impl)
    y = (h * jax.nn.gelu(xg.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, rules, "btm")
    out = jnp.einsum("bsm,md->bsd", y, p["out_proj"])
    if not return_cache:
        return out
    pad = jnp.zeros((B, K - 1, xr_raw.shape[-1]), xr_raw.dtype)
    conv_tail = jnp.concatenate([pad, xr_raw], axis=1)[:, -(K - 1):]
    return out, {"conv": conv_tail, "h": hT.astype(jnp.float32)}


def rglru_decode_step(
    x_t: jnp.ndarray,  # (B, D)
    p: Dict,
    cfg,
    cache: Dict,  # {"conv": (B,K-1,Dr), "h": (B,Dr) f32}
    *,
    rules=None,
) -> Tuple[jnp.ndarray, Dict]:
    xr = jnp.einsum("bd,dm->bm", x_t, p["in_x"])
    xg = jnp.einsum("bd,dm->bm", x_t, p["in_gate"])
    xc, new_conv = conv_step(xr, p["conv_w"], p["conv_b"], cache["conv"])

    r, i = _gates(xc, p)
    log_a_t = _C * r * _log_a(p)
    a_t = jnp.exp(log_a_t)
    h = a_t * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a_t), 1e-12)) \
        * i * xc.astype(jnp.float32)
    y = (h * jax.nn.gelu(xg.astype(jnp.float32))).astype(x_t.dtype)
    out = jnp.einsum("bm,md->bd", y, p["out_proj"])
    return out, {"conv": new_conv, "h": h}
