"""Mixture-of-Experts FFN: GShard-style grouped capacity dispatch.

Top-k routing with capacity bound per group (dropped tokens pass through
the residual).  The dispatch/combine tensors are one-hot products expressed
as einsums — the formulation GSPMD understands natively, so expert
parallelism over the "model" axis lowers to the canonical all-to-all-free
dispatch (the dispatch einsum contracts the sharded token dim against the
expert-sharded weight dim; XLA inserts the minimal collectives).

Group size bounds the transient dispatch tensor to
(G, g, E, C) with C = g·k/E·cf — set ``group_size`` so this stays ≲ tens of
MB per device.  A shard_map all-to-all path is the §Perf alternative.

Aux load-balancing loss (Switch-style): E·Σ_e f_e·p_e over the pre-capacity
router distribution.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import constrain

__all__ = ["moe_ffn"]


def moe_ffn(
    x: jnp.ndarray,  # (B, S, D)
    p,  # params: router (D,E), w_gate/w_up (E,D,Fe), w_down (E,Fe,D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 512,
    act: str = "silu",
    gated: bool = True,
    rules=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E = p["router"].shape[-1]
    g = min(group_size, S)
    assert S % g == 0, "seq must divide into router groups"
    n_groups = B * (S // g)
    xt = x.reshape(n_groups, g, D)

    # --- routing -----------------------------------------------------------
    logits = jnp.einsum(
        "gsd,de->gse", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # aux loss on the pre-capacity distribution (Switch/GShard)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # --- capacity assignment -------------------------------------------------
    cap = int(g * top_k / E * capacity_factor) + 1
    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (G, g, k, E)
    # priority order: k-slot-major then token order (GShard convention)
    flat = assign.transpose(0, 2, 1, 3).reshape(n_groups, g * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1  # (G, g·k, E) position in expert
    pos = pos.transpose(0, 2, 1).reshape(n_groups, E, top_k, g).transpose(0, 3, 2, 1)
    # pos[g_, s, k_, e]: this token's slot in expert e for its k_-th choice
    slot = jnp.sum(pos * assign, axis=-1)  # (G, g, k)
    keep = slot < cap

    # dispatch (G, g, E, C) = one_hot(expert) × one_hot(slot) × keep
    disp = (
        assign.astype(jnp.bfloat16)[..., None]
        * jax.nn.one_hot(slot, cap, dtype=jnp.bfloat16)[..., None, :]
        * keep.astype(jnp.bfloat16)[..., None, None]
    ).sum(axis=2)  # sum over k → (G, g, E, C)
    combine = (
        assign.astype(jnp.float32)
        * gate_vals[..., None]
        * keep.astype(jnp.float32)[..., None]
    )  # (G, g, k, E)
    comb = (
        combine.astype(jnp.bfloat16)[..., None]
        * jax.nn.one_hot(slot, cap, dtype=jnp.bfloat16)[..., None, :]
    ).sum(axis=2)  # (G, g, E, C)

    # --- expert computation ---------------------------------------------------
    ein = jnp.einsum("gsec,gsd->gecd", disp, xt)  # (G, E, C, D)
    ein = constrain(ein, rules, "gecd")
    if gated:
        hg = jnp.einsum("gecd,edf->gecf", ein, p["w_gate"])
        hu = jnp.einsum("gecd,edf->gecf", ein, p["w_up"])
        h = (jax.nn.silu(hg) if act == "silu" else jax.nn.gelu(hg)) * hu
    else:
        h = jnp.einsum("gecd,edf->gecf", ein, p["w_up"])
        h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    h = constrain(h, rules, "gecf")
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G, E, C, D)
    out = jnp.einsum("gsec,gecd->gsd", comb, out_e)  # (G, g, D)
    return out.reshape(B, S, D).astype(x.dtype), aux.astype(jnp.float32)
