"""Composable model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM."""
from .config import ModelConfig  # noqa: F401
from .model import Model  # noqa: F401
from .params import init_params, param_specs  # noqa: F401
