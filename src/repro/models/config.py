"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` expresses dense / MoE / SSM / hybrid / enc-dec / VLM
backbones.  Layers are organized into homogeneous *superblocks* that are
scan-stacked (O(1) HLO size in depth):

  dense/moe : superblock = 1 block, n_super = n_layers
  ssm       : superblock = 1 mamba block
  hybrid    : superblock = pattern (e.g. rglru, rglru, attn), plus a tail
              stack for the remainder layers
  vlm       : superblock = (cross_attn_every-1) self blocks + 1 cross block
  encdec    : separate encoder (bidirectional) and decoder (self+cross) stacks

Sharding-relevant knobs (``attn_shard``, ``moe_shard``) choose which weight
dim maps onto the mesh "model" axis, because head/expert counts are not
always divisible by 16 (whisper 12H, qwen1.5 20H, qwen3 40H, granite 40E).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads

    # -- attention flavour ---------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False  # qwen3: RMSNorm on q,k per head
    qkv_bias: bool = False  # qwen1.5
    window: Optional[int] = None  # sliding-window for local-attn layers
    gated_mlp: bool = True  # llama/qwen SwiGLU vs whisper/starcoder GELU
    act: str = "silu"

    # -- MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # -- SSM (mamba-1) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model / 16)

    # -- hybrid (recurrentgemma) --------------------------------------------------
    pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "attn")
    lru_width: int = 0  # 0 → d_model

    # -- encoder-decoder (whisper) --------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # stubbed frontend length (whisper: 1500 frames)
    max_pos_embed: int = 0  # >0 → learned/sinusoidal pos table (no RoPE)

    # -- VLM (cross-attention image layers) -------------------------------------------
    cross_attn_every: int = 0  # 5 → one cross layer per 5
    vision_seq: int = 0  # stubbed patch-embedding length

    # -- numerics / sharding ----------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False
    attn_shard: str = "heads"  # heads | headdim (model-axis mapping)
    moe_shard: str = "expert"  # expert | ffn
    # model-axis size the padding rules target (fixed by the production mesh)
    model_axis_size: int = 16

    # ---------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the 'model'-sharded dim divides the mesh axis."""
        return _round_up(self.vocab_size, 128 * self.model_axis_size)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    # superblock decomposition -------------------------------------------------
    @property
    def superblock(self) -> Tuple[str, ...]:
        if self.family in ("dense",):
            return ("attn",)
        if self.family == "moe":
            return ("moe",)
        if self.family == "ssm":
            return ("mamba",)
        if self.family == "hybrid":
            return self.pattern or ("rglru", "rglru", "attn")
        if self.family == "vlm":
            k = self.cross_attn_every or 5
            return ("attn",) * (k - 1) + ("cross",)
        if self.family == "encdec":
            return ("attn",)  # decoder superblock; encoder handled separately
        raise ValueError(self.family)

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.superblock)

    @property
    def n_tail(self) -> int:
        """Remainder layers that do not fill a superblock (hybrid: 38 % 3)."""
        return self.n_layers % len(self.superblock)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        H, Hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        n = V * D * (1 if self.tie_embeddings else 2)  # embed (+unembed)
        attn = D * hd * (H + 2 * Hkv) + H * hd * D
        mlp = (3 if self.gated_mlp else 2) * D * F
        moe = 0
        if self.family == "moe":
            e_mlp = (3 if self.gated_mlp else 2) * D * self.d_expert
            moe = self.n_experts * e_mlp + D * self.n_experts
            mlp = 0
        mamba = 0
        if self.family == "ssm":
            Dm, N, R = self.d_inner, self.ssm_state, self.dt_rank_actual
            mamba = D * 2 * Dm + Dm * self.ssm_conv + Dm * (R + 2 * N) + R * Dm \
                + Dm * N + Dm + Dm * D
            attn = mlp = 0
        per_layer = {
            "dense": attn + mlp,
            "encdec": attn + mlp,
            "moe": attn + moe,
            "ssm": mamba,
            "vlm": attn + mlp,
        }.get(self.family)
        if self.family == "hybrid":
            Dr = self.lru_dim
            rglru = D * 2 * Dr + Dr * self.ssm_conv + 2 * Dr + Dr * D + Dr * Dr // 8
            n_attn = sum(1 for b in self.superblock for _ in [b] if b == "attn") * self.n_super
            n_rec = self.n_layers - n_attn
            return n + n_attn * (attn + mlp) + n_rec * (rglru + mlp)
        total_layers = self.n_layers + self.n_encoder_layers
        return n + total_layers * per_layer

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        e_mlp = (3 if self.gated_mlp else 2) * D * self.d_expert
        dense_part = self.param_count() - self.n_layers * self.n_experts * e_mlp
        return dense_part + self.n_layers * self.top_k * e_mlp
