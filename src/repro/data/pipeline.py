"""Deterministic synthetic token pipeline (shard-aware, prefetched).

Produces reproducible batches as a pure function of (seed, step), so any
host in a multi-host launch generates exactly its own shard — no data
server needed, and checkpoint-restart resumes mid-stream for free (the
stream is stateless in step).

Token statistics follow a Zipf-like power law over the vocab with short
repeated motifs so models have learnable structure (loss decreases —
quickstart/train demos rely on that).  The modality stub for [audio]/[vlm]
archs generates matching synthetic frame/patch embeddings.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "prefetch"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # multi-host sharding: this host yields rows [host_id::n_hosts]
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    # modality stub (encdec/vlm): embeddings (batch, memory_seq, d_model)
    memory_seq: int = 0
    d_model: int = 0


class SyntheticTokens:
    """batch(step) → {"tokens", "labels" [, "memory"]} as numpy arrays."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # precompute zipf probabilities once
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def local_batch_size(self) -> int:
        b, n, h = self.cfg.global_batch, self.cfg.n_hosts, self.cfg.host_id
        assert b % n == 0
        return b // n

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        b = self.local_batch_size()
        toks = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1), p=self._p)
        # inject repeated motifs (predictable continuations)
        m = cfg.motif_len
        motif = rng.choice(cfg.vocab_size, size=(b, m), p=self._p)
        for rep in range(1, cfg.seq_len // (4 * m)):
            start = rep * 4 * m
            toks[:, start:start + m] = motif
        toks = toks.astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.memory_seq and cfg.d_model:
            out["memory"] = rng.standard_normal(
                (b, cfg.memory_seq, cfg.d_model), dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch (overlaps host data gen with device step)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
