"""Deterministic synthetic data pipeline."""
from .pipeline import DataConfig, SyntheticTokens, prefetch  # noqa: F401
