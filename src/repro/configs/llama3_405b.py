"""llama3-405b [dense]: 126L d16384 128H (GQA kv=8) dff 53248 vocab 128256.
[arXiv:2407.21783; unverified]

Fit strategy on 256 chips (DESIGN §5): Adafactor (factored second moment,
no momentum), bf16 params, full remat, 16 microbatches for train_4k;
decode_32k shards the KV cache seq dim on the model axis (kv=8 < 16).
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab_size=128256,
        rope_theta=5e5, act="silu", gated_mlp=True,
        attn_shard="heads", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    optimizer="adafactor",
    microbatches={"train_4k": 16},
    long_context=False,
    grad_accum_dtype="bfloat16",
    seq_shard_train=True,
    external_accum=True,
    decode_shard_kv_seq=True,
    notes="largest assigned config; Adafactor + full remat to fit 4 TB HBM.",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384,
        vocab_size=512, model_axis_size=2, dtype=jnp.float32)
