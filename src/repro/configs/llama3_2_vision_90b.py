"""llama-3.2-vision-90b [vlm]: 100L d8192 64H (GQA kv=8) dff 28672
vocab 128256; cross-attention image layers every 5th layer (20 total);
vision frontend STUBBED — input_specs supplies (B, 1601→1600, 8192)
precomputed patch embeddings. [hf:meta-llama; unverified]
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        cross_attn_every=5, vision_seq=1600,
        rope_theta=5e5, act="silu", gated_mlp=True, attn_shard="heads",
        dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    optimizer="adamw",
    microbatches={"train_4k": 8},
    long_context=False,
    grad_accum_dtype="bfloat16",
    seq_shard_train=True,
    external_accum=True,
    decode_shard_kv_seq=True,
    notes="20 superblocks of (4 self + 1 gated cross); kv=8 < 16 → "
          "decode cache seq-sharded.",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=10, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, vision_seq=16, model_axis_size=2, dtype=jnp.float32)
