"""Assigned input shapes and per-cell input specs (ShapeDtypeStruct).

The four LM shapes from the assignment:
  train_4k     seq 4,096   global_batch 256   → train_step
  prefill_32k  seq 32,768  global_batch 32    → prefill
  decode_32k   seq 32,768  global_batch 128   → decode_step (cache = seq_len)
  long_500k    seq 524,288 global_batch 1     → decode_step, sub-quadratic
                                                 archs only (DESIGN §4)

``input_specs`` returns sharded jax.ShapeDtypeStruct stand-ins for every
input of the lowered function — weak-type-correct, shardable, and never
allocated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..distributed.sharding import ShardingRules
from ..models.config import ModelConfig
from ..models.model import Model

__all__ = ["Shape", "SHAPES", "input_specs", "batch_specs"]


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _memory_shape(cfg: ModelConfig, batch: int) -> Optional[Tuple[int, int, int]]:
    """Modality-stub memory input (frames/patches), already embedded."""
    if cfg.family == "encdec":
        return (batch, cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm":
        return (batch, cfg.vision_seq, cfg.d_model)
    return None


def batch_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules):
    """Train/prefill batch input specs."""
    mesh = rules.mesh
    b_axes = rules.batch_axes if rules.batch_axes else None
    tok = _sds((shape.batch, shape.seq), jnp.int32, mesh, PS(b_axes, None))
    out = {"tokens": tok}
    if shape.kind == "train":
        out["labels"] = _sds((shape.batch, shape.seq), jnp.int32, mesh,
                             PS(b_axes, None))
    mem = _memory_shape(cfg, shape.batch)
    if mem is not None:
        out["memory"] = _sds(mem, jnp.bfloat16, mesh, PS(b_axes, None, None))
    return out


def _shard_like(tree, rules: ShardingRules, kind_fn):
    """Attach NamedShardings to an eval_shape pytree via a kind function."""
    mesh = rules.mesh

    def one(path, leaf):
        spec = kind_fn(path, leaf)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)


def _guard(spec_entries, shape, mesh):
    """Drop axis entries that do not divide the dim (mirror of rules.act)."""
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return PS(*out)


def cache_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules,
                kv_dtype=None):
    """Sharded SDS pytree for the decode cache (never allocated)."""
    model = Model(cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.batch, shape.seq, dtype=kv_dtype))
    b = rules.batch_axes if rules.batch_axes else None
    m = rules.model_axes if rules.model_axes else None
    mesh = rules.mesh

    def kind_fn(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        leafname = names[-1]
        nd = len(leaf.shape)
        if leafname in ("k", "v"):
            # (L, B, T, Hkv, hd)
            if rules.shard_kv_seq:
                entries = (None, b, m, None, None)
            elif rules.attn_shard == "heads" and rules.kv_heads_shardable:
                entries = (None, b, None, m, None)
            elif rules.attn_shard == "headdim":
                entries = (None, b, None, None, m)
            else:
                entries = (None, b, None, None, None)
        elif leafname == "slot_pos":
            entries = (None, b, m if rules.shard_kv_seq else None)
        elif leafname == "conv":
            entries = (None, b, None, m)  # (L, B, K-1, Dm)
        elif leafname == "ssm":
            entries = (None, b, m, None)  # (L, B, Dm, N)
        elif leafname == "h":
            entries = (None, b, m)  # (L, B, Dr)
        else:
            entries = (None,) * nd
        return _guard(entries[:nd], leaf.shape, mesh)

    return _shard_like(cache_shape, rules, kind_fn)


def cross_stack_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules):
    """SDS for precomputed cross-attn K/V (encdec/vlm decode input)."""
    if cfg.family == "encdec":
        t, n = cfg.encoder_seq, cfg.n_layers
    elif cfg.family == "vlm":
        t, n = cfg.vision_seq, cfg.n_super
    else:
        return None
    b = rules.batch_axes if rules.batch_axes else None
    m = rules.model_axes if rules.model_axes else None
    mesh = rules.mesh
    if rules.attn_shard == "heads" and rules.kv_heads_shardable:
        entries = (None, b, None, m, None)
    elif rules.attn_shard == "headdim":
        entries = (None, b, None, None, m)
    else:
        entries = (None, b, None, None, None)
    kv_shape = (n, shape.batch, t, cfg.n_kv_heads, cfg.hd)
    spec = _guard(entries, kv_shape, mesh)
    sds = jax.ShapeDtypeStruct(kv_shape, cfg.dtype,
                               sharding=NamedSharding(mesh, spec))
    return {"k": sds, "v": sds}


def input_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules,
                kv_dtype=None) -> Dict[str, Any]:
    """All inputs for the cell's lowered function, as sharded SDS."""
    mesh = rules.mesh
    b = rules.batch_axes if rules.batch_axes else None
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape, rules)
    # decode: one new token against a filled cache
    out = {
        "token": _sds((shape.batch,), jnp.int32, mesh, PS(b)),
        "index": _sds((shape.batch,), jnp.int32, mesh, PS(b)),
        "cache": cache_specs(cfg, shape, rules, kv_dtype=kv_dtype),
    }
    cross = cross_stack_specs(cfg, shape, rules)
    if cross is not None:
        out["cross_stack"] = cross
    return out
