"""falcon-mamba-7b [ssm]: 64L d4096 attention-free, vocab 65024,
ssm_state=16 (mamba-1 blocks). [arXiv:2410.05355; unverified]

Sub-quadratic: long_500k RUNS (O(1) state per token). d_inner 8192/16 ✓.
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=65024,
        ssm_state=16, ssm_conv=4, ssm_expand=2,
        dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    infer_replicate_fsdp=True,
    optimizer="adamw",
    seq_shard_train=True,
    microbatches={"train_4k": 4},
    long_context=True,
    notes="attention-free; decode state is O(1) — long_500k applicable.",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=0, vocab_size=512, ssm_state=8,
        model_axis_size=2, dtype=jnp.float32)
