"""qwen1.5-4b [dense]: 40L d2560 20H (kv=20, MHA) dff 6912 vocab 151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

20 heads % 16 ≠ 0 → headdim-mode TP (hd 128 / 16 = 8); caches shard hd.
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab_size=151936,
        qkv_bias=True, rope_theta=1e6, act="silu", gated_mlp=True,
        attn_shard="headdim", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    decode_shard_kv_seq=True,
    infer_replicate_fsdp=True,
    optimizer="adamw",
    seq_shard_train=True,
    microbatches={"train_4k": 4},
    long_context=False,
    kv_cache_dtype="float8_e4m3fn",  # MHA kv=20: 3.4 TB cache → 1.7 TB
    notes="MHA kv=20: headdim sharding keeps cache distributed 256-way.",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=5, d_ff=192,
        vocab_size=512, model_axis_size=2, dtype=jnp.float32)
