"""Assigned architecture configs + shapes (one module per arch)."""
from .registry import ARCH_NAMES, ArchInfo, get, reduced  # noqa: F401
from .shapes import SHAPES, Shape, batch_specs, input_specs  # noqa: F401
