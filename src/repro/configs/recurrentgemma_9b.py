"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) dff 12288
vocab 256000; RG-LRU + local attention 1:2 (pattern rglru,rglru,attn),
window 2048. [arXiv:2402.19427; unverified]

38 = 12 superblocks × 3 + 2 tail rglru layers. Sub-quadratic (bounded
window + O(1) LRU state): long_500k RUNS.
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000, head_dim=256,
        pattern=("rglru", "rglru", "attn"), window=2048, lru_width=4096,
        act="gelu", gated_mlp=True, attn_shard="heads",
        dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    infer_replicate_fsdp=True,
    optimizer="adamw",
    seq_shard_train=True,
    microbatches={"train_4k": 4},
    long_context=True,
    notes="ring KV cache bounded at window=2048; kv=1 replicated.",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=512, head_dim=16, window=16, lru_width=64,
        model_axis_size=2, dtype=jnp.float32)
