"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Dict, Mapping, Tuple

from ..models.config import ModelConfig

__all__ = ["ArchInfo", "ARCH_NAMES", "get", "reduced"]

ARCH_NAMES = [
    "whisper_small",
    "starcoder2_15b",
    "qwen1_5_4b",
    "qwen3_14b",
    "llama3_405b",
    "falcon_mamba_7b",
    "olmoe_1b_7b",
    "granite_moe_3b_a800m",
    "recurrentgemma_9b",
    "llama3_2_vision_90b",
]


@dataclass(frozen=True)
class ArchInfo:
    optimizer: str = "adamw"  # adamw | adafactor
    # microbatch count per shape (train only; inference shapes run whole)
    microbatches: Mapping[str, int] = field(
        default_factory=lambda: {"train_4k": 4})
    # run the long_500k cell? (sub-quadratic sequence mixing only)
    long_context: bool = False
    # decode_32k KV-cache sharding: shard T on model (kv heads unshardable)
    decode_shard_kv_seq: bool = False
    # tiny models: replicate params, shard batch over the WHOLE mesh for
    # train/prefill (TP would trade cheap memory for expensive collectives)
    pure_dp: bool = False
    # gradient accumulation dtype ("float32" | "bfloat16"): the biggest
    # models accumulate in bf16 to fit (documented loss-of-precision trade)
    grad_accum_dtype: str = "float32"
    # Megatron-style sequence parallelism on the residual stream for train
    # cells (bounds the per-layer saved-activation stack of deep models)
    seq_shard_train: bool = False
    # lower train as micro_step+apply_step (external accumulation) instead
    # of one fused jit — halves peak gradient memory for the largest models
    external_accum: bool = False
    # decode KV-cache storage dtype (float8 halves MHA caches)
    kv_cache_dtype: str = "bfloat16"
    # attention impl for train cells ("auto"|"chunked"|"triangle"):
    # chunked bounds the O(S²) logits transient for wide-batch pure-DP cells
    train_attn_impl: str = "auto"
    # inference cells: replicate params over the fsdp axis (kills the
    # per-decode-step weight all-gathers; only for models whose TP-sharded
    # params fit replicated — ≲16 B)
    infer_replicate_fsdp: bool = False
    notes: str = ""


def get(name: str) -> Tuple[ModelConfig, ArchInfo]:
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.config(), mod.INFO


def reduced(name: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.reduced()
