"""qwen3-14b [dense]: 40L d5120 40H (GQA kv=8) dff 17408 vocab 151936,
qk_norm. [hf:Qwen/Qwen3-8B; hf]

40 heads % 16 ≠ 0 → headdim-mode TP (hd 128 / 16 = 8).
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab_size=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6, act="silu", gated_mlp=True,
        attn_shard="headdim", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    decode_shard_kv_seq=True,
    infer_replicate_fsdp=True,
    optimizer="adamw",
    seq_shard_train=True,
    microbatches={"train_4k": 4},
    long_context=False,
    notes="qk-norm per head; headdim sharding (40H, 8kv).",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=512, head_dim=16, model_axis_size=2, dtype=jnp.float32)
