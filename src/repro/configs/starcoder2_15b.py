"""starcoder2-15b [dense]: 40L d6144 48H (GQA kv=4) dff 24576 vocab 49152.
GQA + RoPE; GELU FFN (non-gated), per the starcoder2 family.
[arXiv:2402.19173; hf]

48 heads / 16 = 3 → heads-mode TP; kv=4 replicated across the model axis
(weights are small); decode_32k therefore shards the KV cache's SEQ dim
on the model axis (flash-decode layout).
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        gated_mlp=False, act="gelu", rope_theta=1e5,
        attn_shard="heads", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    infer_replicate_fsdp=True,
    optimizer="adamw",
    seq_shard_train=True,
    microbatches={"train_4k": 4},
    long_context=False,
    decode_shard_kv_seq=True,
    notes="kv=4 not divisible by model axis → cache seq-sharded at decode.",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab_size=512, model_axis_size=2, dtype=jnp.float32)
