"""whisper-small [audio]: 12L enc + 12L dec, d768, 12H (kv=12), dff 3072,
vocab 51865; conv frontend STUBBED — input_specs supplies (B, 1500, 768)
precomputed frame embeddings. [arXiv:2212.04356; unverified]

12 heads % 16 ≠ 0 → attn_shard="headdim" (hd 64 / 16 = 4). LayerNorm +
GELU FFN + learned positional table (no RoPE), per the whisper family.
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        n_encoder_layers=12, encoder_seq=1500,
        max_pos_embed=40960,  # covers the decode_32k cache + headroom
        gated_mlp=False, act="gelu", qkv_bias=True,
        attn_shard="headdim", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    infer_replicate_fsdp=True,
    optimizer="adamw",
    microbatches={"train_4k": 1},
    long_context=False,
    decode_shard_kv_seq=True,  # seq-sharded cache: partial softmax, no hd psums
    pure_dp=True,
    train_attn_impl="chunked",  # 0.25B params: replicate, batch over the full mesh
    notes="enc-dec; decode shapes run the DECODER against a stubbed encoder "
          "memory of 1500 frames; long_500k skipped (full attention).",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, encoder_seq=32, max_pos_embed=256,
        model_axis_size=2, dtype=jnp.float32)
