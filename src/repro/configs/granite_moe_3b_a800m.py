"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) expert-dff 512
vocab 49155, MoE 40 experts top-8. [hf:ibm-granite; hf]

40 experts % 16 ≠ 0 → moe_shard="ffn" (expert-FFN dim 512/16=32, experts
replicated); 24 heads % 16 ≠ 0 → headdim TP (hd 64/16=4).
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155,
        n_experts=40, top_k=8, d_expert=512,
        act="silu", gated_mlp=True, attn_shard="headdim",
        moe_shard="ffn", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    decode_shard_kv_seq=True,
    infer_replicate_fsdp=True,
    optimizer="adamw",
    microbatches={"train_4k": 2},
    long_context=False,
    notes="E=40 unshardable on 16 → TP inside experts (moe_shard=ffn).",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=512, n_experts=6, top_k=2, d_expert=32,
        model_axis_size=2, dtype=jnp.float32)
