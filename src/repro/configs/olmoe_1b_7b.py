"""olmoe-1b-7b [moe]: 16L d2048 16H (kv=16) expert-dff 1024 vocab 50304,
MoE 64 experts top-8. [arXiv:2409.02060; hf]

64 experts / 16 = 4 → expert-parallel over the model axis (EP).
"""
import jax.numpy as jnp
from ..models.config import ModelConfig
from .registry import ArchInfo


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, top_k=8, d_expert=1024,
        act="silu", gated_mlp=True, attn_shard="heads",
        moe_shard="expert", dtype=jnp.bfloat16,
    )


INFO = ArchInfo(
    infer_replicate_fsdp=True,
    optimizer="adamw",
    microbatches={"train_4k": 4},
    long_context=False,
    notes="EP over model axis; GShard capacity dispatch (cf=1.25).",
)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=512, n_experts=8, top_k=2, d_expert=64,
        model_axis_size=2, dtype=jnp.float32)
