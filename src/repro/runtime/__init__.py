"""Runtime health: heartbeats, stragglers, elastic pool."""
from .monitor import HealthConfig, HealthMonitor  # noqa: F401
