"""Runtime health: heartbeats, straggler detection, elastic slice pool.

On a real cluster each slice's host posts heartbeats; here the executor
posts them after every chunk.  Detection logic is shared either way:

  * missed heartbeats ≥ ``max_missed`` → slice presumed dead → scheduler
    ``drop_slice`` (its commitments re-enter bidding; elastic scale-down).
  * per-slice speed EWMA (observed/declared duration ratio) below
    ``straggler_ratio`` → flagged; the executor can then de-prefer it via
    the window policy or drop/readmit it at reduced speed.

Note the paper-native mitigation also holds: a straggling slice inflates
observed durations, ex-post ε grows for jobs placed there, and calibration
shifts bids away — monitor-based detection is the explicit counterpart.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

import numpy as np

__all__ = ["HealthMonitor", "HealthConfig", "retry_with_backoff"]


def retry_with_backoff(
    fn: Callable[[int], object],
    *,
    retries: int = 3,
    base: float = 0.05,
    factor: float = 2.0,
    max_delay: float = 1.0,
    jitter: float = 0.0,
    rng: Optional[np.random.Generator] = None,
    sleep: Callable[[float], None] = time.sleep,
    retryable: Callable[[BaseException], bool] = lambda e: True,
):
    """Call ``fn(attempt)`` with capped exponential backoff between retries.

    ``fn`` receives the 0-based attempt index (so callers can make
    per-attempt decisions deterministic).  Up to ``retries`` retries are
    made after the first attempt; the delay before retry ``k`` (1-based)
    is ``min(base * factor**(k-1), max_delay)`` plus a deterministic
    jitter term ``U[0, jitter) * delay`` drawn from ``rng`` — with a
    seeded generator the full delay sequence is reproducible, which is
    what lets fault-injection runs replay byte-identically.

    Exceptions for which ``retryable`` returns False propagate
    immediately; the last exception propagates when attempts are
    exhausted.  ``sleep`` is injectable so simulated time never blocks
    on wall-clock waits (the simulator passes a no-op).
    """
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    last: Optional[BaseException] = None
    for attempt in range(retries + 1):
        try:
            return fn(attempt)
        except BaseException as exc:  # noqa: BLE001 - filtered by `retryable`
            if not retryable(exc) or attempt == retries:
                raise
            last = exc
            delay = min(base * factor**attempt, max_delay)
            if jitter > 0.0 and rng is not None:
                delay += float(rng.uniform(0.0, jitter)) * delay
            if delay > 0.0:
                sleep(delay)
    raise last  # pragma: no cover - unreachable (loop always returns/raises)


@dataclass(frozen=True)
class HealthConfig:
    heartbeat_interval: float = 5.0
    max_missed: int = 3
    straggler_ratio: float = 0.6  # observed speed below 60% of nominal
    speed_halflife: int = 8


@dataclass
class _SliceHealth:
    last_heartbeat: float = 0.0
    speed_ewma: float = 1.0
    n_obs: int = 0


class HealthMonitor:
    def __init__(self, cfg: HealthConfig = HealthConfig()):
        self.cfg = cfg
        self._slices: Dict[str, _SliceHealth] = {}

    def register(self, slice_id: str, now: Optional[float] = None) -> None:
        self._slices[slice_id] = _SliceHealth(
            last_heartbeat=now if now is not None else time.time())

    def remove(self, slice_id: str) -> None:
        self._slices.pop(slice_id, None)

    def heartbeat(self, slice_id: str, now: Optional[float] = None,
                  observed_speed: Optional[float] = None) -> None:
        st = self._slices.setdefault(slice_id, _SliceHealth())
        st.last_heartbeat = now if now is not None else time.time()
        if observed_speed is not None:
            decay = 0.5 ** (1.0 / self.cfg.speed_halflife)
            st.speed_ewma = decay * st.speed_ewma + (1 - decay) * observed_speed
            st.n_obs += 1

    def dead_slices(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()
        limit = self.cfg.heartbeat_interval * self.cfg.max_missed
        return [s for s, st in self._slices.items()
                if now - st.last_heartbeat > limit]

    def stragglers(self) -> List[str]:
        return [s for s, st in self._slices.items()
                if st.n_obs >= 2 and st.speed_ewma < self.cfg.straggler_ratio]

    def speed(self, slice_id: str) -> float:
        st = self._slices.get(slice_id)
        return st.speed_ewma if st else 1.0
