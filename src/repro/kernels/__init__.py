"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel subpackage ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd dispatch wrapper (pallas on TPU, interpret/XLA on CPU)
  ref.py    — pure-jnp oracle, the correctness ground truth

Kernels:
  flash_attention — online-softmax attention (GQA, causal, sliding window)
  linear_scan     — h_t = a_t h_{t-1} + b_t (Mamba / RG-LRU recurrence)
  jasda_score     — paper §4.2: batched variant scoring + FMP safety
  wis_dp          — paper §4.4: on-device weighted-interval-scheduling DP
"""
from .flash_attention.ops import flash_attention  # noqa: F401
from .linear_scan.ops import linear_scan  # noqa: F401
from .jasda_score.ops import score_variants  # noqa: F401
from .wis_dp.ops import wis_clear  # noqa: F401
