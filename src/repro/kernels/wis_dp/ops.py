"""Jit'd wrappers: on-device WIS clearing (sort → DP kernel → backtrack).

``wis_clear`` has the same contract as ``core.wis.wis_select`` (returns
selected ORIGINAL indices sorted ascending by end time + total weight), so
it can be plugged into ``clearing.clear_window(selector=...)`` directly.

``wis_settle_batch`` / ``wis_settle_fused`` are the batched multi-window
forms behind the device-resident round settle (core/wis.py
``RoundSelector``): one dispatch clears EVERY window of an auction round.
They follow the ``jasda_score`` zero-recompile contract — weights and
predecessor tables are runtime operands, shapes are pow2-bucketed by the
caller, and ``trace_counts`` exposes jit cache misses so benchmarks can
assert the cache is never missed across drifting (W, M) rounds.  The fused
form gathers its weights from the IN-FLIGHT device scores of the round's
``jasda_score`` dispatch, so scores flow into selection without a host
round-trip.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import KernelDispatchError, check_dispatch_fault, use_interpret
from .kernel import wis_batch_pallas, wis_dp_pallas
from .ref import wis_batch_reference, wis_dp_reference

__all__ = [
    "wis_clear",
    "wis_dp",
    "wis_settle_batch",
    "wis_settle_fused",
    "trace_counts",
]

# Incremented when a batched-settle jit wrapper RETRACES (python body runs
# only on a jit cache miss) — the settle_throughput benchmark asserts these
# stay flat across rounds with drifting (W, M, scores).
TRACE_COUNT = {"settle_ref": 0, "settle_pallas": 0}


def trace_counts() -> dict:
    """Cumulative retrace counters for the batched settle dispatches."""
    return dict(TRACE_COUNT)


@jax.jit
def _settle_ref_jit(weights, pred):
    TRACE_COUNT["settle_ref"] += 1
    return wis_batch_reference(weights, pred)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _settle_pallas_jit(weights, pred, interpret):
    TRACE_COUNT["settle_pallas"] += 1
    return wis_batch_pallas(weights, pred, interpret=interpret)


def _fused_weights(scores, idx, mask, transform):
    """Gather selection weights from in-flight scores, one shared recipe.

    ``transform`` (same length as ``scores``, or None) is a per-pool-index
    selection-weight multiplier — the policy's score transform (FairShare's
    age boost) quantized to float32, applied IN-DISPATCH so transforming
    backends can consume the fused first pass too.  The product of two
    float32 operands rounded to float32 matches the host path's quantized
    transform by construction.
    """
    safe = jnp.clip(idx, 0, scores.shape[0] - 1)
    w = scores[safe].astype(jnp.float32)
    if transform is not None:
        w = w * transform[safe].astype(jnp.float32)
    return jnp.where(mask, w, 0.0)


@jax.jit
def _settle_ref_fused_jit(scores, idx, mask, pred):
    TRACE_COUNT["settle_ref"] += 1
    return wis_batch_reference(_fused_weights(scores, idx, mask, None), pred)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _settle_pallas_fused_jit(scores, idx, mask, pred, interpret):
    TRACE_COUNT["settle_pallas"] += 1
    return wis_batch_pallas(_fused_weights(scores, idx, mask, None), pred,
                            interpret=interpret)


@jax.jit
def _settle_ref_fused_tr_jit(scores, transform, idx, mask, pred):
    TRACE_COUNT["settle_ref"] += 1
    return wis_batch_reference(
        _fused_weights(scores, idx, mask, transform), pred)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _settle_pallas_fused_tr_jit(scores, transform, idx, mask, pred, interpret):
    TRACE_COUNT["settle_pallas"] += 1
    return wis_batch_pallas(
        _fused_weights(scores, idx, mask, transform), pred,
        interpret=interpret)


# ---------------------------------------------------------------------------
# Mesh-sharded settle: partition the window (row) axis over an auction mesh
# ---------------------------------------------------------------------------

# (mesh, impl, interpret, fused, transformed) -> jitted shard_map wrapper;
# one executable per mesh shape, keeping the zero-retrace contract (the
# inner jit cache stays keyed on bucketed (W, L) shapes only).
_SHARDED_SETTLE_CACHE: dict = {}


def _sharded_settle_fn(mesh, impl: str, interpret: bool, fused: bool,
                       transformed: bool):
    key = (mesh, impl, interpret, fused, transformed)
    fn = _SHARDED_SETTLE_CACHE.get(key)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    row = PS(tuple(mesh.axis_names))
    rep = PS()
    kernel = wis_batch_reference if impl == "ref" else \
        functools.partial(wis_batch_pallas, interpret=interpret)

    if fused:
        # scores (and transform) stay REPLICATED: lanes of any window may
        # index any pool row, so the gather needs the whole scores array —
        # this all-gather of the (M_pad,) score vector is the only
        # cross-shard exchange on the device side of a round
        def body(scores, transform, idx, mask, pred):
            return kernel(_fused_weights(scores, idx, mask, transform), pred)

        if transformed:
            sharded = shard_map(
                body, mesh=mesh,
                in_specs=(rep, rep, row, row, row),
                out_specs=(row, row), check_rep=False)

            @jax.jit
            def call(scores, transform, idx, mask, pred):
                TRACE_COUNT["settle_ref" if impl == "ref" else "settle_pallas"] += 1
                return sharded(scores, transform, idx, mask, pred)
        else:
            sharded = shard_map(
                lambda scores, idx, mask, pred: body(scores, None, idx, mask, pred),
                mesh=mesh, in_specs=(rep, row, row, row),
                out_specs=(row, row), check_rep=False)

            @jax.jit
            def call(scores, idx, mask, pred):
                TRACE_COUNT["settle_ref" if impl == "ref" else "settle_pallas"] += 1
                return sharded(scores, idx, mask, pred)
    else:
        sharded = shard_map(
            kernel, mesh=mesh, in_specs=(row, row),
            out_specs=(row, row), check_rep=False)

        @jax.jit
        def call(weights, pred):
            TRACE_COUNT["settle_ref" if impl == "ref" else "settle_pallas"] += 1
            return sharded(weights, pred)

    _SHARDED_SETTLE_CACHE[key] = call
    return call


def _settle_shards(mesh, rows: int) -> int:
    """Shard count for a (rows, L) settle under ``mesh`` (1 = unsharded)."""
    if mesh is None:
        return 1
    from ...distributed.sharding import auction_row_spec, mesh_size, spec_sharded

    n = mesh_size(mesh)
    if n <= 1 or not spec_sharded(auction_row_spec(mesh, rows)):
        return 1
    return n


def wis_settle_batch(weights, pred, *, impl: Optional[str] = None, mesh=None):
    """Batched multi-window WIS: (W, L) sorted weights/pred → (sel, totals).

    Rows are windows, lanes candidates sorted ascending by end time (the
    host pack in core/wis.py produces the layout); padded / banned lanes
    carry weight 0 and are provably never selected under the strict ``>``
    tie rule.  Returns jax arrays (left in flight — np.asarray to block).

    ``mesh`` shards the window (row) axis via ``shard_map``: each shard
    clears its rows independently (the per-row DP never crosses rows), so
    the sharded dispatch is byte-identical to the single-device one.
    Non-dividing or single-device meshes fall back to unsharded.
    """
    weights = jnp.asarray(weights, jnp.float32)
    pred = jnp.asarray(pred, jnp.int32)
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    shape = tuple(int(s) for s in weights.shape)
    check_dispatch_fault(impl, "wis_settle_batch", shape)
    try:
        if _settle_shards(mesh, weights.shape[0]) > 1:
            return _sharded_settle_fn(mesh, impl, use_interpret(), False, False)(
                weights, pred)
        if impl == "ref":
            return _settle_ref_jit(weights, pred)
        return _settle_pallas_jit(weights, pred, use_interpret())
    except KernelDispatchError:
        raise
    except Exception as exc:
        raise KernelDispatchError(
            impl, "wis_settle_batch", shape, cause=exc) from exc


def wis_settle_fused(scores, idx, mask, pred, *, impl: Optional[str] = None,
                     mesh=None, transform=None):
    """Fused score→clear dispatch: gather weights from IN-FLIGHT scores.

    ``scores`` is the (M_pad,) device array of a ``jasda_score`` round
    dispatch (still in flight); ``idx``/``mask``/``pred`` are the host-built
    (W, L) sorted-lane layout (pool index per lane, validity, predecessor
    counts).  The gather chains on the scoring computation on the async
    stream, so the round's selection never waits on a device→host→device
    round-trip.  Returns the in-flight (sel, totals) pair.

    ``transform`` (optional (M_pad,) float32) multiplies each gathered
    score in-dispatch — the clearing policy's selection transform
    (``ClearingPolicy.prefetch_transform``), which is what lets
    score-transforming backends (FairShare) ride the fused path.  ``mesh``
    shards the window rows; scores/transform stay replicated (any lane may
    gather any pool row).
    """
    scores = jnp.asarray(scores)
    idx = jnp.asarray(idx, jnp.int32)
    mask = jnp.asarray(mask, bool)
    pred = jnp.asarray(pred, jnp.int32)
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if transform is not None:
        transform = jnp.asarray(transform, jnp.float32)
    shape = tuple(int(s) for s in idx.shape)
    check_dispatch_fault(impl, "wis_settle_fused", shape)
    try:
        if _settle_shards(mesh, idx.shape[0]) > 1:
            fn = _sharded_settle_fn(mesh, impl, use_interpret(), True,
                                    transform is not None)
            if transform is not None:
                return fn(scores, transform, idx, mask, pred)
            return fn(scores, idx, mask, pred)
        if transform is not None:
            if impl == "ref":
                return _settle_ref_fused_tr_jit(scores, transform, idx, mask, pred)
            return _settle_pallas_fused_tr_jit(scores, transform, idx, mask, pred,
                                               use_interpret())
        if impl == "ref":
            return _settle_ref_fused_jit(scores, idx, mask, pred)
        return _settle_pallas_fused_jit(scores, idx, mask, pred, use_interpret())
    except KernelDispatchError:
        raise
    except Exception as exc:
        raise KernelDispatchError(
            impl, "wis_settle_fused", shape, cause=exc) from exc


def wis_dp(weights, pred, *, impl: Optional[str] = None):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return wis_dp_reference(jnp.asarray(weights), jnp.asarray(pred))
    return wis_dp_pallas(
        jnp.asarray(weights), jnp.asarray(pred), interpret=use_interpret()
    )


def wis_clear(starts, ends, weights, *, impl: Optional[str] = None) -> Tuple[np.ndarray, float]:
    """Drop-in optimal WIS selector backed by the device DP."""
    starts = np.asarray(starts, np.float64)
    ends = np.asarray(ends, np.float64)
    weights = np.asarray(weights, np.float64)
    m = starts.shape[0]
    if m == 0:
        return np.zeros((0,), np.int64), 0.0

    order = np.argsort(ends, kind="stable")
    s, e, w = starts[order], ends[order], weights[order]
    pred = np.searchsorted(e, s, side="right").astype(np.int32)

    dp, take = wis_dp(w.astype(np.float32), pred, impl=impl)
    dp = np.asarray(dp)
    take = np.asarray(take)

    sel = []
    j = m
    while j > 0:
        if take[j - 1]:
            sel.append(j - 1)
            j = pred[j - 1]
        else:
            j -= 1
    sel = np.array(sel[::-1], dtype=np.int64)
    return order[sel], float(dp[-1]) if m else 0.0
