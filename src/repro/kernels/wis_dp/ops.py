"""Jit'd wrapper: full on-device WIS clearing (sort → DP kernel → backtrack).

``wis_clear`` has the same contract as ``core.wis.wis_select`` (returns
selected ORIGINAL indices sorted ascending by end time + total weight), so
it can be plugged into ``clearing.clear_window(selector=...)`` directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import use_interpret
from .kernel import wis_dp_pallas
from .ref import wis_dp_reference

__all__ = ["wis_clear", "wis_dp"]


def wis_dp(weights, pred, *, impl: Optional[str] = None):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return wis_dp_reference(jnp.asarray(weights), jnp.asarray(pred))
    return wis_dp_pallas(
        jnp.asarray(weights), jnp.asarray(pred), interpret=use_interpret()
    )


def wis_clear(starts, ends, weights, *, impl: Optional[str] = None) -> Tuple[np.ndarray, float]:
    """Drop-in optimal WIS selector backed by the device DP."""
    starts = np.asarray(starts, np.float64)
    ends = np.asarray(ends, np.float64)
    weights = np.asarray(weights, np.float64)
    m = starts.shape[0]
    if m == 0:
        return np.zeros((0,), np.int64), 0.0

    order = np.argsort(ends, kind="stable")
    s, e, w = starts[order], ends[order], weights[order]
    pred = np.searchsorted(e, s, side="right").astype(np.int32)

    dp, take = wis_dp(w.astype(np.float32), pred, impl=impl)
    dp = np.asarray(dp)
    take = np.asarray(take)

    sel = []
    j = m
    while j > 0:
        if take[j - 1]:
            sel.append(j - 1)
            j = pred[j - 1]
        else:
            j -= 1
    sel = np.array(sel[::-1], dtype=np.int64)
    return order[sel], float(dp[-1]) if m else 0.0
