"""Pure-jnp oracle for the WIS clearing DP (paper §4.4).

Operates on intervals ALREADY sorted by end time with precomputed
predecessors p(j) (both produced by ops.py on host/device):

    dp[0] = 0;  dp[j+1] = max(dp[j], w[j] + dp[p[j]])
    take[j] = (w[j] + dp[p[j]] > dp[j])

``wis_dp_reference`` returns (dp[1:], take) for one window; backtracking
runs in ops.py.  ``wis_batch_reference`` is the multi-window form the
device-resident settle dispatches: DP *and* backtrack for a whole
``(W, L)`` padded round in one call (vmapped scan; the backtrack is a
bounded cursor scan, the same control flow the Pallas kernel lowers).
Padded / banned lanes carry weight 0 — with the strict ``>`` tie rule a
zero-weight lane is provably never taken, which is what lets the settle
path ban lanes by zeroing instead of re-sorting (see core/wis.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wis_dp_reference", "wis_batch_reference"]


def wis_dp_reference(weights: jnp.ndarray, pred: jnp.ndarray):
    """(M,) weights, (M,) predecessor counts → (dp (M,), take (M,) bool)."""
    m = weights.shape[0]

    def step(dp, j):
        with_j = weights[j] + dp[pred[j]]
        without_j = dp[j]
        take = with_j > without_j
        dp = dp.at[j + 1].set(jnp.where(take, with_j, without_j))
        return dp, take

    dp0 = jnp.zeros((m + 1,), weights.dtype)
    dp, take = jax.lax.scan(step, dp0, jnp.arange(m))
    return dp[1:], take


def _backtrack_one(take: jnp.ndarray, pred: jnp.ndarray) -> jnp.ndarray:
    """Selection mask (sorted order) from one window's take/pred tables.

    The classical data-dependent while loop (j = pred[j-1] on take, else
    j-1) runs at most L steps because j strictly decreases; expressing it
    as a bounded ``lax.scan`` over a cursor keeps it vmappable across
    windows.  Inactive steps revisit lane 0 with take=False, so the
    scatter-max never flips a decided lane.
    """
    L = take.shape[0]

    def step(j, _):
        jm1 = jnp.maximum(j - 1, 0)
        active = j > 0
        t = jnp.logical_and(active, take[jm1])
        nxt = jnp.where(active, jnp.where(t, pred[jm1], j - 1), 0)
        return nxt, (jm1, t)

    _, (pos, tk) = jax.lax.scan(step, jnp.int32(L), None, length=L)
    sel = jnp.zeros((L,), bool).at[pos].max(tk)
    return sel


def wis_batch_reference(weights: jnp.ndarray, pred: jnp.ndarray):
    """Batched multi-window DP + backtrack.

    Args:
      weights: (W, L) float32, sorted by end time per row, 0 on padded /
        banned lanes.
      pred: (W, L) int32 predecessor counts per row (indexes dp[0..L]).

    Returns:
      (sel (W, L) bool selection mask in SORTED lane order,
       total (W,) float32 optimal totals).
    """
    dp, take = jax.vmap(wis_dp_reference)(weights, pred)
    sel = jax.vmap(_backtrack_one)(take, pred)
    total = dp[:, -1] if dp.shape[-1] else jnp.zeros((dp.shape[0],), weights.dtype)
    return sel, total
