"""Pure-jnp oracle for the WIS clearing DP (paper §4.4).

Operates on intervals ALREADY sorted by end time with precomputed
predecessors p(j) (both produced by ops.py on host/device):

    dp[0] = 0;  dp[j+1] = max(dp[j], w[j] + dp[p[j]])
    take[j] = (w[j] + dp[p[j]] > dp[j])

Returns (dp[1:], take); backtracking runs in ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wis_dp_reference"]


def wis_dp_reference(weights: jnp.ndarray, pred: jnp.ndarray):
    """(M,) weights, (M,) predecessor counts → (dp (M,), take (M,) bool)."""
    m = weights.shape[0]

    def step(dp, j):
        with_j = weights[j] + dp[pred[j]]
        without_j = dp[j]
        take = with_j > without_j
        dp = dp.at[j + 1].set(jnp.where(take, with_j, without_j))
        return dp, take

    dp0 = jnp.zeros((m + 1,), weights.dtype)
    dp, take = jax.lax.scan(step, dp0, jnp.arange(m))
    return dp[1:], take
