"""WIS clearing DP as a Pallas TPU kernel (paper §4.4).

Rationale: when the variant pool is produced on-device by ``jasda_score``,
clearing on-device avoids a host round-trip per scheduling iteration — at
high iteration rates (the paper's "fine-grained, high-frequency scheduling
regimes") the PCIe hop would dominate.  A GPU port of this DP is a
single-threaded loop in one thread block; the TPU version keeps the whole
dp table VMEM-resident (M ≤ ~64k fits easily) and runs the recurrence as a
sequential fori_loop with dynamic VMEM addressing — the grid has a single
program, so there is no cross-core hazard.

The O(M log M) sort + predecessor search stays OUTSIDE the kernel (ops.py:
XLA sort/searchsorted are already optimal); the kernel is the O(M)
data-dependent part XLA cannot fuse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["wis_dp_pallas"]


def _dp_kernel(w_ref, p_ref, dp_ref, take_ref, dp_scr, *, m: int):
    dp_scr[0, 0] = 0.0

    def body(j, _):
        w_j = w_ref[0, j]
        p_j = p_ref[0, j]
        with_j = w_j + dp_scr[0, p_j]
        without_j = dp_scr[0, j]
        take = with_j > without_j
        dp_scr[0, j + 1] = jnp.where(take, with_j, without_j)
        take_ref[0, j] = take.astype(jnp.int32)
        dp_ref[0, j] = dp_scr[0, j + 1]
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wis_dp_pallas(weights: jnp.ndarray, pred: jnp.ndarray, *, interpret: bool = False):
    """(M,) sorted-by-end weights + predecessor table → (dp, take)."""
    m = weights.shape[0]
    dp, take = pl.pallas_call(
        functools.partial(_dp_kernel, m=m),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, m + 1), jnp.float32)],
        interpret=interpret,
    )(weights[None, :].astype(jnp.float32), pred[None, :].astype(jnp.int32))
    return dp[0], take[0].astype(bool)
