"""WIS clearing DP as a Pallas TPU kernel (paper §4.4).

Rationale: when the variant pool is produced on-device by ``jasda_score``,
clearing on-device avoids a host round-trip per scheduling iteration — at
high iteration rates (the paper's "fine-grained, high-frequency scheduling
regimes") the PCIe hop would dominate.  A GPU port of this DP is a
single-threaded loop in one thread block; the TPU version keeps the whole
dp table VMEM-resident (M ≤ ~64k fits easily) and runs the recurrence as a
sequential fori_loop with dynamic VMEM addressing — the grid has a single
program, so there is no cross-core hazard.

The O(M log M) sort + predecessor search stays OUTSIDE the kernel (ops.py:
XLA sort/searchsorted are already optimal); the kernel is the O(M)
data-dependent part XLA cannot fuse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["wis_dp_pallas", "wis_batch_pallas"]


def _dp_kernel(w_ref, p_ref, dp_ref, take_ref, dp_scr, *, m: int):
    dp_scr[0, 0] = 0.0

    def body(j, _):
        w_j = w_ref[0, j]
        p_j = p_ref[0, j]
        with_j = w_j + dp_scr[0, p_j]
        without_j = dp_scr[0, j]
        take = with_j > without_j
        dp_scr[0, j + 1] = jnp.where(take, with_j, without_j)
        take_ref[0, j] = take.astype(jnp.int32)
        dp_ref[0, j] = dp_scr[0, j + 1]
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wis_dp_pallas(weights: jnp.ndarray, pred: jnp.ndarray, *, interpret: bool = False):
    """(M,) sorted-by-end weights + predecessor table → (dp, take)."""
    m = weights.shape[0]
    dp, take = pl.pallas_call(
        functools.partial(_dp_kernel, m=m),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, m + 1), jnp.float32)],
        interpret=interpret,
    )(weights[None, :].astype(jnp.float32), pred[None, :].astype(jnp.int32))
    return dp[0], take[0].astype(bool)


# ---------------------------------------------------------------------------
# Batched multi-window DP + backtrack (device-resident settle, one dispatch)
# ---------------------------------------------------------------------------


def _batch_kernel(w_ref, p_ref, sel_ref, total_ref, dp_scr, take_scr, *, m: int):
    """One grid program = one window: forward DP, then in-kernel backtrack.

    The backward pass is the classical data-dependent walk (j = pred[j-1]
    when lane j-1 was taken, else j-1) expressed as a bounded fori_loop over
    a cursor — j strictly decreases every active step, so m steps always
    reach j = 0; inactive steps rewrite lane 0 with its current value.
    Everything stays VMEM-resident; the grid dimension batches windows.
    """
    dp_scr[...] = jnp.zeros_like(dp_scr)
    sel_ref[...] = jnp.zeros_like(sel_ref)

    def fwd(j, _):
        w_j = w_ref[0, j]
        p_j = p_ref[0, j]
        with_j = w_j + dp_scr[0, p_j]
        without_j = dp_scr[0, j]
        take = with_j > without_j
        dp_scr[0, j + 1] = jnp.where(take, with_j, without_j)
        take_scr[0, j] = take.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, m, fwd, 0)
    total_ref[0, 0] = dp_scr[0, m]

    def bwd(_, j):
        jm1 = jnp.maximum(j - 1, 0)
        active = j > 0
        t = jnp.logical_and(active, take_scr[0, jm1] > 0)
        sel_ref[0, jm1] = jnp.where(t, 1, sel_ref[0, jm1])
        return jnp.where(active, jnp.where(t, p_ref[0, jm1], j - 1), 0)

    jax.lax.fori_loop(0, m, bwd, jnp.int32(m))


@functools.partial(jax.jit, static_argnames=("interpret",))
def wis_batch_pallas(weights: jnp.ndarray, pred: jnp.ndarray, *, interpret: bool = False):
    """Batched WIS: (W, L) sorted weights + predecessors → (sel, totals).

    Same per-row contract as ``wis_batch_reference`` (ref.py): rows are
    windows, lanes are candidates sorted ascending by end time, padded /
    banned lanes carry weight 0 (never taken under the strict ``>`` rule).
    Returns the selection mask in sorted lane order plus per-window optimal
    totals — the whole round's clearing in ONE dispatch.
    """
    w, m = weights.shape
    sel, total = pl.pallas_call(
        functools.partial(_batch_kernel, m=m),
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, m), jnp.int32),
            jax.ShapeDtypeStruct((w, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, m + 1), jnp.float32),
            pltpu.VMEM((1, m), jnp.int32),
        ],
        interpret=interpret,
    )(weights.astype(jnp.float32), pred.astype(jnp.int32))
    return sel.astype(bool), total[:, 0]
