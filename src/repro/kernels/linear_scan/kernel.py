"""Diagonal linear recurrence h_t = a_t·h_{t-1} + b_t as a Pallas TPU kernel.

TPU adaptation of GPU chunked-scan kernels (Mamba's selective scan /
RG-LRU): GPUs split T across thread blocks and stitch with inter-block
carries in shared memory; TPU grids execute SEQUENTIALLY in row-major
order, so the carry simply lives in VMEM scratch across the time-block
axis — no inter-block protocol needed.

  grid = (B, nD, nT), nT last ("arbitrary") so time advances innermost;
  blocks (1, Bt, Bd) of a and b stream through VMEM; the (1, Bd) carry
  persists in scratch.  Within a block the recurrence is a fori_loop over
  Bt rows — elementwise VPU work vectorized across the 128-wide D lanes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["linear_scan_pallas"]


def _scan_kernel(h0_ref, a_ref, b_ref, o_ref, hT_ref, carry, *, block_t: int, n_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)  # (1, Bd)

    a = a_ref[0].astype(jnp.float32)  # (Bt, Bd)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, body, carry[0])
    carry[0, :] = h

    @pl.when(it == n_t - 1)
    def _fin():
        hT_ref[0] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def linear_scan_pallas(
    a: jnp.ndarray,  # (B, T, D)
    b: jnp.ndarray,  # (B, T, D)
    h0: Optional[jnp.ndarray] = None,  # (B, D)
    *,
    block_t: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)
    block_t = min(block_t, T)
    block_d = min(block_d, D)
    assert T % block_t == 0 and D % block_d == 0, "T and D must tile"
    n_t, n_d = T // block_t, D // block_d

    grid = (B, n_d, n_t)  # time innermost → sequential carry is valid
    kernel = functools.partial(_scan_kernel, block_t=block_t, n_t=n_t)
    out, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda b_, id_, it: (b_, id_)),
            pl.BlockSpec((1, block_t, block_d), lambda b_, id_, it: (b_, it, id_)),
            pl.BlockSpec((1, block_t, block_d), lambda b_, id_, it: (b_, it, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b_, id_, it: (b_, it, id_)),
            pl.BlockSpec((1, block_d), lambda b_, id_, it: (b_, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), a.dtype),
            jax.ShapeDtypeStruct((B, D), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(h0, a, b)
    return out, hT
