"""Jit'd dispatch wrapper for the diagonal linear recurrence.

``linear_scan`` picks the implementation:
  * ``impl="pallas"``  — sequential-grid TPU kernel (interpret on CPU)
  * ``impl="assoc"``   — jax.lax.associative_scan (log-depth, XLA-fusible;
                         default under pjit/GSPMD and on CPU)
  * ``impl="scan"``    — jax.lax.scan (serial; smallest memory)
"""
from __future__ import annotations

from typing import Optional

import jax

from ..common import use_interpret
from .kernel import linear_scan_pallas
from .ref import linear_scan_associative, linear_scan_reference

__all__ = ["linear_scan"]


def linear_scan(a, b, h0=None, *, impl: Optional[str] = None,
                block_t: int = 256, block_d: int = 512):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "assoc"
    if impl == "assoc":
        return linear_scan_associative(a, b, h0)
    if impl == "scan":
        return linear_scan_reference(a, b, h0)
    if impl == "pallas":
        return linear_scan_pallas(
            a, b, h0, block_t=block_t, block_d=block_d, interpret=use_interpret()
        )
    raise ValueError(f"unknown impl {impl}")
