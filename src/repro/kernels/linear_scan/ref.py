"""Pure-jnp oracle for the diagonal linear recurrence h_t = a_t·h_{t-1} + b_t.

Two reference implementations:
  * ``linear_scan_reference``       — jax.lax.scan over time (sequential).
  * ``linear_scan_associative``     — jax.lax.associative_scan (log-depth);
    this is also the XLA fast path used by models on non-TPU backends.

Both return the full state trajectory h (B, T, D) and the final state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["linear_scan_reference", "linear_scan_associative"]


def linear_scan_reference(
    a: jnp.ndarray,  # (B, T, D) decay
    b: jnp.ndarray,  # (B, T, D) input
    h0: Optional[jnp.ndarray] = None,  # (B, D)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    hT, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hT


def linear_scan_associative(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blelloch-style: compose (a, b) pairs associatively along T."""
    B, T, D = a.shape
    if h0 is not None:
        # fold h0 into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, b), axis=1)
    return b_c, b_c[:, -1]
