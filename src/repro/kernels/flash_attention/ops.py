"""Jit'd dispatch wrapper for flash attention.

``flash_attention`` picks the implementation:
  * ``impl="pallas"``     — the TPU kernel (compiled on TPU, interpret on CPU)
  * ``impl="xla"``        — the pure-jnp reference (materialized softmax);
                            the right choice inside pjit'd model code on CPU
                            and the GSPMD-sharded dry-run.
  * ``impl=None`` (auto)  — pallas on TPU backends, xla elsewhere.
"""
from __future__ import annotations

from typing import Optional

import jax

from ..common import use_interpret
from .kernel import mha_pallas
from .ref import mha_reference

__all__ = ["flash_attention"]


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    impl: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return mha_reference(
            q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
        )
    if impl == "pallas":
        return mha_pallas(
            q,
            k,
            v,
            causal=causal,
            window=window,
            scale=scale,
            q_offset=q_offset,
            block_q=block_q,
            block_k=block_k,
            interpret=use_interpret(),
        )
    raise ValueError(f"unknown impl {impl}")
