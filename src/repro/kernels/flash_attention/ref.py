"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..common import NEG_INF

__all__ = ["mha_reference"]


def mha_reference(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (None = full)
    scale: Optional[float] = None,
    q_offset: int = 0,  # absolute position of q[0] (decode: cache length)
) -> jnp.ndarray:
    """Materialized-softmax attention; numerically the kernel's ground truth."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)

    # expand kv heads to q heads (GQA)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)

    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale

    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)

    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
