"""Flash attention as a Pallas TPU kernel (online softmax, VMEM tiling).

TPU adaptation of the GPU flash algorithm:
  * grid = (B, Hq, nQ, nK) with the LAST axis "arbitrary" — TPU executes the
    grid sequentially in row-major order, so the (m, l, acc) running state
    for one (b, h, iq) lives in VMEM scratch across the nK sweep (the GPU
    version keeps it in registers/shared memory across the inner loop).
  * BlockSpecs put a (Bq, D) query tile and (Bk, D) key/value tiles in VMEM;
    Bq = Bk = 128 aligns the MXU contraction dims (multiples of 128).
  * GQA is expressed in the k/v index_map (head h reads kv head h // group),
    so no repeated-KV materialization ever happens.
  * causal + sliding-window masks are applied with position arithmetic; a
    fully-masked k block is skipped with @pl.when (the sequential-grid
    analogue of the GPU early-exit).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ..common import NEG_INF

__all__ = ["mha_pallas"]


def _attn_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,  # output tile
    m_scr, l_scr, acc_scr,  # VMEM scratch carried across the nK axis
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    n_k: int,
    q_offset: int,
):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Block-level skip: with causality the earliest q in this tile bounds
    # which k tiles can contribute; same for the window's trailing edge.
    first_q = iq * block_q + q_offset
    last_q = first_q + block_q - 1
    needed = True
    if causal:
        needed = ik * block_k <= last_q
    if window is not None:
        needed = jnp.logical_and(needed, (ik + 1) * block_k - 1 > first_q - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (Bk, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (Bq, Bk)

        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0:1]  # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)

        p = jnp.exp(s - m_new)  # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)  # (Bq, 1)

        l_new = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[:, 0:1] = m_new
        l_scr[:, 0:1] = l_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "block_q", "block_k", "interpret", "q_offset",
    ),
)
def mha_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, "seq dims must tile"
    n_q, n_k = sq // block_q, sk // block_k

    grid = (b, hq, n_q, n_k)
    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # m
            pltpu.VMEM((block_q, 1), jnp.float32),  # l
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
