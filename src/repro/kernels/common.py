"""Shared kernel utilities: dispatch policy + numerics helpers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["use_interpret", "log_ndtr", "NEG_INF"]

NEG_INF = -1e30  # large-negative for masking (avoids inf-inf NaNs in bf16)


@functools.cache
def use_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode on non-TPU backends.

    interpret=True executes the kernel body with jnp on CPU — bit-identical
    control flow to the TPU lowering, used for CI validation against ref.py.
    """
    return jax.default_backend() != "tpu"


def log_ndtr(z: jnp.ndarray) -> jnp.ndarray:
    """Numerically stable log Φ(z) built from lax primitives only.

    Safe inside Pallas kernel bodies (no scipy).  For z ≥ −1 uses
    log1p(−Φ̄(z)) via erfc; for z < −1 uses the erfc-scaled form
    log(erfcx(−z/√2)/2) − z²/2, stable far into the left tail.
    """
    z = jnp.asarray(z)
    sqrt_half = 0.7071067811865476
    x = z * sqrt_half
    # right/central region
    right = jnp.log1p(-0.5 * jax.lax.erfc(x))
    # left tail: Φ(z) = erfc(-x)/2 = erfcx(-x)·exp(-x²)/2
    left = jnp.log(0.5 * jax.lax.erfc(-x).clip(min=1e-300))
    # erfc underflows around z < -37 in f64 / z < -13 in f32; asymptotic form:
    #   logΦ(z) ≈ -z²/2 - log(-z√(2π))  for z → -∞
    asym = -0.5 * z * z - jnp.log(-z * 2.5066282746310002 + 1e-30)
    out = jnp.where(z >= -1.0, right, jnp.where(z >= -10.0, left, asym))
    return out
