"""Shared kernel utilities: dispatch policy, fault surface + numerics.

Besides the numerics helpers this module owns the kernel packages' fault
surface: every device dispatch in ``jasda_score`` and ``wis_dp`` funnels
raw XLA/pallas errors into a typed :class:`KernelDispatchError` (backend +
bucketed operand shape attached), and :class:`BackendHealth` is the sticky
per-backend ladder state the scheduler uses to degrade pallas → ref →
host numpy without ever re-trying a backend that failed once (so the
zero-retrace contract holds per HEALTHY backend: a jit cache is only ever
consulted while its backend is trusted, and abandoning a backend abandons
its cache wholesale instead of thrashing it).

``inject_dispatch_fault`` is the deterministic fault-injection hook for
tests and the simulator's ``device_dispatch_fail`` event: it arms ONE
failure for a named backend; the next dispatch on that backend raises
``KernelDispatchError`` before touching the device.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "use_interpret",
    "log_ndtr",
    "NEG_INF",
    "KernelDispatchError",
    "BackendHealth",
    "DEGRADATION_LADDER",
    "inject_dispatch_fault",
    "clear_dispatch_faults",
    "check_dispatch_fault",
    "dispatch_faults_snapshot",
    "restore_dispatch_faults",
]

NEG_INF = -1e30  # large-negative for masking (avoids inf-inf NaNs in bf16)

#: backend order the scheduler walks when a dispatch fails; "numpy" is the
#: host float64 reference and never raises KernelDispatchError.
DEGRADATION_LADDER = ("pallas", "ref", "numpy")


class KernelDispatchError(RuntimeError):
    """A device dispatch failed; carries backend + bucketed operand shape.

    Raised instead of whatever XLA/pallas error surfaced so callers can
    (a) tell WHICH backend of a fused round failed and at what bucket
    shape (real-TPU debugging: bucket shape identifies the retraced
    executable), and (b) drive the degradation ladder on a stable type
    rather than string-matching runtime errors.
    """

    def __init__(self, backend: str, op: str,
                 shape: Tuple[int, ...] = (),
                 cause: Optional[BaseException] = None):
        self.backend = backend
        self.op = op
        self.shape = tuple(int(s) for s in shape)
        self.cause = cause
        detail = f" <- {type(cause).__name__}: {cause}" if cause else ""
        super().__init__(
            f"{op}[{backend}] dispatch failed at bucket shape "
            f"{self.shape}{detail}")


class BackendHealth:
    """Sticky per-backend health: once a backend fails it stays failed.

    One instance is shared by a scheduler's scoring AND settle dispatches
    so a pallas failure observed while scoring also steers the round's
    WIS settle away from pallas.  ``resolve(preferred)`` walks the
    degradation ladder from the preferred backend to the first healthy
    one ("numpy" is always healthy — the host reference path has no
    device to lose).  Stickiness is what makes fault landing
    deterministic across serial and pipelined runs: after the first
    failure the chosen backend no longer depends on WHEN subsequent
    dispatches happen.
    """

    def __init__(self) -> None:
        self._failed: Dict[str, str] = {}

    def mark_failed(self, backend: str, reason: str = "") -> None:
        self._failed.setdefault(backend, reason)

    def healthy(self, backend: str) -> bool:
        return backend not in self._failed

    def resolve(self, preferred: str) -> str:
        """First healthy backend at or below ``preferred`` on the ladder."""
        if preferred not in DEGRADATION_LADDER:
            return preferred if self.healthy(preferred) else "numpy"
        start = DEGRADATION_LADDER.index(preferred)
        for backend in DEGRADATION_LADDER[start:]:
            if self.healthy(backend):
                return backend
        return "numpy"

    def failed_backends(self) -> Dict[str, str]:
        return dict(self._failed)

    # snapshot/restore hooks used by checkpointed crash recovery ---------
    def snapshot(self) -> Dict[str, str]:
        return dict(self._failed)

    def restore(self, snap: Dict[str, str]) -> None:
        self._failed = dict(snap)


# Armed one-shot dispatch faults: backend -> remaining failure count.
# Module-level (not per-scheduler) because the dispatch functions in the
# kernel packages are free functions; determinism comes from the FAULT PLAN
# arming them at seeded times, and stickiness of BackendHealth means at most
# the FIRST dispatch after arming observes the fault.
_ARMED_FAULTS: Dict[str, int] = {}


def inject_dispatch_fault(backend: str, count: int = 1) -> None:
    """Arm ``count`` dispatch failures for ``backend`` (test/sim hook)."""
    _ARMED_FAULTS[backend] = _ARMED_FAULTS.get(backend, 0) + int(count)


def clear_dispatch_faults() -> None:
    _ARMED_FAULTS.clear()


def dispatch_faults_snapshot() -> Dict[str, int]:
    """Armed-but-unfired faults (checkpointed so crash restore replays a
    fault armed between the checkpoint and the crash exactly once)."""
    return dict(_ARMED_FAULTS)


def restore_dispatch_faults(snap: Dict[str, int]) -> None:
    _ARMED_FAULTS.clear()
    _ARMED_FAULTS.update({k: int(v) for k, v in snap.items()})


def check_dispatch_fault(backend: str, op: str,
                         shape: Tuple[int, ...] = ()) -> None:
    """Raise KernelDispatchError if a fault is armed for ``backend``."""
    n = _ARMED_FAULTS.get(backend, 0)
    if n > 0:
        if n == 1:
            _ARMED_FAULTS.pop(backend, None)
        else:
            _ARMED_FAULTS[backend] = n - 1
        raise KernelDispatchError(
            backend, op, shape,
            cause=RuntimeError("injected dispatch fault"))


@functools.cache
def use_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode on non-TPU backends.

    interpret=True executes the kernel body with jnp on CPU — bit-identical
    control flow to the TPU lowering, used for CI validation against ref.py.
    """
    return jax.default_backend() != "tpu"


def log_ndtr(z: jnp.ndarray) -> jnp.ndarray:
    """Numerically stable log Φ(z) built from lax primitives only.

    Safe inside Pallas kernel bodies (no scipy).  For z ≥ −1 uses
    log1p(−Φ̄(z)) via erfc; for z < −1 uses the erfc-scaled form
    log(erfcx(−z/√2)/2) − z²/2, stable far into the left tail.
    """
    z = jnp.asarray(z)
    sqrt_half = 0.7071067811865476
    x = z * sqrt_half
    # right/central region
    right = jnp.log1p(-0.5 * jax.lax.erfc(x))
    # left tail: Φ(z) = erfc(-x)/2 = erfcx(-x)·exp(-x²)/2
    left = jnp.log(0.5 * jax.lax.erfc(-x).clip(min=1e-300))
    # erfc underflows around z < -37 in f64 / z < -13 in f32; asymptotic form:
    #   logΦ(z) ≈ -z²/2 - log(-z√(2π))  for z → -∞
    asym = -0.5 * z * z - jnp.log(-z * 2.5066282746310002 + 1e-30)
    out = jnp.where(z >= -1.0, right, jnp.where(z >= -10.0, left, asym))
    return out
