"""Jit'd wrapper for batched variant scoring: padding + bucketed dispatch.

Zero-recompile contract (see kernel.py): λ, capacity and θ are traced
runtime operands — scalars or per-variant vectors — so the jit cache is
keyed by SHAPES only.  To keep drifting pool sizes from retracing, M is
padded to power-of-two buckets (min ``MIN_BUCKET_M``): round k with 700
bids and round k+1 with 900 both dispatch the 1024-row executable.  Padded
rows are self-masking (capacity 0 with mu > 0 is a deterministic violation
→ ineligible, score 0) and sliced off before returning.

``pool_to_arrays_round`` packs a pooled auction round into struct-of-arrays
form with a single python walk over the pool; FMP grid discretizations are
memoized in a bounded :class:`FMPGridCache` scoped per scheduler / per round
(NOT process-global — see the cache's docstring).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import KernelDispatchError, check_dispatch_fault, use_interpret
from .kernel import TRACE_COUNT as _PALLAS_TRACE_COUNT
from .kernel import score_variants_pallas
from .ref import score_variants_reference

__all__ = [
    "score_variants",
    "score_variants_numpy",
    "pool_to_arrays",
    "pool_to_arrays_round",
    "PackedRound",
    "FMPGridCache",
    "MIN_BUCKET_M",
    "bucket_m",
    "trace_counts",
]

# Smallest M-bucket: pools below this pad up to one shared executable; above,
# buckets double (256, 512, 1024, ...) so the jit cache stays O(log M_max).
MIN_BUCKET_M = 256

TRACE_COUNT = {"ref": 0}


def trace_counts() -> dict:
    """Retrace counters per dispatch path (jit cache misses, cumulative).

    The python body of a jitted wrapper runs only when jax (re)traces it, so
    these stay flat across calls that hit the cache — the property the
    ``score_dispatch`` benchmark gates on.
    """
    return {"pallas": _PALLAS_TRACE_COUNT["pallas"], "ref": TRACE_COUNT["ref"]}


def bucket_m(m: int) -> int:
    """Pad target for a pool of ``m`` rows: next power of two, min bucket."""
    return max(MIN_BUCKET_M, 1 << int(np.ceil(np.log2(max(m, 1)))))


def _pad_rows(x: np.ndarray, m_pad: int, fill: float = 0.0) -> np.ndarray:
    if x.shape[0] == m_pad:
        return x
    pad = np.full((m_pad - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, pad], axis=0)


def _per_variant_np(x, m: int, fill_value: float = 0.0,
                    m_pad: Optional[int] = None,
                    dtype=np.float32) -> np.ndarray:
    """Scalar / (M,) / (M,1) runtime parameter → padded (m_pad,) host array.

    The single host-side normalizer for λ/capacity/θ — every numpy path
    (bucketed dispatch padding, the small-pool scorer, round packing) goes
    through it so the accepted shapes can never drift apart.  The traced
    jnp equivalents live next to their kernels (ref._per_variant,
    kernel._as_column).
    """
    m_pad = m_pad or m
    out = np.full(m_pad, fill_value, dtype)
    x = np.asarray(x, dtype)
    out[:m] = x if x.ndim == 0 else x.reshape(-1)
    return out


@jax.jit
def _score_ref_jit(feat_job, feat_sys, alphas, betas, mu, sigma, lam, capacity, theta):
    TRACE_COUNT["ref"] += 1
    return score_variants_reference(
        feat_job, feat_sys, alphas, betas, mu, sigma,
        lam=lam, capacity=capacity, theta=theta,
    )


# ---------------------------------------------------------------------------
# Mesh-sharded dispatch: partition the pooled bid axis over an auction mesh
# ---------------------------------------------------------------------------

# (mesh, impl, block_m, interpret) -> jitted shard_map wrapper.  One cached
# executable per mesh shape (Mesh hashes by devices + axis names), so the
# zero-recompile contract survives sharding: the jit cache inside each
# wrapper is still keyed by bucketed shapes only, and drifting pool sizes
# under one mesh never retrace.
_SHARDED_SCORE_CACHE: dict = {}


def _sharded_score_fn(mesh, impl: str, block_m: int, interpret: bool):
    key = (mesh, impl, block_m, interpret)
    fn = _SHARDED_SCORE_CACHE.get(key)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    row = PS(tuple(mesh.axis_names))
    rep = PS()
    if impl == "ref":
        def body(fj, fs, alphas, betas, mu, sg, lam, cap, th):
            return score_variants_reference(
                fj, fs, alphas, betas, mu, sg, lam=lam, capacity=cap, theta=th)
        out_specs = (row, row, row)
    else:
        def body(fj, fs, alphas, betas, mu, sg, lam, cap, th):
            score, elig = score_variants_pallas(
                fj, fs, alphas, betas, mu, sg, lam=lam, capacity=cap,
                theta=th, block_m=block_m, interpret=interpret)
            return score, elig
        out_specs = (row, row)
    # check_rep=False: pallas_call has no replication rule, and scoring is
    # row-independent anyway (no cross-shard collectives in the body)
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(row, row, rep, rep, row, row, row, row, row),
        out_specs=out_specs, check_rep=False)

    @jax.jit
    def call(fj, fs, alphas, betas, mu, sg, lam, cap, th):
        # the python body runs only on a jit cache miss — same retrace
        # accounting as the unsharded wrappers.  The pallas path needs no
        # explicit bump: score_variants_pallas is itself jitted and its
        # body (which increments the pallas counter) runs exactly when
        # this wrapper traces.
        if impl == "ref":
            TRACE_COUNT["ref"] += 1
        return sharded(fj, fs, alphas, betas, mu, sg, lam, cap, th)

    _SHARDED_SCORE_CACHE[key] = call
    return call


def score_variants(
    feat_job,
    feat_sys,
    alphas,
    betas,
    mu,
    sigma,
    *,
    lam,
    capacity,
    theta,
    impl: Optional[str] = None,
    block_m: int = 256,
    bucket: bool = True,
    trim: bool = True,
    mesh=None,
):
    """Batched scoring dispatch: Pallas on TPU, jnp reference elsewhere.

    ``lam`` / ``capacity`` / ``theta`` accept scalars (legacy overload,
    broadcast over the pool) or per-variant ``(M,)`` vectors.  All three are
    runtime operands: changing their VALUES never recompiles.  With
    ``bucket=True`` (default) M is padded to a power-of-two bucket so
    changing pool SIZE only compiles once per bucket.

    Returns ``(score, eligible, p_exceed)`` aligned with the input rows;
    ``p_exceed`` is None on the Pallas path (not materialized in-kernel).
    ``trim=False`` returns the full BUCKET-PADDED arrays instead (padded
    rows score 0/ineligible by construction) — callers that chain further
    device work on the in-flight scores (the fused settle dispatch) need
    the shape-stable padded form to stay retrace-free.

    ``mesh`` (a 1-axis auction mesh from ``launch.mesh.make_auction_mesh``)
    shards the padded pool axis across devices via ``shard_map``.  Scoring
    is row-independent, so the sharded dispatch is byte-identical to the
    single-device one; M-bucketing stays GLOBAL (pad first, then shard), so
    the jit cache is one executable per bucket per mesh shape.  Meshes that
    cannot evenly divide the bucket (or with a single device) fall back to
    the unsharded dispatch silently.
    """
    feat_job = np.asarray(feat_job, np.float32)
    feat_sys = np.asarray(feat_sys, np.float32)
    alphas = np.asarray(alphas, np.float32)
    betas = np.asarray(betas, np.float32)
    mu = np.asarray(mu, np.float32)
    sigma = np.asarray(sigma, np.float32)

    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"

    m = feat_job.shape[0]
    m_pad = bucket_m(m) if bucket else m
    fj = _pad_rows(feat_job, m_pad)
    fs = _pad_rows(feat_sys, m_pad)
    # padded rows: capacity 0 with mu 1 > 0 and sigma 0 is a deterministic
    # violation -> ineligible by construction regardless of theta
    mu_p = _pad_rows(mu, m_pad, fill=1.0)
    sg_p = _pad_rows(sigma, m_pad, fill=0.0)
    lam_v = _per_variant_np(lam, m, 0.0, m_pad)
    cap_v = _per_variant_np(capacity, m, 0.0, m_pad)
    th_v = _per_variant_np(theta, m, 0.0, m_pad)

    end = m if trim else m_pad
    n_shards = 1
    if mesh is not None:
        from ...distributed.sharding import auction_row_spec, mesh_size, spec_sharded

        n_shards = mesh_size(mesh)
        if n_shards <= 1 or not spec_sharded(auction_row_spec(mesh, m_pad)):
            n_shards = 1  # degenerate / non-dividing mesh: unsharded path

    # typed fault surface: injected faults fire before the device is
    # touched; raw XLA/pallas errors are re-raised as KernelDispatchError
    # carrying backend + bucketed shape (the degradation ladder keys on it)
    check_dispatch_fault(impl, "score_variants", (m_pad, fj.shape[1]))
    if impl == "ref":
        try:
            if n_shards > 1:
                score, elig, p_exceed = _sharded_score_fn(mesh, "ref", 0, False)(
                    fj, fs, alphas, betas, mu_p, sg_p, lam_v, cap_v, th_v)
            else:
                score, elig, p_exceed = _score_ref_jit(
                    fj, fs, alphas, betas, mu_p, sg_p, lam_v, cap_v, th_v
                )
        except KernelDispatchError:
            raise
        except Exception as exc:
            raise KernelDispatchError(
                "ref", "score_variants", (m_pad, fj.shape[1]), cause=exc
            ) from exc
        return score[:end], elig[:end], p_exceed[:end]

    # per-SHARD row extent bounds the pallas block size under sharding
    bm = min(block_m, max(8, m_pad // n_shards))
    try:
        if n_shards > 1:
            score, elig = _sharded_score_fn(mesh, "pallas", bm, use_interpret())(
                fj, fs, alphas, betas, mu_p, sg_p, lam_v, cap_v, th_v)
        else:
            score, elig = score_variants_pallas(
                fj, fs, alphas, betas, mu_p, sg_p,
                lam=lam_v, capacity=cap_v, theta=th_v,
                block_m=bm, interpret=use_interpret(),
            )
    except KernelDispatchError:
        raise
    except Exception as exc:
        raise KernelDispatchError(
            impl, "score_variants", (m_pad, fj.shape[1]), cause=exc
        ) from exc
    # kernel does not return p_exceed; recompute lazily only if needed
    return score[:end], elig[:end], None


def score_variants_numpy(
    feat_job,
    feat_sys,
    alphas,
    betas,
    mu,
    sigma,
    *,
    lam,
    capacity,
    theta,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host numpy path with semantics identical to ref.py / the kernel.

    Used below ``scoring.SMALL_POOL_M`` where one device dispatch costs more
    than the whole matmul; float64 so near-ties rank like the legacy
    per-window path.  Returns ``(score, eligible, p_exceed)``.
    """
    from scipy.special import log_ndtr as _log_ndtr

    fj = np.asarray(feat_job, np.float64)
    fs = np.asarray(feat_sys, np.float64)
    m = fj.shape[0]
    lam_v = _per_variant_np(lam, m, dtype=np.float64)
    cap_v = _per_variant_np(capacity, m, dtype=np.float64)
    th_v = _per_variant_np(theta, m, dtype=np.float64)

    h = np.clip(fj @ np.asarray(alphas, np.float64), 0.0, 1.0)
    f = np.clip(fs @ np.asarray(betas, np.float64), 0.0, 1.0)
    score = lam_v * h + (1.0 - lam_v) * f

    mu = np.asarray(mu, np.float64)
    sg = np.asarray(sigma, np.float64)
    cap_c = cap_v[:, None]
    with np.errstate(divide="ignore", invalid="ignore"):
        z = np.where(sg > 0, (cap_c - mu) / np.maximum(sg, 1e-300),
                     np.where(mu <= cap_c, np.inf, -np.inf))
    logphi = np.where(np.isposinf(z), 0.0, _log_ndtr(np.where(np.isposinf(z), 0.0, z)))
    log_surv = np.sum(logphi, axis=-1)
    p_exceed = -np.expm1(log_surv)
    eligible = p_exceed <= th_v
    return np.where(eligible, score, 0.0), eligible, p_exceed


def _pack_job_features(variants, policy, dtype=np.float32):
    """Declared job features + α vector in the (jct, qos, progress) order the
    kernel contract fixes — single source of truth for both packing paths."""
    fj = np.zeros((len(variants), 3), dtype)
    for i, v in enumerate(variants):
        d = v.declared_features
        fj[i] = [d.get("jct", 0.0), d.get("qos", 0.0), d.get("progress", 0.0)]
    alphas = np.array(
        [policy.alphas.get("jct", 0.0), policy.alphas.get("qos", 0.0),
         policy.alphas.get("progress", 0.0)], dtype)
    return fj, alphas


def pool_to_arrays(
    variants,
    window,
    policy,
    *,
    grid: int = 32,
) -> Tuple[np.ndarray, ...]:
    """Host-side helper: struct-of-arrays feature/FMP matrices for a pool.

    Feature order must match the α/β vectors built here (job: jct, qos,
    progress; sys: utilization, slack, age placeholder 0 — ages are added by
    the caller when known).
    """
    m = len(variants)
    fj, alphas = _pack_job_features(variants, policy)
    fs = np.zeros((m, 3), np.float32)
    mu = np.zeros((m, grid), np.float32)
    sg = np.zeros((m, grid), np.float32)
    for i, v in enumerate(variants):
        util = min(1.0, v.duration / max(window.duration, 1e-9))
        lead = max(0.0, (v.t_start - window.t_min) / max(window.duration, 1e-9))
        fs[i] = [util, 1.0 - lead, 0.0]
        mu[i], sg[i] = v.fmp.grid(grid)
    betas = np.array(
        [policy.betas.get("utilization", 0.0), policy.betas.get("slack", 0.0),
         policy.betas.get("age", 0.0)], np.float32)
    return fj, fs, alphas, betas, mu, sg


# ---------------------------------------------------------------------------
# Round packing: the union of every window's bids in ONE struct-of-arrays
# ---------------------------------------------------------------------------


class FMPGridCache:
    """Bounded LRU of FMP grid discretizations, scoped per scheduler/round.

    Replaces the former process-global ``functools.lru_cache`` on the mean-mu
    helper, which retained FMP objects (and their grids) across unrelated
    scheduler instances and benchmark runs for the life of the process.  One
    instance lives on each ``JasdaScheduler``; stateless callers get a fresh
    per-call (per-round) cache.

    Entries are keyed by ``(fmp, n_grid)`` (PhaseFMP is frozen/hashable) and
    hold ``(mu_f32, sigma_f32, mean_mu_f64)`` — the f32 copies feed the
    device pack directly, the float64 mean feeds the ψ_mem_headroom feature
    with the same precision as the legacy per-window path.
    """

    def __init__(self, maxsize: int = 1024):
        self.maxsize = max(1, int(maxsize))
        self._d: "OrderedDict[tuple, tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def grid(self, fmp, n: int) -> Tuple[np.ndarray, np.ndarray, float]:
        key = (fmp, n)
        hit = self._d.get(key)
        if hit is not None:
            self.hits += 1
            self._d.move_to_end(key)
            return hit
        self.misses += 1
        mu64, sg64 = fmp.grid(n)
        entry = (
            np.asarray(mu64, np.float32),
            np.asarray(sg64, np.float32),
            float(np.mean(mu64)),
        )
        self._d[key] = entry
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
        return entry

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        self._d.clear()


class PackedRound(NamedTuple):
    """Struct-of-arrays form of one pooled auction round.

    ``caps``/``thetas`` are per-variant: ``caps[i]`` is the capacity of the
    window variant i bids on (gathered via ``win_idx``), so the kernel can
    re-verify safety condition (a) in-kernel against heterogeneous slices.
    """

    fj: np.ndarray  # (M, Fj) float64 job features (or calibrated h column)
    fs: np.ndarray  # (M, Fs) float64 system features
    alphas: np.ndarray  # (Fj,) float64
    betas: np.ndarray  # (Fs,) float64
    mu: np.ndarray  # (M, T) float32 FMP means (T=1 zeros when grids unpacked)
    sg: np.ndarray  # (M, T) float32 FMP stds
    caps: np.ndarray  # (M,) float64 per-variant window capacity
    thetas: np.ndarray  # (M,) float64 per-variant safety bound


def pool_to_arrays_round(
    variants,
    windows,
    win_idx,
    policy,
    *,
    h=None,
    ages=None,
    grid: int = 32,
    pack_grids: bool = False,
    theta=1.0,
    cache: Optional[FMPGridCache] = None,
    view=None,
) -> PackedRound:
    """Pack a pooled ROUND of bids for one batched scoring dispatch.

    Each variant is scored against ITS OWN window (``win_idx[i]`` indexes
    ``windows``); the returned :class:`PackedRound` carries the per-variant
    window capacities and θ so the kernel re-verifies safety condition (a)
    per window.  System features mirror ``scoring.score_pool`` exactly:
    [utilization, slack, mem_headroom, age], so the batched call reproduces
    the per-window numpy path.

    ``h`` (optional, (M,)) is the pre-calibrated job utility ĥ(v); when given
    the job side collapses to a single feature column with α = [1.0], which
    is how the round path injects §4.2.1 calibration without a per-variant
    device round-trip.  ``pack_grids=False`` skips the (M, T) FMP grids (the
    in-kernel safety recheck is a no-op when generation already enforced
    condition (a)); pass True to re-verify with ``theta`` (scalar broadcast
    or per-variant vector).  ``cache`` memoizes FMP grid discretizations —
    pass the scheduler's :class:`FMPGridCache` to reuse grids across rounds;
    None uses a fresh per-call cache.

    The pool is walked at most ONCE in python (``view`` — a
    ``types.PoolView`` aligned with ``variants`` — skips even that); grids
    and grid statistics are gathered from the cache by unique FMP, so a
    round over thousands of variants sharing a few job FMPs touches each
    grid once.  Within the round, FMPs are deduplicated by object identity
    (cheap) and only the per-unique-FMP cache lookups hash the frozen
    dataclass.

    Features stay float64 on the host so the small-pool numpy scoring path
    ranks variants exactly like the legacy per-window path even on near-ties;
    the jnp/Pallas dispatch (ops.score_variants) downcasts to float32 at the
    device boundary.
    """
    m = len(variants)
    win_idx = np.asarray(win_idx)
    w_tmin = np.asarray([w.t_min for w in windows], np.float64)[win_idx]
    w_dur = np.asarray([max(w.duration, 1e-9) for w in windows], np.float64)[win_idx]
    w_cap = np.asarray([w.capacity for w in windows], np.float64)[win_idx]

    if cache is None:
        cache = FMPGridCache(maxsize=max(64, m))

    # -- at most one pool walk: scalars + unique-FMP gather -------------------
    if view is not None:
        t_start = view.t_start
        dur = view.duration
        fmp_list = view.fmps
        job_ids = view.job_ids
    else:
        rows = [(v.t_start, v.duration, v.fmp, v.job_id) for v in variants]
        ts, ds, fmp_list, job_ids = zip(*rows) if rows else ((), (), (), ())
        t_start = np.asarray(ts, np.float64)
        dur = np.asarray(ds, np.float64)
        fmp_list = list(fmp_list)
        job_ids = list(job_ids)
    fmp_row = np.empty(m, np.intp)
    row_of: dict = {}  # id(fmp) -> row (identity dedup: no dataclass hashing)
    uniq = []  # [(mu_f32, sg_f32, mean_mu)]
    for i, fmp in enumerate(fmp_list):
        r = row_of.get(id(fmp))
        if r is None:
            r = len(uniq)
            row_of[id(fmp)] = r
            uniq.append(cache.grid(fmp, grid))
        fmp_row[i] = r
    if ages:
        get_age = ages.get
        age = np.asarray([get_age(j, 0.0) for j in job_ids], np.float64)
    else:
        age = np.zeros(m, np.float64)

    util = np.clip(dur / w_dur, 0.0, 1.0)
    slack = np.clip(1.0 - (t_start - w_tmin) / w_dur, 0.0, 1.0)
    mean_mu = np.asarray([u[2] for u in uniq], np.float64)[fmp_row] if m else \
        np.zeros(0, np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        headroom = np.where(
            w_cap > 0, np.clip(1.0 - mean_mu / np.where(w_cap > 0, w_cap, 1.0), 0.0, 1.0), 0.0
        )
    fs = np.stack([util, slack, headroom, np.clip(age, 0.0, 1.0)], axis=1)
    betas = np.array(
        [policy.betas.get("utilization", 0.0), policy.betas.get("slack", 0.0),
         policy.betas.get("mem_headroom", 0.0), policy.betas.get("age", 0.0)],
        np.float64)

    if h is not None:
        fj = np.asarray(h, np.float64)[:, None]
        alphas = np.array([1.0], np.float64)
    else:
        fj, alphas = _pack_job_features(variants, policy, dtype=np.float64)

    if pack_grids and m:
        mu_tab = np.stack([u[0] for u in uniq])
        sg_tab = np.stack([u[1] for u in uniq])
        mu = mu_tab[fmp_row]
        sg = sg_tab[fmp_row]
    else:
        # sigma=0 with mu=0 <= capacity is deterministically safe: the
        # kernel's eligibility mask becomes a no-op, as intended
        mu = np.zeros((m, 1), np.float32)
        sg = np.zeros((m, 1), np.float32)

    thetas = _per_variant_np(theta, m, dtype=np.float64)
    return PackedRound(fj, fs, alphas, betas, mu, sg, w_cap, thetas)
