"""Jit'd wrapper for batched variant scoring: padding + dispatch.

Pads M to the block multiple (padded rows are self-masking: sigma=0 with
mu > capacity makes them ineligible, score 0) and T/F to lane-friendly
sizes, then calls the Pallas kernel (TPU / interpret) or the jnp reference.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import use_interpret
from .kernel import score_variants_pallas
from .ref import score_variants_reference

__all__ = ["score_variants", "pool_to_arrays", "pool_to_arrays_round"]


def _pad_rows(x: jnp.ndarray, m_pad: int, fill: float = 0.0) -> jnp.ndarray:
    if x.shape[0] == m_pad:
        return x
    pad = jnp.full((m_pad - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def score_variants(
    feat_job,
    feat_sys,
    alphas,
    betas,
    mu,
    sigma,
    *,
    lam: float,
    capacity: float,
    theta: float,
    impl: Optional[str] = None,
    block_m: int = 256,
):
    feat_job = jnp.asarray(feat_job, jnp.float32)
    feat_sys = jnp.asarray(feat_sys, jnp.float32)
    alphas = jnp.asarray(alphas, jnp.float32)
    betas = jnp.asarray(betas, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    sigma = jnp.asarray(sigma, jnp.float32)

    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return score_variants_reference(
            feat_job, feat_sys, alphas, betas, mu, sigma,
            lam=lam, capacity=capacity, theta=theta,
        )

    m = feat_job.shape[0]
    bm = min(block_m, max(8, m))
    m_pad = -(-m // bm) * bm
    fj = _pad_rows(feat_job, m_pad)
    fs = _pad_rows(feat_sys, m_pad)
    # padded rows: deterministic violation -> ineligible by construction
    mu_p = _pad_rows(mu, m_pad, fill=float(capacity) * 2.0 + 1.0)
    sg_p = _pad_rows(sigma, m_pad, fill=0.0)
    score, elig, = score_variants_pallas(
        fj, fs, alphas, betas, mu_p, sg_p,
        lam=lam, capacity=capacity, theta=theta,
        block_m=bm, interpret=use_interpret(),
    )[:2]
    # kernel does not return p_exceed; recompute lazily only if needed
    return score[:m], elig[:m], None


def _pack_job_features(variants, policy, dtype=np.float32):
    """Declared job features + α vector in the (jct, qos, progress) order the
    kernel contract fixes — single source of truth for both packing paths."""
    fj = np.zeros((len(variants), 3), dtype)
    for i, v in enumerate(variants):
        d = v.declared_features
        fj[i] = [d.get("jct", 0.0), d.get("qos", 0.0), d.get("progress", 0.0)]
    alphas = np.array(
        [policy.alphas.get("jct", 0.0), policy.alphas.get("qos", 0.0),
         policy.alphas.get("progress", 0.0)], dtype)
    return fj, alphas


def pool_to_arrays(
    variants,
    window,
    policy,
    *,
    grid: int = 32,
) -> Tuple[np.ndarray, ...]:
    """Host-side helper: struct-of-arrays feature/FMP matrices for a pool.

    Feature order must match the α/β vectors built here (job: jct, qos,
    progress; sys: utilization, slack, age placeholder 0 — ages are added by
    the caller when known).
    """
    m = len(variants)
    fj, alphas = _pack_job_features(variants, policy)
    fs = np.zeros((m, 3), np.float32)
    mu = np.zeros((m, grid), np.float32)
    sg = np.zeros((m, grid), np.float32)
    for i, v in enumerate(variants):
        util = min(1.0, v.duration / max(window.duration, 1e-9))
        lead = max(0.0, (v.t_start - window.t_min) / max(window.duration, 1e-9))
        fs[i] = [util, 1.0 - lead, 0.0]
        mu[i], sg[i] = v.fmp.grid(grid)
    betas = np.array(
        [policy.betas.get("utilization", 0.0), policy.betas.get("slack", 0.0),
         policy.betas.get("age", 0.0)], np.float32)
    return fj, fs, alphas, betas, mu, sg


# ---------------------------------------------------------------------------
# Round packing: the union of every window's bids in ONE struct-of-arrays
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=4096)
def _fmp_mean_mu(fmp, grid: int) -> float:
    """mean_t mu(t) of a (hashable, frozen) FMP — the only grid statistic
    ψ_mem_headroom needs, so a round over thousands of variants sharing a few
    job FMPs touches each grid once."""
    mu, _ = fmp.grid(grid)
    return float(np.mean(mu))


def pool_to_arrays_round(
    variants,
    windows,
    win_idx,
    policy,
    *,
    h=None,
    ages=None,
    grid: int = 32,
    pack_grids: bool = False,
):
    """Pack a pooled ROUND of bids for one batched scoring dispatch.

    Each variant is scored against ITS OWN window (``win_idx[i]`` indexes
    ``windows``).  System features mirror ``scoring.score_pool`` exactly:
    [utilization, slack, mem_headroom, age], so the batched call reproduces
    the per-window numpy path.

    ``h`` (optional, (M,)) is the pre-calibrated job utility ĥ(v); when given
    the job side collapses to a single feature column with α = [1.0], which
    is how the round path injects §4.2.1 calibration without a per-variant
    device round-trip.  ``pack_grids=False`` skips the (M, T) FMP grids (the
    in-kernel safety recheck is a no-op when generation already enforced
    condition (a)); pass True to re-verify with a caller-chosen θ.

    Features stay float64 on the host so the small-pool numpy scoring path
    ranks variants exactly like the legacy per-window path even on near-ties;
    the jnp/Pallas dispatch (ops.score_variants) downcasts to float32 at the
    device boundary.
    """
    m = len(variants)
    w_tmin = np.asarray([w.t_min for w in windows], np.float64)[win_idx]
    w_dur = np.asarray([max(w.duration, 1e-9) for w in windows], np.float64)[win_idx]
    w_cap = np.asarray([w.capacity for w in windows], np.float64)[win_idx]

    t_start = np.fromiter((v.t_start for v in variants), np.float64, m)
    dur = np.fromiter((v.duration for v in variants), np.float64, m)
    util = np.clip(dur / w_dur, 0.0, 1.0)
    slack = np.clip(1.0 - (t_start - w_tmin) / w_dur, 0.0, 1.0)
    mean_mu = np.fromiter(
        (_fmp_mean_mu(v.fmp, grid) for v in variants), np.float64, m
    )
    with np.errstate(divide="ignore", invalid="ignore"):
        headroom = np.where(
            w_cap > 0, np.clip(1.0 - mean_mu / np.where(w_cap > 0, w_cap, 1.0), 0.0, 1.0), 0.0
        )
    if ages:
        age = np.fromiter(
            (np.clip(ages.get(v.job_id, 0.0), 0.0, 1.0) for v in variants),
            np.float64, m,
        )
    else:
        age = np.zeros(m, np.float64)
    fs = np.stack([util, slack, headroom, age], axis=1)
    betas = np.array(
        [policy.betas.get("utilization", 0.0), policy.betas.get("slack", 0.0),
         policy.betas.get("mem_headroom", 0.0), policy.betas.get("age", 0.0)],
        np.float64)

    if h is not None:
        fj = np.asarray(h, np.float64)[:, None]
        alphas = np.array([1.0], np.float64)
    else:
        fj, alphas = _pack_job_features(variants, policy, dtype=np.float64)

    if pack_grids:
        mu = np.zeros((m, grid), np.float32)
        sg = np.zeros((m, grid), np.float32)
        for i, v in enumerate(variants):
            mu[i], sg[i] = v.fmp.grid(grid)
    else:
        # sigma=0 with mu=0 <= capacity is deterministically safe: the
        # kernel's eligibility mask becomes a no-op, as intended
        mu = np.zeros((m, 1), np.float32)
        sg = np.zeros((m, 1), np.float32)
    return fj, fs, alphas, betas, mu, sg
