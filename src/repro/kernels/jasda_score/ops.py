"""Jit'd wrapper for batched variant scoring: padding + dispatch.

Pads M to the block multiple (padded rows are self-masking: sigma=0 with
mu > capacity makes them ineligible, score 0) and T/F to lane-friendly
sizes, then calls the Pallas kernel (TPU / interpret) or the jnp reference.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import use_interpret
from .kernel import score_variants_pallas
from .ref import score_variants_reference

__all__ = ["score_variants", "pool_to_arrays"]


def _pad_rows(x: jnp.ndarray, m_pad: int, fill: float = 0.0) -> jnp.ndarray:
    if x.shape[0] == m_pad:
        return x
    pad = jnp.full((m_pad - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def score_variants(
    feat_job,
    feat_sys,
    alphas,
    betas,
    mu,
    sigma,
    *,
    lam: float,
    capacity: float,
    theta: float,
    impl: Optional[str] = None,
    block_m: int = 256,
):
    feat_job = jnp.asarray(feat_job, jnp.float32)
    feat_sys = jnp.asarray(feat_sys, jnp.float32)
    alphas = jnp.asarray(alphas, jnp.float32)
    betas = jnp.asarray(betas, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    sigma = jnp.asarray(sigma, jnp.float32)

    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return score_variants_reference(
            feat_job, feat_sys, alphas, betas, mu, sigma,
            lam=lam, capacity=capacity, theta=theta,
        )

    m = feat_job.shape[0]
    bm = min(block_m, max(8, m))
    m_pad = -(-m // bm) * bm
    fj = _pad_rows(feat_job, m_pad)
    fs = _pad_rows(feat_sys, m_pad)
    # padded rows: deterministic violation -> ineligible by construction
    mu_p = _pad_rows(mu, m_pad, fill=float(capacity) * 2.0 + 1.0)
    sg_p = _pad_rows(sigma, m_pad, fill=0.0)
    score, elig, = score_variants_pallas(
        fj, fs, alphas, betas, mu_p, sg_p,
        lam=lam, capacity=capacity, theta=theta,
        block_m=bm, interpret=use_interpret(),
    )[:2]
    # kernel does not return p_exceed; recompute lazily only if needed
    return score[:m], elig[:m], None


def pool_to_arrays(
    variants,
    window,
    policy,
    *,
    grid: int = 32,
) -> Tuple[np.ndarray, ...]:
    """Host-side helper: struct-of-arrays feature/FMP matrices for a pool.

    Feature order must match the α/β vectors built here (job: jct, qos,
    progress; sys: utilization, slack, age placeholder 0 — ages are added by
    the caller when known).
    """
    m = len(variants)
    fj = np.zeros((m, 3), np.float32)
    fs = np.zeros((m, 3), np.float32)
    mu = np.zeros((m, grid), np.float32)
    sg = np.zeros((m, grid), np.float32)
    for i, v in enumerate(variants):
        d = v.declared_features
        fj[i] = [d.get("jct", 0.0), d.get("qos", 0.0), d.get("progress", 0.0)]
        util = min(1.0, v.duration / max(window.duration, 1e-9))
        lead = max(0.0, (v.t_start - window.t_min) / max(window.duration, 1e-9))
        fs[i] = [util, 1.0 - lead, 0.0]
        mu[i], sg[i] = v.fmp.grid(grid)
    alphas = np.array(
        [policy.alphas.get("jct", 0.0), policy.alphas.get("qos", 0.0),
         policy.alphas.get("progress", 0.0)], np.float32)
    betas = np.array(
        [policy.betas.get("utilization", 0.0), policy.betas.get("slack", 0.0),
         policy.betas.get("age", 0.0)], np.float32)
    return fj, fs, alphas, betas, mu, sg
