"""Batched variant scoring + safety as a Pallas TPU kernel.

The paper's per-iteration hot loop (Algorithm 1 lines 6–8) evaluated for M
variants at once: two small feature matmuls (MXU) fused with the log-space
safety reduction over the FMP time grid (VPU), one VMEM pass.

Calling convention (the zero-recompile contract)
------------------------------------------------
``lam``, ``capacity`` and ``theta`` are **traced runtime operands**, not
compile-time constants: one compiled executable serves every policy preset
(λ), every mix of per-window slice capacities, and every safety bound θ.
Each is a per-variant ``(M, 1)`` float32 column — scalars are broadcast by
the caller (ops.py keeps the scalar overload) — so a single dispatch can
re-verify eligibility condition (a) against *heterogeneous* capacities:
variant i is checked against the capacity of the window it bids on.

Only ``block_m`` and ``interpret`` remain static: the jit cache is keyed by
(M-bucket, T, Fj, Fs) shapes alone, and ops.py pads M to power-of-two
buckets so drifting pool sizes reuse one executable per bucket.

Tiling: grid over M blocks; each program holds (BM, Fj)+(BM, Fs) feature
tiles, the (BM, T) FMP grid tiles, the (BM, 1) λ/capacity/θ columns, and
produces (BM,) scores + eligibility.  T and F are padded to lane multiples
by ops.py.  A GPU port would reduce across a warp per variant; on TPU the
whole (BM, T) tile reduces in one vectorized `sum` on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ..common import log_ndtr

__all__ = ["score_variants_pallas", "TRACE_COUNT"]

# Incremented each time the jitted wrapper RETRACES (python body re-executes
# only on a jit cache miss) — benchmarks/run.py's score_dispatch scenario
# asserts this stays flat across rounds with varying (λ, capacity, θ, M).
TRACE_COUNT = {"pallas": 0}


def _as_column(x, m: int) -> jnp.ndarray:
    """Broadcast a scalar / (M,) / (M,1) runtime parameter to (M, 1) f32."""
    x = jnp.asarray(x, jnp.float32)
    if x.ndim == 0:
        return jnp.broadcast_to(x, (m, 1))
    return x.reshape(m, 1)


def _score_kernel(
    fj_ref, fs_ref, al_ref, be_ref, mu_ref, sg_ref, lam_ref, cap_ref, th_ref,
    score_ref, elig_ref,
):
    fj = fj_ref[...].astype(jnp.float32)  # (BM, Fj)
    fs = fs_ref[...].astype(jnp.float32)  # (BM, Fs)
    al = al_ref[...].astype(jnp.float32)  # (1, Fj)
    be = be_ref[...].astype(jnp.float32)  # (1, Fs)
    lam = lam_ref[...].astype(jnp.float32)[:, 0]  # (BM,)

    h = jnp.clip(jnp.sum(fj * al, axis=-1), 0.0, 1.0)  # (BM,)
    f = jnp.clip(jnp.sum(fs * be, axis=-1), 0.0, 1.0)
    score = lam * h + (1.0 - lam) * f

    mu = mu_ref[...].astype(jnp.float32)  # (BM, T)
    sg = sg_ref[...].astype(jnp.float32)
    cap = cap_ref[...].astype(jnp.float32)  # (BM, 1) -> broadcasts over T
    theta = th_ref[...].astype(jnp.float32)[:, 0]  # (BM,)
    z = (cap - mu) / jnp.maximum(sg, 1e-30)
    # deterministic grid points: surely-safe -> logphi 0; surely-violating -> -inf
    safe_det = jnp.logical_and(sg <= 0.0, mu <= cap)
    viol_det = jnp.logical_and(sg <= 0.0, mu > cap)
    logphi = jnp.where(safe_det, 0.0, log_ndtr(jnp.where(sg > 0, z, 0.0)))
    logphi = jnp.where(viol_det, -jnp.inf, logphi)
    log_surv = jnp.sum(logphi, axis=-1)  # (BM,)
    p_exceed = -jnp.expm1(log_surv)
    eligible = p_exceed <= theta

    score_ref[...] = jnp.where(eligible, score, 0.0)[None, :]
    elig_ref[...] = eligible[None, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def score_variants_pallas(
    feat_job: jnp.ndarray,  # (M, Fj)
    feat_sys: jnp.ndarray,  # (M, Fs)
    alphas: jnp.ndarray,  # (Fj,)
    betas: jnp.ndarray,  # (Fs,)
    mu: jnp.ndarray,  # (M, T)
    sigma: jnp.ndarray,  # (M, T)
    *,
    lam,  # traced: scalar or (M,)/(M,1)
    capacity,  # traced: scalar or (M,)/(M,1)
    theta,  # traced: scalar or (M,)/(M,1)
    block_m: int = 256,
    interpret: bool = False,
):
    TRACE_COUNT["pallas"] += 1
    m, fj = feat_job.shape
    _, fs = feat_sys.shape
    _, t = mu.shape
    block_m = min(block_m, m)
    assert m % block_m == 0, "pad M to a block multiple in ops.py"
    grid = (m // block_m,)

    lam_c = _as_column(lam, m)
    cap_c = _as_column(capacity, m)
    th_c = _as_column(theta, m)

    score, elig = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, fj), lambda i: (i, 0)),
            pl.BlockSpec((block_m, fs), lambda i: (i, 0)),
            pl.BlockSpec((1, fj), lambda i: (0, 0)),
            pl.BlockSpec((1, fs), lambda i: (0, 0)),
            pl.BlockSpec((block_m, t), lambda i: (i, 0)),
            pl.BlockSpec((block_m, t), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.int32),
        ],
        interpret=interpret,
    )(feat_job, feat_sys, alphas[None, :], betas[None, :], mu, sigma,
      lam_c, cap_c, th_c)
    return score[0], elig[0].astype(bool)
