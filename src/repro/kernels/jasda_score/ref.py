"""Pure-jnp oracle for batched variant scoring + safety (paper §4.1–§4.2).

Given M variants with job-side features X_j (M, Fj), system-side features
X_s (M, Fs), and per-variant FMP grids (mu, sigma) over T points:

    h̃        = clip(X_j @ α, 0, 1)
    f̃_sys    = clip(X_s @ β, 0, 1)
    score     = λ·h̃ + (1−λ)·f̃_sys                      (Eq. 4)
    log_surv  = Σ_t log Φ((c − μ_t)/σ_t)                 (grid safety)
    p_exceed  = 1 − exp(log_surv)
    eligible  = p_exceed ≤ θ                              (condition (a))

Scores of ineligible variants are zeroed (they never enter clearing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import log_ndtr

__all__ = ["score_variants_reference"]


def score_variants_reference(
    feat_job: jnp.ndarray,  # (M, Fj)
    feat_sys: jnp.ndarray,  # (M, Fs)
    alphas: jnp.ndarray,  # (Fj,)
    betas: jnp.ndarray,  # (Fs,)
    mu: jnp.ndarray,  # (M, T)
    sigma: jnp.ndarray,  # (M, T)
    *,
    lam: float,
    capacity: float,
    theta: float,
):
    h = jnp.clip(feat_job @ alphas, 0.0, 1.0)
    f = jnp.clip(feat_sys @ betas, 0.0, 1.0)
    score = lam * h + (1.0 - lam) * f

    z = (capacity - mu) / jnp.maximum(sigma, 1e-30)
    z = jnp.where(sigma > 0, z, jnp.where(mu <= capacity, jnp.inf, -jnp.inf))
    logphi = jnp.where(jnp.isposinf(z), 0.0, log_ndtr(z))
    log_surv = jnp.sum(logphi, axis=-1)
    p_exceed = -jnp.expm1(log_surv)
    eligible = p_exceed <= theta
    return jnp.where(eligible, score, 0.0), eligible, p_exceed
