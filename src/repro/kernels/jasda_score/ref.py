"""Pure-jnp oracle for batched variant scoring + safety (paper §4.1–§4.2).

Given M variants with job-side features X_j (M, Fj), system-side features
X_s (M, Fs), and per-variant FMP grids (mu, sigma) over T points:

    h̃        = clip(X_j @ α, 0, 1)
    f̃_sys    = clip(X_s @ β, 0, 1)
    score     = λ·h̃ + (1−λ)·f̃_sys                      (Eq. 4)
    log_surv  = Σ_t log Φ((c_i − μ_t)/σ_t)               (grid safety)
    p_exceed  = 1 − exp(log_surv)
    eligible  = p_exceed ≤ θ_i                            (condition (a))

``lam``, ``capacity`` and ``theta`` are runtime values — scalars broadcast
over the pool (the legacy overload), or per-variant ``(M,)``/``(M, 1)``
vectors so each bid is verified against the capacity and risk bound of the
window it targets (heterogeneous slices, one dispatch).  The Pallas kernel
(kernel.py) and the host numpy path (ops.score_variants_numpy) implement
identical semantics.

Scores of ineligible variants are zeroed (they never enter clearing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import log_ndtr

__all__ = ["score_variants_reference"]


def _per_variant(x, m: int) -> jnp.ndarray:
    """Normalize a scalar / (M,) / (M,1) runtime parameter to (M,) f32."""
    x = jnp.asarray(x, jnp.float32)
    if x.ndim == 0:
        return jnp.broadcast_to(x, (m,))
    return x.reshape(m)


def score_variants_reference(
    feat_job: jnp.ndarray,  # (M, Fj)
    feat_sys: jnp.ndarray,  # (M, Fs)
    alphas: jnp.ndarray,  # (Fj,)
    betas: jnp.ndarray,  # (Fs,)
    mu: jnp.ndarray,  # (M, T)
    sigma: jnp.ndarray,  # (M, T)
    *,
    lam,  # scalar or per-variant (M,)
    capacity,  # scalar or per-variant (M,)
    theta,  # scalar or per-variant (M,)
):
    m = feat_job.shape[0]
    lam_v = _per_variant(lam, m)
    cap_v = _per_variant(capacity, m)[:, None]  # broadcast over T
    th_v = _per_variant(theta, m)

    h = jnp.clip(feat_job @ alphas, 0.0, 1.0)
    f = jnp.clip(feat_sys @ betas, 0.0, 1.0)
    score = lam_v * h + (1.0 - lam_v) * f

    z = (cap_v - mu) / jnp.maximum(sigma, 1e-30)
    z = jnp.where(sigma > 0, z, jnp.where(mu <= cap_v, jnp.inf, -jnp.inf))
    logphi = jnp.where(jnp.isposinf(z), 0.0, log_ndtr(z))
    log_surv = jnp.sum(logphi, axis=-1)
    p_exceed = -jnp.expm1(log_surv)
    eligible = p_exceed <= th_v
    return jnp.where(eligible, score, 0.0), eligible, p_exceed
