"""Mesh-sharded auction rounds (PR 6): sharded == single-device, byte-wise.

The tentpole contract: partitioning a round over an auction mesh — the
pooled-bid axis of the scoring dispatch and the (W, L) window axis of the
batched WIS settle, both via ``shard_map`` — changes WHERE the round
computes, never WHAT it selects.  Cross-window conflict resolution stays
host-side and global, so the only device-side cross-shard exchange is the
replicated score gather of the fused settle.

Multi-device tests need virtual devices: run with
``JASDA_FORCE_HOST_DEVICES=8`` (see tests/conftest.py), which maps to
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  On a single-device
session they skip; the mesh-builder and fallback tests always run.

Property tests run under hypothesis when available and fall back to seeded
random pools otherwise (hypothesis is not in the baked-in environment).
"""
import numpy as np
import pytest

import jax

from repro.core import (JasdaScheduler, Policy, ScoringPolicy, SimConfig,
                        SliceSpec, make_workload, simulate)
from repro.core.clearing import clear_round
from repro.core.pipeline import pipelined_clear_rounds
from repro.core.policy import FairShare, GlobalAssignment, GreedyWIS
from repro.core.scheduler import SchedulerConfig
from repro.core.trp import fmp_standard
from repro.core.types import Variant, Window
from repro.launch.mesh import AUCTION_AXIS, make_auction_mesh, mesh_chips

GB = 1 << 30

multi_device = pytest.mark.skipif(
    jax.local_device_count() < 2,
    reason="needs >1 device (set JASDA_FORCE_HOST_DEVICES=8)")

BACKENDS = [GreedyWIS(), GlobalAssignment(), FairShare(),
            FairShare(age_weight=0.0, spread=0.5)]


def _mk_round(rng, m, n_windows, n_jobs=23):
    """A random round on float32-exact grids (12-bit utilities, half-step
    intervals) so the f32 device DP and f64 host DP decide identically."""
    windows = [Window(f"s{k}", (6 + 2 * (k % 5)) * GB, 0.0, 100.0)
               for k in range(n_windows)]
    fmp = fmp_standard(1 * GB, 2 * GB, 0.1 * GB)
    pool = []
    for i in range(m):
        w = windows[int(rng.integers(0, n_windows))]
        t0 = float(rng.integers(0, 180)) / 2
        dur = float(rng.integers(2, 40)) / 2
        if t0 + dur > 100.0:
            dur = 100.0 - t0
        if dur <= 0:
            continue
        pool.append(Variant(
            job_id=f"J{i % n_jobs}", slice_id=w.slice_id, t_start=t0,
            duration=dur, fmp=fmp,
            local_utility=float(rng.integers(1, 1 << 12)) / (1 << 12),
            declared_features={}, payload={"work": dur}, variant_id=f"v{i}"))
    return windows, pool


def _sig(rr):
    """Byte-level round signature: per-window selections, scores, feedback
    inputs (selected_idx), totals."""
    return ([tuple(v.variant_id for v in r.selected) for r in rr.results],
            tuple(rr.scores), rr.selected_idx, rr.total_score, rr.n_conflicts)


# ---------------------------------------------------------------------------
# mesh builders (always run)
# ---------------------------------------------------------------------------


def test_auction_mesh_shape_and_axis():
    mesh = make_auction_mesh()
    assert mesh.axis_names == (AUCTION_AXIS,)
    n = mesh_chips(mesh)
    assert n & (n - 1) == 0  # power of two
    assert n <= jax.local_device_count()


def test_auction_mesh_clamps_to_pow2_floor():
    avail = jax.local_device_count()
    for req in (1, 2, 3, 5, 7, 8, 100):
        n = mesh_chips(make_auction_mesh(req))
        assert n & (n - 1) == 0
        assert n <= min(req, avail)


def test_production_mesh_degrades_without_raising():
    from repro.launch.mesh import make_production_mesh

    # CI boxes never have 256 chips — the builder must fall back, not raise
    mesh = make_production_mesh()
    assert mesh_chips(mesh) >= 1
    mesh = make_production_mesh(multi_pod=True)
    assert mesh_chips(mesh) >= 1


def test_row_spec_guard_falls_back_unsharded():
    from repro.distributed.sharding import (auction_row_spec, mesh_size,
                                            replicated_spec, spec_sharded)

    mesh = make_auction_mesh()
    n = mesh_size(mesh)
    assert mesh_size(None) == 1
    if n > 1:
        assert spec_sharded(auction_row_spec(mesh, 16 * n))
        # a dim the mesh does not divide degrades to replicated (guard_spec)
        assert not spec_sharded(auction_row_spec(mesh, 16 * n + 1))
    assert not spec_sharded(replicated_spec())


# ---------------------------------------------------------------------------
# sharded == single-device byte-identity (multi-device)
# ---------------------------------------------------------------------------


def _check_round_parity(seed, mesh, *, backend, wis_impl="ref",
                        pipelined=False):
    rng = np.random.default_rng(seed)
    # ragged M spanning: tiny (empty shards after padding), below/above the
    # SMALL_POOL_M device threshold, and window counts that leave some
    # windows empty / all-masked
    m = int(rng.choice([3, 40, 257, 900, 2100]))
    n_windows = int(rng.integers(1, 12))
    ages = {f"J{i}": (i % 7) / 6.0 for i in range(23)}
    policy = ScoringPolicy()
    if pipelined:
        rounds = [_mk_round(rng, m, n_windows) for _ in range(3)]
        serial = [clear_round(w, p, policy, ages=ages, clearing=backend,
                              wis_impl=wis_impl) for w, p in rounds]
        sharded = pipelined_clear_rounds(rounds, policy, ages=ages,
                                         clearing=backend, wis_impl=wis_impl,
                                         mesh=mesh)
        assert [_sig(a) for a in serial] == [_sig(b) for b in sharded]
    else:
        windows, pool = _mk_round(rng, m, n_windows)
        base = clear_round(windows, pool, policy, ages=ages, clearing=backend,
                           wis_impl=wis_impl)
        shard = clear_round(windows, pool, policy, ages=ages,
                            clearing=backend, wis_impl=wis_impl, mesh=mesh)
        assert _sig(base) == _sig(shard)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAS_HYPOTHESIS = False


if HAS_HYPOTHESIS:

    @multi_device
    @pytest.mark.parametrize("backend", BACKENDS, ids=lambda b: b.name)
    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_sharded_round_byte_identical_prop(backend, seed):
        _check_round_parity(seed, make_auction_mesh(), backend=backend)

else:

    @multi_device
    @pytest.mark.parametrize("backend", BACKENDS, ids=lambda b: b.name)
    def test_sharded_round_byte_identical_seeded(backend):
        mesh = make_auction_mesh()
        for seed in range(8):
            _check_round_parity(seed, mesh, backend=backend)


@multi_device
@pytest.mark.parametrize("backend", [GreedyWIS(), FairShare()],
                         ids=lambda b: b.name)
def test_sharded_pipelined_equals_serial_unsharded(backend):
    mesh = make_auction_mesh()
    for seed in (5, 17):
        _check_round_parity(seed, mesh, backend=backend, pipelined=True)


@multi_device
def test_sharded_empty_and_all_masked_windows():
    """Rounds where some shards see only padding and some windows clear
    empty must match unsharded exactly (including the empty results)."""
    mesh = make_auction_mesh()
    rng = np.random.default_rng(0)
    policy = ScoringPolicy()
    # 2 bids across 9 windows: most windows all-masked, most shards empty
    windows, pool = _mk_round(rng, 2, 9)
    base = clear_round(windows, pool, policy, wis_impl="ref")
    shard = clear_round(windows, pool, policy, wis_impl="ref", mesh=mesh)
    assert _sig(base) == _sig(shard)
    assert len(base.results) == 9


@multi_device
def test_odd_mesh_falls_back_identically():
    """A hand-built non-pow2 mesh cannot divide pow2 buckets — the guard
    degrades every dispatch to unsharded, with identical results."""
    if jax.local_device_count() < 3:
        pytest.skip("needs 3 devices")
    odd = jax.make_mesh((3,), (AUCTION_AXIS,), devices=jax.devices()[:3])
    rng = np.random.default_rng(4)
    windows, pool = _mk_round(rng, 700, 5)
    base = clear_round(windows, pool, ScoringPolicy(), wis_impl="ref")
    shard = clear_round(windows, pool, ScoringPolicy(), wis_impl="ref",
                        mesh=odd)
    assert _sig(base) == _sig(shard)


@multi_device
def test_scheduler_mesh_knob_byte_identical():
    """SchedulerConfig.mesh: full simulated auction (pipelined) sharded ==
    single-device, across logs and commit logs."""

    def run(mesh):
        cfg = SchedulerConfig.from_policy(
            Policy(), wis_impl="ref", score_impl="ref")
        import dataclasses

        cfg = dataclasses.replace(cfg, mesh=mesh)
        sched = JasdaScheduler(
            [SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10", 10 * GB, n_chips=2)], cfg)
        simulate(sched, make_workload(30, seed=3, arrival_rate=0.3),
                 SimConfig(t_end=600.0, seed=2, pipeline=True))
        return ([(r.t, r.n_selected, round(r.total_score, 9))
                 for r in sched.log],
                [(c.variant_id, c.slice_id, round(c.t_start, 9),
                  round(c.score, 9)) for c in sched.commit_log])

    assert run(None) == run(make_auction_mesh())


@multi_device
def test_large_round_sharded_equivalence_and_zero_retrace():
    """The headline contract at scale: an 8-way (or what the session has)
    sharded round at M ≥ 1e5 is byte-identical to single-device, and a
    second same-bucket round retraces NOTHING (one executable per pow2
    bucket per mesh shape)."""
    from repro.kernels.jasda_score import ops as score_ops
    from repro.kernels.wis_dp import ops as wis_ops

    mesh = make_auction_mesh(8)
    rng = np.random.default_rng(100)
    policy = ScoringPolicy()
    windows, pool = _mk_round(rng, 1 << 17, 24, n_jobs=101)
    assert len(pool) >= 100_000
    base = clear_round(windows, pool, policy, wis_impl="ref")
    shard = clear_round(windows, pool, policy, wis_impl="ref", mesh=mesh)
    assert _sig(base) == _sig(shard)

    # same pow2 bucket, different M / different data → zero retraces
    windows2, pool2 = _mk_round(rng, (1 << 17) - 4097, 24, n_jobs=101)
    before = (score_ops.trace_counts(), wis_ops.trace_counts())
    base2 = clear_round(windows2, pool2, policy, wis_impl="ref")
    shard2 = clear_round(windows2, pool2, policy, wis_impl="ref", mesh=mesh)
    assert _sig(base2) == _sig(shard2)
    after = (score_ops.trace_counts(), wis_ops.trace_counts())
    assert after == before, f"retraced: {before} -> {after}"
