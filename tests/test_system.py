"""End-to-end system behaviour: the paper's full loop + framework glue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (JasdaScheduler, SimConfig, SliceSpec, make_workload,
                        simulate)

GB = 1 << 30


def test_full_interaction_cycle_end_to_end():
    """One complete JASDA lifecycle: announce → bid → clear → commit →
    execute → verify, with metrics coming out the other side."""
    slices = [SliceSpec(f"s{k}", 20 * GB, n_chips=2) for k in range(3)]
    sched = JasdaScheduler(slices)
    agents = make_workload(25, seed=9, arrival_rate=0.5)
    res = simulate(sched, agents, SimConfig(t_end=2500.0, seed=1))
    assert res.n_finished == 25
    assert res.capacity_violations <= 2
    assert res.utilization > 0.1
    # audit trail exists (transparency, paper §5(f))
    assert len(sched.log) > 100
    assert any(row.n_selected > 0 for row in sched.log)
    # ex-post verification ran: every job has calibration state
    snap = sched.calibrator.snapshot()
    assert len(snap) == 25
    assert all(0 < s["rho"] <= 1 for s in snap.values())


def test_lambda_policy_spectrum():
    """Table 2's qualitative claim: the λ knob changes scheduling behaviour
    (selection order shifts between job-centric and system-centric)."""
    from repro.core.scheduler import SchedulerConfig
    from repro.core import ScoringPolicy
    slices = [SliceSpec("s0", 16 * GB, n_chips=2)]
    orders = {}
    for lam in (0.3, 0.7):
        sched = JasdaScheduler(
            [SliceSpec("s0", 16 * GB, n_chips=2)],
            SchedulerConfig(scoring=ScoringPolicy(lam=lam)))
        agents = make_workload(30, seed=4, arrival_rate=2.0)
        simulate(sched, agents, SimConfig(t_end=1000.0, seed=2))
        # commit_log is the append-only audit trail; `commitments` holds only
        # OUTSTANDING commitments (settled ones are pruned)
        orders[lam] = tuple(r.job_id for r in sched.commit_log[:20])
    assert orders[0.3] != orders[0.7], "λ must influence clearing decisions"


def test_quickstart_example_runs():
    import subprocess, sys, os
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "examples/quickstart.py", "--steps", "5"],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
