"""Streaming service mode: arrivals, SLO metrics, admission, durability.

Covers the service-layer contracts:
  * seeded arrival processes replay byte-identically and are invariant to
    the ``take_until`` cut points;
  * P² streaming quantiles track numpy percentiles without buffering;
  * a fixed-seed soak is deterministic (identical award log + stats);
  * crash-restart from a mid-stream checkpoint replays byte-identically;
  * under 2.0x overload bounded-queue admission retains goodput while
    accept-all degrades (blown deadlines waste capacity);
  * the HealthMonitor is wired in: silent slices are revoked, straggling
    slices degraded, and shed jobs get LOSS_SHED feedback;
  * CheckpointStore restart semantics (typed error on corrupt blobs,
    monotone latest across save→restore→save, gc keeps the newest).

CI runs this file across seeds via JASDA_SERVICE_SEED (see the service
job in .github/workflows/ci.yml).
"""
from __future__ import annotations

import os
import pickle

import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointStore
from repro.core import JasdaScheduler, SliceSpec
from repro.core.negotiation.messages import LOSS_SHED, build_shed_feedback
from repro.service import (AcceptAll, BoundedQueue, BurstArrivals,
                           DeadlineExpired, DiurnalArrivals, JasdaService,
                           JobArrival, JobCancel, P2Quantile, PoissonArrivals,
                           ServiceConfig, TokenBucket, queue_bound_for_bucket)
from repro.serving import Request, ServingArrivals

SEED = int(os.environ.get("JASDA_SERVICE_SEED", "0"))
GB = 1 << 30

# capacity of the 7-slice cluster is ~12 chips; log-uniform work on
# (8, 40) has mean (40-8)/ln(5) ~ 19.9, so this rate offers ~1.0x load
RATE_1X = 12.0 / 19.88


def _cluster():
    return ([SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10a", 10 * GB, n_chips=2),
             SliceSpec("s10b", 10 * GB, n_chips=2)]
            + [SliceSpec(f"s5{i}", 5 * GB, n_chips=1) for i in range(4)])


def _service(seed=SEED, rate=0.5, admission=None, t_end=120.0,
             qos_fraction=0.3, deadline_slack=(3.0, 8.0),
             cancel_fraction=0.0, max_bucket_m=512):
    arr = PoissonArrivals(rate, seed=seed, work_range=(8.0, 40.0),
                          mem_range_gb=(1.0, 12.0),
                          qos_fraction=qos_fraction,
                          deadline_slack=deadline_slack,
                          cancel_fraction=cancel_fraction)
    cfg = ServiceConfig(t_end=t_end, seed=seed, max_bucket_m=max_bucket_m)
    return JasdaService(JasdaScheduler(_cluster()), arr, config=cfg,
                        admission=admission or AcceptAll())


def _soak_key(svc, stats):
    """Everything two identical soaks must agree on, byte for byte."""
    return ([(r.round, r.t, r.variant_id, r.job_id, r.slice_id)
             for r in svc.award_log], stats)


# ---------------------------------------------------------------------------
# arrivals
# ---------------------------------------------------------------------------

class TestArrivals:
    @pytest.mark.parametrize("mk", [
        lambda s: PoissonArrivals(0.8, seed=s),
        lambda s: BurstArrivals(0.3, 2.0, seed=s),
        lambda s: DiurnalArrivals(1.0, period=120.0, seed=s),
    ], ids=["poisson", "burst", "diurnal"])
    def test_replay_identical_and_cut_invariant(self, mk):
        # one big pull vs many small pulls: same events, same order
        a, b = mk(SEED), mk(SEED)
        big = a.take_until(200.0)
        small = []
        for t in np.arange(2.0, 202.0, 2.0):
            small.extend(b.take_until(float(t)))
        assert big == small
        assert len(big) > 20

    def test_different_seeds_differ(self):
        a = PoissonArrivals(0.8, seed=1).take_until(100.0)
        b = PoissonArrivals(0.8, seed=2).take_until(100.0)
        assert a != b

    def test_side_events_reference_emitted_jobs(self):
        arr = PoissonArrivals(1.0, seed=SEED, qos_fraction=1.0,
                              deadline_slack=(0.5, 1.0), cancel_fraction=0.5)
        evs = arr.take_until(150.0)
        jobs = {e.spec.job_id for e in evs if isinstance(e, JobArrival)}
        deadlines = [e for e in evs if isinstance(e, DeadlineExpired)]
        cancels = [e for e in evs if isinstance(e, JobCancel)]
        assert deadlines and all(d.job_id in jobs for d in deadlines)
        assert cancels and all(c.job_id in jobs for c in cancels)
        # events come out time-ordered
        ts = [e.t for e in evs]
        assert ts == sorted(ts)

    def test_pickle_resumes_mid_draw(self):
        a = PoissonArrivals(0.7, seed=SEED, qos_fraction=0.5)
        a.take_until(50.0)
        b = pickle.loads(pickle.dumps(a))
        assert a.take_until(150.0) == b.take_until(150.0)

    def test_t_end_truncates(self):
        arr = PoissonArrivals(1.0, seed=SEED, t_end=30.0)
        evs = arr.take_until(500.0)
        assert all(e.t <= 30.0 for e in evs if isinstance(e, JobArrival))
        assert arr.take_until(1000.0) == []

    def test_diurnal_modulates(self):
        # floor=0: arrivals concentrate in the sine's high half-period
        arr = DiurnalArrivals(2.0, period=100.0, floor=0.0, seed=SEED)
        ts = [e.t for e in arr.take_until(1000.0)
              if isinstance(e, JobArrival)]
        phase = [t % 100.0 for t in ts]
        high = sum(1 for p in phase if p < 50.0)  # sin>0 half
        assert high > 0.7 * len(phase)


# ---------------------------------------------------------------------------
# streaming quantiles
# ---------------------------------------------------------------------------

class TestP2Quantile:
    @pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
    @pytest.mark.parametrize("dist", ["uniform", "lognormal", "exponential"])
    def test_tracks_numpy_percentile(self, q, dist):
        rng = np.random.default_rng(SEED)
        xs = getattr(rng, dist)(size=4000)
        est = P2Quantile(q)
        for x in xs:
            est.observe(x)
        truth = float(np.percentile(xs, 100 * q))
        spread = float(np.percentile(xs, 99.5) - np.percentile(xs, 0.5))
        assert abs(est.value() - truth) < 0.12 * spread

    def test_small_sample_exact(self):
        est = P2Quantile(0.5)
        for x in (5.0, 1.0, 3.0):
            est.observe(x)
        assert est.value() == 3.0

    def test_empty_is_nan(self):
        assert np.isnan(P2Quantile(0.9).value())

    def test_deterministic_and_picklable(self):
        xs = np.random.default_rng(SEED).exponential(size=500)
        a, b = P2Quantile(0.95), P2Quantile(0.95)
        for x in xs[:250]:
            a.observe(x)
            b.observe(x)
        b = pickle.loads(pickle.dumps(b))
        for x in xs[250:]:
            a.observe(x)
            b.observe(x)
        assert a.value() == b.value()

    def test_rejects_bad_q(self):
        with pytest.raises(ValueError):
            P2Quantile(0.0)
        with pytest.raises(ValueError):
            P2Quantile(1.5)


# ---------------------------------------------------------------------------
# the soak: determinism + lifecycle
# ---------------------------------------------------------------------------

class TestServiceSoak:
    def test_fixed_seed_soak_deterministic(self):
        s1 = _service(cancel_fraction=0.05)
        s2 = _service(cancel_fraction=0.05)
        st1, st2 = s1.run(), s2.run()
        assert st1.n_awards > 0  # NaN-latency stats would compare unequal
        assert _soak_key(s1, st1) == _soak_key(s2, st2)

    def test_lifecycle_accounting(self):
        svc = _service(rate=0.6, qos_fraction=1.0, deadline_slack=(1.0, 2.0),
                       cancel_fraction=0.1, t_end=160.0)
        st = svc.run()
        assert st.n_arrived == st.n_admitted + st.n_shed
        assert st.n_completed + st.n_expired + st.n_cancelled <= st.n_admitted
        assert st.n_completed > 0 and st.n_rounds >= 160
        assert st.goodput > 0 and st.completed_work > 0
        # in-flight bookkeeping stays bounded by the live pool
        assert len(svc.metrics.timelines) <= len(svc.scheduler.agents)
        # SLO quantiles are populated and ordered
        assert 0 <= st.latency_p50 <= st.latency_p95 <= st.latency_p99
        assert st.announce_award_p50 <= st.announce_award_p99

    def test_non_pipelined_matches_pipelined(self):
        # the pipelined prepare/settle path must not change decisions
        s1 = _service()
        st1 = s1.run()
        arr = PoissonArrivals(0.5, seed=SEED, work_range=(8.0, 40.0),
                              mem_range_gb=(1.0, 12.0), qos_fraction=0.3,
                              deadline_slack=(3.0, 8.0))
        s2 = JasdaService(
            JasdaScheduler(_cluster()), arr,
            config=ServiceConfig(t_end=120.0, seed=SEED, pipeline=False))
        st2 = s2.run()
        assert _soak_key(s1, st1) == _soak_key(s2, st2)

    def test_expired_jobs_leave_the_pool(self):
        svc = _service(rate=1.5, qos_fraction=1.0, deadline_slack=(0.5, 1.0),
                       t_end=100.0)
        st = svc.run()
        assert st.n_expired > 0
        # the pool only holds jobs whose deadline has not passed: an
        # expiry event always evicts its (unfinished) job
        for a in svc.scheduler.agents.values():
            if a.spec.qos_deadline is not None and not a.finished:
                assert a.spec.qos_deadline > svc.now - 1e-9


# ---------------------------------------------------------------------------
# durability: crash-restart byte-identity
# ---------------------------------------------------------------------------

class TestCrashRestart:
    def test_restart_replays_byte_identically(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=10)
        s1 = _service(cancel_fraction=0.05)
        st1 = s1.run(checkpoint=store, checkpoint_every=30)
        steps = store.steps()
        assert len(steps) >= 3
        # "crash" at an interior checkpoint: restore and run to horizon
        mid = steps[len(steps) // 2]
        s2 = JasdaService.restore(store, mid)
        assert s2.round_count == mid
        st2 = s2.run()
        assert _soak_key(s1, st1) == _soak_key(s2, st2)

    def test_restore_latest_by_default(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=10)
        s1 = _service(t_end=60.0)
        s1.run(checkpoint=store, checkpoint_every=20)
        s2 = JasdaService.restore(store)
        assert s2.round_count == max(store.steps())

    def test_restore_rejects_foreign_payload(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save_state(0, {"not": "a service"})
        with pytest.raises(TypeError):
            JasdaService.restore(store)


# ---------------------------------------------------------------------------
# admission control under overload
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_queue_bound_from_bucket(self):
        assert queue_bound_for_bucket(512) == 32
        assert queue_bound_for_bucket(128) == 8
        assert queue_bound_for_bucket(16) == 4  # floor

    def test_token_bucket_rate_limits(self):
        tb = TokenBucket(rate=0.1, burst=2.0)
        decisions = [tb.on_arrival(None, float(t), [])[0]
                     for t in range(0, 40, 2)]
        assert decisions[0] and decisions[1]  # burst admits
        assert not all(decisions)  # then the rate bites
        assert sum(decisions) <= 2 + 0.1 * 40 + 1

    def test_overload_bounded_retains_goodput_accept_all_degrades(self):
        # the acceptance scenario: QoS jobs under 2.0x offered load; the
        # bounded pool sheds early (before capacity is spent) while
        # accept-all admits everything and blows deadlines mid-flight
        kw = dict(qos_fraction=1.0, deadline_slack=(1.0, 2.0),
                  t_end=240.0, max_bucket_m=128)
        base = _service(rate=RATE_1X, admission=AcceptAll(), **kw).run()
        bounded = _service(rate=2 * RATE_1X, admission=BoundedQueue(),
                           **kw).run()
        flood = _service(rate=2 * RATE_1X, admission=AcceptAll(), **kw).run()
        assert base.goodput > 0
        retained_bounded = bounded.goodput / base.goodput
        retained_flood = flood.goodput / base.goodput
        assert bounded.n_shed > 0 and flood.n_shed == 0
        # the SLO: bounded keeps goodput within 10% of the 1.0x run
        assert retained_bounded >= 0.9
        # while accept-all measurably degrades below the bounded run
        assert retained_flood < retained_bounded - 0.05
        # and wastes far more admitted work on blown deadlines
        assert flood.n_expired > bounded.n_expired

    def test_shed_jobs_get_loss_shed_feedback(self):
        svc = _service(rate=2 * RATE_1X, admission=BoundedQueue(4),
                       t_end=60.0)
        st = svc.run()
        assert st.n_shed > 0
        # an evicted victim counts both as admitted (then) and shed (now),
        # so the two sides cover every arrival with eviction overlap
        assert st.n_admitted + st.n_shed >= st.n_arrived
        # pool never exceeds the bound right after an admission decision
        live = [a for a in svc.scheduler.agents.values() if not a.finished]
        assert len(live) <= 4 + 1  # +1: the round in flight may finish one

    def test_build_shed_feedback_shape(self):
        fb = build_shed_feedback(5.0, ["j1", "j2"])
        assert set(fb.losses) == {"j1", "j2"}
        for jid in ("j1", "j2"):
            (lr,) = fb.losses[jid]
            assert lr.reason == LOSS_SHED
            assert lr.variant_id == jid
            assert lr.window.slice_id == "" and lr.window.duration == 0.0
        assert fb.awards == {} and fb.windows == ()
        assert fb.reliability == {"j1": 1.0, "j2": 1.0}


# ---------------------------------------------------------------------------
# health-monitor wiring
# ---------------------------------------------------------------------------

class TestHealthWiring:
    def test_muted_slice_gets_revoked(self):
        svc = _service(rate=0.8, t_end=80.0)
        svc.mute_slice("s20")
        st = svc.run()
        assert st.n_revoked_slices == 1
        assert "s20" not in svc.scheduler.slices
        assert "s20" in svc.dead_slices
        # no award may land on the dead slice after revocation
        revoke_t = 1.0 * (1 + svc.monitor.cfg.max_missed)
        late = [r for r in svc.award_log
                if r.slice_id == "s20" and r.t > revoke_t + 1.0]
        assert late == []

    def test_straggler_gets_degraded_once(self):
        from repro.runtime.monitor import HealthConfig, HealthMonitor

        arr = PoissonArrivals(0.8, seed=SEED, work_range=(8.0, 40.0),
                              mem_range_gb=(1.0, 12.0))
        # short EWMA halflife so a few slow completions trip the detector
        monitor = HealthMonitor(HealthConfig(
            heartbeat_interval=1.0, straggler_ratio=0.6, speed_halflife=2))
        svc = JasdaService(JasdaScheduler(_cluster()), arr,
                           config=ServiceConfig(t_end=100.0, seed=SEED),
                           monitor=monitor)
        # degrade the executor's view of s10a: completions post low
        # observed speed, the EWMA sinks below the straggler ratio
        orig = svc.exec.launch

        def slow_launch(v, t_now):
            orig(v, t_now)
            if v.slice_id == "s10a" and "s10a" in svc.exec.running:
                # stretch the recorded duration: the completion event
                # still pops at the original time, but dur_actual (and so
                # the observed speed the monitor sees) says a 3x-slow run
                vv, end = svc.exec.running["s10a"]
                svc.exec.running["s10a"] = (
                    vv, vv.t_start + 3.0 * (end - vv.t_start))

        svc.exec.launch = slow_launch
        st = svc.run()
        assert st.n_degraded_slices >= 1
        assert "s10a" in svc._degraded
        # degraded exactly once despite many slow completions
        assert st.n_degraded_slices == len(svc._degraded)

    def test_healthy_run_touches_no_slices(self):
        st = _service(t_end=60.0).run()
        assert st.n_revoked_slices == 0 and st.n_degraded_slices == 0


# ---------------------------------------------------------------------------
# checkpoint-store restart semantics (satellite)
# ---------------------------------------------------------------------------

class TestCheckpointStoreRestart:
    def test_latest_survives_gc(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=2)
        for s in range(6):
            store.save_state(s, {"s": s})
        assert store.steps() == [4, 5]
        obj, step = store.restore_state()
        assert (obj["s"], step) == (5, 5)

    def test_truncated_blob_raises_typed_error(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save_state(3, {"x": list(range(1000))})
        blob = tmp_path / "step_3" / "state.pkl"
        blob.write_bytes(blob.read_bytes()[:20])
        with pytest.raises(CheckpointError):
            store.restore_state(3)

    def test_corrupt_blob_raises_typed_error(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save_state(1, {"x": 1})
        blob = tmp_path / "step_1" / "state.pkl"
        data = bytearray(blob.read_bytes())
        data[: len(data) // 2] = os.urandom(len(data) // 2)
        blob.write_bytes(bytes(data))
        with pytest.raises((CheckpointError, Exception)) as ei:
            store.restore_state(1)
        # the contract: never a bare EOFError/UnpicklingError
        assert not isinstance(ei.value, (EOFError, pickle.UnpicklingError))

    def test_corrupt_latest_falls_back_to_older_step(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=5)
        store.save_state(1, {"ok": 1})
        store.save_state(2, {"ok": 2})
        (tmp_path / "step_2" / "state.pkl").write_bytes(b"\x80garbage")
        with pytest.raises(CheckpointError):
            store.restore_state()
        obj, step = store.restore_state(1)  # the fallback callers use
        assert (obj["ok"], step) == (1, 1)

    def test_save_restore_save_keeps_index_monotone(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=10)
        svc = _service(t_end=40.0)
        svc.run(checkpoint=store, checkpoint_every=10)
        first = list(store.steps())
        svc2 = JasdaService.restore(store, first[0])
        svc2.run(t_end=80.0, checkpoint=store, checkpoint_every=10)
        after = store.steps()
        assert after == sorted(after)
        assert store.latest_step() == max(after)
        assert max(after) > max(first)

    def test_array_step_rejected_by_restore_state(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save(0, {"w": np.ones((2, 2), np.float32)}, blocking=True)
        with pytest.raises(ValueError):
            store.restore_state(0)


# ---------------------------------------------------------------------------
# serving adapter: token-level requests through the auction (PR-8 carry-over)
# ---------------------------------------------------------------------------

class TestServingAdapter:
    def _trace(self, n=6):
        rng = np.random.default_rng(7)
        reqs = []
        for i in range(n):
            prompt = rng.integers(0, 100, size=8 + 2 * i).astype(np.int32)
            reqs.append((1.0 + 2.0 * i,
                         Request(f"r{i}", prompt, max_new_tokens=8 + i)))
        return reqs

    def _svc(self, trace, seed=SEED, t_end=90.0):
        arr = ServingArrivals(trace)
        cfg = ServiceConfig(t_end=t_end, seed=seed)
        return JasdaService(JasdaScheduler(_cluster()), arr, config=cfg,
                            admission=AcceptAll())

    def test_requests_complete_with_ordered_timeline(self):
        trace = self._trace()
        svc = self._svc(trace)
        # timelines are popped on completion; stash them on the way out
        finished = {}
        orig = svc.metrics.completed

        def completed(jid, now, work):
            finished[jid] = (svc.metrics.timelines.get(jid), now)
            orig(jid, now, work)

        svc.metrics.completed = completed
        stats = svc.run()
        assert stats.n_arrived == len(trace)
        assert stats.n_admitted == len(trace)
        assert stats.n_completed == len(trace)
        arrivals = {f"req-{r.request_id}": t for t, r in trace}
        assert set(finished) == set(arrivals)
        for jid, (tl, t_done) in finished.items():
            # admit -> announce -> award -> complete, all after arrival
            assert tl is not None and tl.award is not None
            assert arrivals[jid] <= tl.admit <= tl.award <= t_done
            if tl.announce is not None:
                assert tl.admit <= tl.announce <= tl.award

    def test_trace_replay_is_seed_independent(self):
        # job synthesis draws nothing from the rng: same trace, different
        # seeds, byte-identical arrival stream (and same-seed soaks agree
        # end to end — executor runtime noise IS seeded)
        trace = self._trace()
        a1 = ServingArrivals(trace, seed=3).take_until(float("inf"))
        a2 = ServingArrivals(trace, seed=11).take_until(float("inf"))
        assert [(e.t, e.spec.job_id, e.spec.total_work) for e in a1] \
            == [(e.t, e.spec.job_id, e.spec.total_work) for e in a2]
        s1, s2 = self._svc(trace), self._svc(trace)
        st1, st2 = s1.run(), s2.run()
        assert _soak_key(s1, st1) == _soak_key(s2, st2)

    def test_deadline_factor_stages_expiries(self):
        trace = self._trace(4)
        arr = ServingArrivals(trace, deadline_factor=4.0)
        events = arr.take_until(float("inf"))
        arrives = [e for e in events if isinstance(e, JobArrival)]
        expiries = [e for e in events if isinstance(e, DeadlineExpired)]
        assert len(arrives) == len(expiries) == len(trace)
        assert all(a.spec.qos_deadline is not None for a in arrives)
