"""Preemption-aware recovery: the revocation ladder end to end.

Covers the PR's tentpole and its regression surface:
  * idempotent ``revoke_slice`` (fault and repartition paths may race to
    the same revocation — only the first observes anything);
  * the executor truncation path (overrun credits only the committed
    fraction; early finishes credit full work and truncate the audit row);
  * partial-progress credit through ``scheduler.preempt`` (granule
    accounting, biddable-pool arithmetic, ``preempted`` audit rows);
  * cross-slice live migration through ``scheduler.migrate_commitment``
    (residual re-placement, score carry-over, pool conservation);
  * the full ladder under a slice revocation retaining work the lossy
    path torches, with disruption counters surfaced on SimResult;
  * byte-identity of the DEGENERATE ladder (budget 0, granularity 0)
    with the historical slice-failure path — simulator serial AND
    pipelined, and a service soak through health policing;
  * a work-conservation property (hypothesis when available, seeded
    sweep otherwise): credited progress never exceeds declared work;
  * crash-checkpoint byte-identical resume ACROSS a migration boundary
    (serial AND pipelined) and planner pickling in the scheduler graph.

CI runs this file across seeds via JASDA_CHAOS_SEED (see the chaos job
in .github/workflows/ci.yml).
"""
import os
import pickle

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import (FaultEvent, FaultPlan, JasdaScheduler,
                        MigrationConfig, MigrationPlanner, SimConfig,
                        SliceSpec, make_workload, simulate)
from repro.core.events import EventHeap, ExecutionPlumbing
from repro.core.faults import SCHEDULER_CRASH, SLICE_REVOKED
from repro.service import (AcceptAll, JasdaService, PoissonArrivals,
                           ServiceConfig)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAS_HYPOTHESIS = False

SEED = int(os.environ.get("JASDA_CHAOS_SEED", "0"))
GB = 1 << 30


def _slices(n=4, cap_gb=16):
    return [SliceSpec(f"S{k}", cap_gb * GB, flops_per_s=1.0, hbm_bw=1.0)
            for k in range(n)]


def _workload(n=14, granularity=0.0, seed=None):
    return make_workload(n, seed=SEED + 1 if seed is None else seed,
                         arrival_rate=0.5, work_range=(20.0, 60.0),
                         mem_range_gb=(1.0, 8.0),
                         preempt_granularity=granularity)


def _commit_rows(sched):
    return [(r.status, r.job_id, r.slice_id, r.t_start, r.t_end, r.score)
            for r in sched.commit_log]


def _sim_key(r):
    return (_commit_rows(r.scheduler), r.jct_per_job, r.n_finished,
            r.total_score)


def _revoke_plan(t=30.5):
    """One deterministic mid-stream slice death (no repair)."""
    return FaultPlan(seed=SEED, events=(
        FaultEvent(t=t, kind=SLICE_REVOKED, target="S0"),))


def _busy_sched(n_jobs=10, granularity=0.0):
    sched = JasdaScheduler(_slices())
    for a in _workload(n_jobs, granularity=granularity):
        sched.add_job(a, 0.0)
    for k in range(3):
        sched.run_round(float(k))
    assert sched.commitments
    return sched


# ---------------------------------------------------------------------------
# idempotent revocation
# ---------------------------------------------------------------------------

class TestIdempotentRevocation:
    def test_double_revoke_is_a_strict_noop(self):
        sched = _busy_sched()
        sid = sched.commitments[0].variant.slice_id
        lost = sched.revoke_slice(sid, 3.0)
        assert lost and sid not in sched.slices
        rows = _commit_rows(sched)
        epoch = sched._epoch
        fb = sched.last_feedback
        n_lost = sched.n_lost_total
        # the second revocation (a fault/repartition race) observes nothing
        assert sched.revoke_slice(sid, 4.0) == []
        assert _commit_rows(sched) == rows  # no duplicate ``lost`` rows
        assert sched._epoch == epoch  # no epoch churn
        assert sched.last_feedback is fb  # no second broadcast
        assert sched.n_lost_total == n_lost
        assert sched.loss_reasons.get("slice_failed") == len(lost)

    def test_revoking_unknown_slice_is_a_noop(self):
        sched = JasdaScheduler(_slices())
        epoch = sched._epoch
        assert sched.revoke_slice("nope", 0.0) == []
        assert sched._epoch == epoch


# ---------------------------------------------------------------------------
# executor truncation (core/events.py complete())
# ---------------------------------------------------------------------------

class TestExecutorTruncation:
    def _committed(self):
        sched = JasdaScheduler(_slices())
        ex = ExecutionPlumbing(sched, EventHeap(),
                               np.random.default_rng(SEED),
                               runtime_cv=0.0, check_capacity=False)
        for a in _workload(8):
            sched.add_job(a, 0.0)
        rr = sched.run_round(0.0)
        assert rr.selected
        return sched, ex, rr.selected[0]

    def test_overrun_credits_only_the_committed_fraction(self):
        sched, ex, v = self._committed()
        agent = sched.agents[v.job_id]
        work = float(v.payload["work"])
        dur_actual = 2.0 * (v.t_end - v.t_start)  # 2x overrun
        ex.running[v.slice_id] = (v, v.t_start + dur_actual)
        out = ex.complete(v.slice_id, v.t_start + dur_actual)
        assert out is not None and out[0] is v
        # the tail beyond the committed end is lost work
        assert agent.work_done == pytest.approx(
            work * (v.t_end - v.t_start) / dur_actual)
        row = [r for r in sched.commit_log if r.status == "completed"][0]
        assert row.t_end == pytest.approx(v.t_end)  # slice reclaimed on time

    def test_early_finish_credits_full_work_and_truncates_row(self):
        sched, ex, v = self._committed()
        agent = sched.agents[v.job_id]
        dur_actual = 0.5 * (v.t_end - v.t_start)
        ex.running[v.slice_id] = (v, v.t_start + dur_actual)
        ex.complete(v.slice_id, v.t_start + dur_actual)
        assert agent.work_done == pytest.approx(float(v.payload["work"]))
        row = [r for r in sched.commit_log if r.status == "completed"][0]
        assert row.t_end == pytest.approx(v.t_start + dur_actual)

    def test_vacated_slice_completion_is_none(self):
        sched, ex, v = self._committed()
        assert ex.complete(v.slice_id, 10.0) is None  # never launched


# ---------------------------------------------------------------------------
# partial-progress credit (scheduler.preempt)
# ---------------------------------------------------------------------------

class TestPartialProgressCredit:
    def test_preempt_credits_work_and_audits(self):
        sched = _busy_sched(granularity=5.0)
        c = sched.commitments[0]
        v = c.variant
        agent = sched.agents[v.job_id]
        work = float(v.payload["work"])
        credit = min(5.0, work)
        biddable_before = agent.biddable_work
        mid = 0.5 * (v.t_start + v.t_end)
        rec = sched.preempt(v, mid, work_done=credit)
        assert rec is not None and rec.status == "preempted"
        assert rec.work_credited == pytest.approx(credit)
        assert rec.t_end == pytest.approx(mid)
        # only the residual re-enters the biddable pool
        assert agent.work_done == pytest.approx(credit)
        assert agent.biddable_work == pytest.approx(
            biddable_before + work - credit)
        assert sched.n_preempted_total == 1
        assert sched.work_credited_total == pytest.approx(credit)
        assert sched.loss_reasons == {"preempted": 1}

    def test_preempt_unknown_commitment_returns_none(self):
        sched = _busy_sched()
        v = sched.commitments[0].variant
        sched.fail(v, 1.0)  # already settled
        assert sched.preempt(v, 2.0, work_done=1.0) is None

    def test_zero_granularity_keeps_all_or_nothing(self):
        # the default JobSpec declares no checkpoint granularity
        for a in _workload(4):
            assert a.spec.preempt_granularity == 0.0
        # and a granular workload carries it through
        for a in _workload(4, granularity=3.0):
            assert a.spec.preempt_granularity == 3.0


# ---------------------------------------------------------------------------
# cross-slice live migration (scheduler.migrate_commitment)
# ---------------------------------------------------------------------------

class TestLiveMigration:
    def test_migrate_moves_residual_and_preserves_score(self):
        sched = _busy_sched(granularity=5.0)
        c = sched.commitments[0]
        v = c.variant
        agent = sched.agents[v.job_id]
        work = float(v.payload["work"])
        credit, residual = 5.0, work - 5.0
        target = next(s for s in sorted(sched.slices) if s != v.slice_id)
        t0 = 500.0  # far future: trivially idle on the target
        biddable_before = agent.biddable_work
        new_v = sched.migrate_commitment(
            v, 2.0, slice_id=target, t_start=t0, duration=30.0,
            residual_work=residual, credited_work=credit)
        assert new_v is not None
        assert new_v.slice_id == target
        assert new_v.variant_id == v.variant_id + "~mig"
        assert float(new_v.payload["work"]) == pytest.approx(residual)
        # migration is not a re-auction: the commit score carries over
        succ = [d for d in sched.commitments if d.variant is new_v][0]
        assert succ.score == pytest.approx(c.score)
        old_row = [r for r in sched.commit_log if r.status == "migrated"][0]
        assert old_row.work_credited == pytest.approx(credit)
        # pool conservation: outstanding swapped W → residual, done +credit
        assert agent.work_done == pytest.approx(credit)
        assert agent.biddable_work == pytest.approx(biddable_before)
        # the target timeline actually holds the successor's reservation
        with pytest.raises(ValueError):
            sched.slices[target].commit(t0, t0 + 1.0)
        assert sched.n_migrated_total == 1

    def test_migrate_to_unknown_slice_returns_none(self):
        sched = _busy_sched()
        v = sched.commitments[0].variant
        assert sched.migrate_commitment(
            v, 1.0, slice_id="nope", t_start=5.0, duration=5.0,
            residual_work=1.0) is None


# ---------------------------------------------------------------------------
# the ladder under fire
# ---------------------------------------------------------------------------

class TestRevocationLadder:
    def test_ladder_retains_work_the_lossy_path_torches(self):
        agents = lambda: _workload(14, granularity=4.0)  # noqa: E731
        r_off = simulate(JasdaScheduler(_slices()), agents(),
                         SimConfig(t_end=250.0, seed=SEED),
                         faults=_revoke_plan())
        r_on = simulate(JasdaScheduler(_slices()), agents(),
                        SimConfig(t_end=250.0, seed=SEED,
                                  migration=MigrationConfig()),
                        faults=_revoke_plan())
        # the ladder actually fired, and its rungs are accounted
        assert r_on.n_migrated + r_on.n_preempted > 0
        assert r_off.n_migrated == r_off.n_preempted == 0
        # the lossy run torches every doomed chunk (queued ones as
        # ``slice_failed`` losses, the running one as a creditless
        # ``failed`` row); the ladder run saves work from them — either
        # re-placed residuals or granule credit
        assert r_off.work_credited == 0.0
        assert r_on.n_lost_commitments <= r_off.n_lost_commitments
        assert r_on.n_migrated > 0 or r_on.work_credited > 0.0

    def test_planner_counters_match_scheduler_ledger(self):
        r = simulate(JasdaScheduler(_slices()),
                     _workload(14, granularity=4.0),
                     SimConfig(t_end=200.0, seed=SEED,
                               migration=MigrationConfig()),
                     faults=_revoke_plan())
        sched = r.scheduler
        assert r.n_migrated == sched.n_migrated_total
        assert r.n_preempted == sched.n_preempted_total
        assert r.n_lost_commitments == sched.n_lost_total
        assert r.work_credited == pytest.approx(sched.work_credited_total)
        # the per-reason histogram sums to the event counters
        reasons = dict(r.loss_reasons)
        assert reasons.get("migrated", 0) == r.n_migrated
        assert reasons.get("preempted", 0) == r.n_preempted
        # every audit credit is non-negative and the ledger sums exactly
        credits = [getattr(rec, "work_credited", 0.0)
                   for rec in sched.commit_log]
        assert all(w >= 0.0 for w in credits)
        assert sum(credits) == pytest.approx(sched.work_credited_total)


# ---------------------------------------------------------------------------
# byte-identity of the degenerate ladder
# ---------------------------------------------------------------------------

class TestStaticIdentity:
    @pytest.mark.parametrize("pipeline", [False, True])
    def test_degenerate_ladder_identical_to_lossy_path(self, pipeline):
        agents = lambda: _workload(14)  # noqa: E731  (granularity 0)
        r0 = simulate(JasdaScheduler(_slices()), agents(),
                      SimConfig(t_end=200.0, seed=SEED, pipeline=pipeline),
                      faults=_revoke_plan(t=25.5))
        r1 = simulate(JasdaScheduler(_slices()), agents(),
                      SimConfig(t_end=200.0, seed=SEED, pipeline=pipeline,
                                migration=MigrationConfig(
                                    migration_budget=0)),
                      faults=_revoke_plan(t=25.5))
        assert _sim_key(r0) == _sim_key(r1)
        assert r1.n_migrated == 0 and r1.n_preempted == 0

    def test_service_soak_identical_through_policing(self):
        def soak(migration):
            arr = PoissonArrivals(0.6, seed=SEED, work_range=(8.0, 40.0),
                                  mem_range_gb=(1.0, 8.0))
            cfg = ServiceConfig(t_end=80.0, seed=SEED, migration=migration)
            svc = JasdaService(JasdaScheduler(_slices()), arr,
                               config=cfg, admission=AcceptAll())
            svc.mute_slice("S0")  # policed dead after max_missed beats
            stats = svc.run()
            assert stats.n_revoked_slices == 1  # the ladder entry fired
            return ([(r.round, r.t, r.variant_id, r.job_id, r.slice_id)
                     for r in svc.award_log], stats)

        # Poisson jobs declare no granularity, so a budget-0 ladder must
        # degenerate to the historical lossy path byte-for-byte — the
        # ServiceStats snapshots (counters included) compare equal
        assert soak(None) == soak(MigrationConfig(migration_budget=0))


# ---------------------------------------------------------------------------
# work conservation (property-based when hypothesis is available)
# ---------------------------------------------------------------------------

def _assert_conservation(seed):
    plan = FaultPlan.generate(seed, t_end=150.0,
                              slice_ids=[f"S{k}" for k in range(4)],
                              revoke_rate=0.004)
    r = simulate(JasdaScheduler(_slices()),
                 _workload(12, granularity=3.0, seed=seed + 1),
                 SimConfig(t_end=150.0, seed=seed,
                           migration=MigrationConfig()),
                 faults=plan)
    for a in r.scheduler.agents.values():
        # credited progress never exceeds the declared work, never negative
        assert -1e-6 <= a.work_done <= a.spec.total_work + 1e-6
        if a.finished:
            assert a.work_done >= a.spec.total_work - 1e-6
    # credits only accrue (record_progress adds granules, never subtracts):
    # every audit row's credit is non-negative and the ledger is exact
    credits = [getattr(rec, "work_credited", 0.0)
               for rec in r.scheduler.commit_log]
    assert all(w >= 0.0 for w in credits)
    assert r.work_credited == pytest.approx(sum(credits))


if HAS_HYPOTHESIS:

    @settings(max_examples=5, deadline=None)
    @given(st.integers(min_value=0, max_value=12))
    def test_progress_conservation_property(seed):
        _assert_conservation(seed)

else:  # pragma: no cover - exercised only without hypothesis

    @pytest.mark.parametrize("seed", [SEED, SEED + 1, SEED + 2])
    def test_progress_conservation_seeded(seed):
        _assert_conservation(seed)


# ---------------------------------------------------------------------------
# durability: crash resume across a migration boundary
# ---------------------------------------------------------------------------

class TestDurability:
    @pytest.mark.parametrize("pipeline", [False, True])
    def test_crash_resume_across_migration_boundary(self, pipeline, tmp_path):
        revoke = (FaultEvent(t=30.5, kind=SLICE_REVOKED, target="S0"),)

        def run(tag, crash):
            events = revoke + ((
                FaultEvent(t=45.5, kind=SCHEDULER_CRASH),) if crash else ())
            cfg = SimConfig(t_end=220.0, seed=SEED, pipeline=pipeline,
                            migration=MigrationConfig())
            store = CheckpointStore(str(tmp_path / f"{tag}_{pipeline}"))
            return simulate(JasdaScheduler(_slices()),
                            _workload(14, granularity=4.0), cfg,
                            faults=FaultPlan(seed=SEED, events=events),
                            checkpoint=store, checkpoint_every=5)

        ref = run("ref", False)
        # the crash restores state that includes a completed migration
        assert ref.n_migrated + ref.n_preempted > 0
        crash = run("crash", True)
        assert _sim_key(crash) == _sim_key(ref)
        assert (crash.n_migrated, crash.n_preempted,
                crash.n_lost_commitments) == (
            ref.n_migrated, ref.n_preempted, ref.n_lost_commitments)
        assert crash.work_credited == pytest.approx(ref.work_credited)

    def test_planner_pickles_with_scheduler(self):
        sched = _busy_sched(granularity=5.0)
        planner = MigrationPlanner(sched)
        sid = sched.commitments[0].variant.slice_id
        planner.evacuate(sid, 3.0)
        sched2, planner2 = pickle.loads(pickle.dumps((sched, planner)))
        assert planner2.scheduler is sched2  # one graph, identity kept
        assert (planner2.n_migrated, planner2.n_preempted,
                planner2.n_lost) == (planner.n_migrated,
                                     planner.n_preempted, planner.n_lost)
        assert planner2.config == planner.config
