"""Test-session environment hooks.

``JASDA_FORCE_HOST_DEVICES=N`` splits the CPU backend into N virtual XLA
devices (``--xla_force_host_platform_device_count``) so the mesh-sharded
auction suite (tests/test_sharded_auction.py) can exercise real multi-device
shard_map dispatches on a plain CPU runner.  The flag must land in XLA_FLAGS
before the FIRST jax import, which is why this lives in conftest.py (pytest
imports it before any test module).  Unset (the default) leaves the device
topology alone — single-device runs skip the multi-device parity tests.
"""
import os

_n = os.environ.get("JASDA_FORCE_HOST_DEVICES")
if _n:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={int(_n)} " + _flags
        ).strip()
