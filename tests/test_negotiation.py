"""Bid-side negotiation API: typed round protocol, BiddingStrategy backends,
and the clearing→agent feedback channel.

The GreedyChunking byte-identity property is pinned against a FROZEN copy
of the pre-negotiation ``JobAgent.generate_variants`` chunk chain kept in
this file: the production code moved into ``repro.core.negotiation``, so
only a literal reference copy can detect a semantic drift of the default
strategy.  Property tests run under hypothesis when available and fall
back to seeded random cases otherwise (hypothesis is not in the baked-in
environment).
"""
import numpy as np
import pytest

from repro.core import (AgentConfig, JasdaScheduler, JobAgent, JobSpec,
                        Policy, SimConfig, SliceSpec, simulate)
from repro.core.atomizer import chunk_candidates
from repro.core.calibration import CalibrationConfig, Calibrator
from repro.core.negotiation import (AdaptiveBidder, Award, BidBundle,
                                    BiddingStrategy, ConservativeSafety,
                                    GreedyChunking, LossReport, RoundFeedback,
                                    WindowAnnouncement, build_feedback)
from repro.core.negotiation.messages import (LOSS_OUTSCORED,
                                             LOSS_SELF_CONFLICT,
                                             LOSS_WINDOW_EMPTY)
from repro.core.trp import fmp_standard, prob_exceed_grid
from repro.core.types import Variant, Window
from repro.core.windows import WindowPolicy

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAS_HYPOTHESIS = False

GB = 1 << 30


# ---------------------------------------------------------------------------
# frozen pre-negotiation reference: the JobAgent generation as shipped before
# the strategy API (verbatim semantics; do NOT refactor alongside production)
# ---------------------------------------------------------------------------

def _ref_features(agent, work, duration, t_start, now):
    from repro.core.scoring import JobFeatures

    finish = t_start + duration
    wait = max(0.0, t_start - now)
    phi_jct = float(np.clip(duration / max(duration + wait, 1e-9), 0.0, 1.0))
    if agent.spec.qos_deadline is None:
        phi_qos = 1.0
    else:
        rem_after = agent.work_remaining - work
        est_completion = finish + rem_after
        phi_qos = JobFeatures.qos(est_completion <= agent.spec.qos_deadline)
    phi_prog = JobFeatures.progress(work, agent.work_remaining)
    return {"jct": phi_jct, "qos": phi_qos, "progress": phi_prog}


def _ref_make_variant(agent, window, t_start, plan, now, seq):
    feats = _ref_features(agent, plan.work, plan.duration, t_start, now)
    declared = {
        k: float(np.clip(v * agent.cfg.misreport, 0.0, 1.0))
        for k, v in feats.items()
    }
    h = sum(agent.cfg.alphas.get(k, 0.0) * v for k, v in declared.items())
    vid = (f"{agent.spec.job_id}/{window.slice_id}"
           f"@{window.t_min:.9g}#{seq}")
    return Variant(
        job_id=agent.spec.job_id,
        slice_id=window.slice_id,
        t_start=t_start,
        duration=plan.duration,
        fmp=agent.spec.fmp,
        local_utility=float(np.clip(h, 0.0, 1.0)),
        declared_features=declared,
        payload={
            "work": plan.work,
            "activation": agent.atomizer.activation_cost,
            "true_features": feats,
        },
        variant_id=vid,
        theta=agent.cfg.theta,
    )


def _ref_generate_variants(agent, window, now, n_chips=1):
    from repro.core.trp import is_safe

    if agent.finished or agent.biddable_work <= 1e-9:
        return []
    thr = agent.throughput_on(window.capacity, n_chips)
    if thr <= 0:
        return []
    if not is_safe(agent.spec.fmp, window.capacity, agent.cfg.theta,
                   method=agent.cfg.safety_method):
        return []

    variants = []
    remaining = agent.biddable_work
    t_cursor = window.t_min
    max_v = agent.atomizer.max_variants_per_window
    while remaining > 1e-9 and t_cursor < window.t_end - 1e-9 and len(variants) < max_v:
        span = window.t_end - t_cursor
        plans = chunk_candidates(remaining, thr, span, agent.atomizer)
        if not plans:
            break
        for plan in plans:
            if len(variants) >= max_v:
                break
            if t_cursor + plan.duration > window.t_end + 1e-9:
                continue
            if agent._overlaps_own(t_cursor, plan.duration):
                continue
            variants.append(
                _ref_make_variant(agent, window, t_cursor, plan, now, len(variants))
            )
        largest = plans[0]
        remaining -= largest.work
        t_cursor += largest.duration
    if variants:
        agent.n_bids += 1
    return variants


def _ref_generate_by_window(agent, windows, now, n_chips=None):
    if agent.finished or agent.biddable_work <= 1e-9:
        return [[] for _ in windows]
    out = []
    for w in windows:
        chips = n_chips.get(w.slice_id, 1) if n_chips else 1
        out.append(_ref_generate_variants(agent, w, now, chips))
    return out


# ---------------------------------------------------------------------------
# random agent/window construction shared by the property tests
# ---------------------------------------------------------------------------

def _random_case(seed):
    rng = np.random.default_rng(seed)
    steady = float(rng.uniform(1.0, 8.0)) * GB
    fmp = fmp_standard(0.4 * steady, steady, 0.1 * steady, rel_sigma=0.03)
    deadline = float(rng.uniform(50, 400)) if rng.uniform() < 0.5 else None
    spec = JobSpec(
        job_id=f"J{seed % 97}",
        arrival_time=0.0,
        total_work=float(rng.uniform(5.0, 120.0)),
        fmp=fmp,
        qos_deadline=deadline,
        min_capacity=float(rng.choice([0.0, 2.0 * GB])),
    )
    cfg = AgentConfig(
        theta=float(rng.choice([0.02, 0.05, 0.3])),
        misreport=float(rng.choice([1.0, 1.0, 1.4])),
    )

    def build():
        a = JobAgent(spec, cfg)
        a.work_done = spec.total_work * float(rng.uniform(0.0, 0.6))
        # a couple of outstanding commitments (own-overlap checks must fire)
        for _ in range(int(rng.integers(0, 3))):
            s = float(rng.uniform(0, 150))
            a.committed_intervals.append((s, s + float(rng.uniform(3, 20))))
            a.outstanding_work += float(rng.uniform(1.0, 5.0))
        a.outstanding_work = min(a.outstanding_work, a.work_remaining)
        return a

    # identical twin agents: production vs frozen reference
    rng = np.random.default_rng(seed)  # re-seed so both builds see same draws
    prod = build()
    rng = np.random.default_rng(seed)
    ref = build()

    wrng = np.random.default_rng(seed + 1)
    windows = []
    for k in range(int(wrng.integers(1, 5))):
        t0 = float(wrng.uniform(0, 120))
        windows.append(Window(
            slice_id=f"s{k}",
            capacity=float(wrng.uniform(1.0, 12.0)) * GB,
            t_min=t0,
            duration=float(wrng.uniform(3.0, 80.0)),
        ))
    chips = {w.slice_id: int(wrng.integers(1, 4)) for w in windows}
    now = float(wrng.uniform(0, 60))
    return prod, ref, windows, chips, now


def _variant_sig(v: Variant):
    return (
        v.variant_id, v.job_id, v.slice_id, v.t_start, v.duration,
        v.local_utility, v.theta,
        tuple(sorted(v.declared_features.items())),
        v.payload["work"], v.payload["activation"],
        tuple(sorted(v.payload["true_features"].items())),
    )


def _check_greedy_matches_legacy(seed):
    prod, ref, windows, chips, now = _random_case(seed)
    got = prod.generate_variants_by_window(windows, now, chips)
    want = _ref_generate_by_window(ref, windows, now, chips)
    assert [[_variant_sig(v) for v in g] for g in got] == \
        [[_variant_sig(v) for v in g] for g in want], \
        "GreedyChunking drifted from the legacy generation"
    assert prod.n_bids == ref.n_bids
    # the flat wrapper is exactly the grouped form flattened
    prod2, ref2 = _random_case(seed)[:2]
    flat = prod2.generate_variants_round(windows, now, chips)
    assert [_variant_sig(v) for v in flat] == \
        [_variant_sig(v) for g in want for v in g]
    # and the single-window wrapper is the one-window round
    if windows:
        w = windows[0]
        single = ref2.generate_variants(w, now, chips[w.slice_id])
        assert [_variant_sig(v) for v in single] == \
            [_variant_sig(v) for v in want[0]]


@pytest.mark.parametrize("seed", range(10))
def test_greedy_chunking_byte_identical_to_legacy(seed):
    _check_greedy_matches_legacy(seed)


if HAS_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_greedy_chunking_identity_property(seed):
        _check_greedy_matches_legacy(seed)


def test_greedy_identity_holds_serial_and_pipelined():
    """End-to-end: a GreedyChunking population schedules byte-identically
    through the strategy path, serial and pipelined (feedback channel on)."""

    def run(pipeline):
        sched = JasdaScheduler(
            [SliceSpec("s0", 20 * GB, n_chips=4),
             SliceSpec("s1", 10 * GB, n_chips=2)], Policy())
        from repro.core import make_workload

        simulate(sched, make_workload(10, seed=11, arrival_rate=0.8),
                 SimConfig(t_end=400.0, seed=4, pipeline=pipeline))
        return [(c.variant_id, c.t_start, c.score) for c in sched.commit_log]

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# typed round protocol
# ---------------------------------------------------------------------------

def _agent(job_id="J0", work=50.0, theta=0.05, strategy=None, misreport=1.0,
           mem_gb=2.0):
    spec = JobSpec(job_id=job_id, arrival_time=0.0, total_work=work,
                   fmp=fmp_standard(0.5 * GB, mem_gb * GB, 0.1 * GB))
    return JobAgent(spec, AgentConfig(theta=theta, strategy=strategy,
                                      misreport=misreport))


def test_respond_returns_aligned_bundle():
    agent = _agent()
    windows = (Window("s0", 8 * GB, 0.0, 30.0), Window("s1", 8 * GB, 5.0, 20.0))
    ann = WindowAnnouncement(now=0.0, windows=windows, chips={"s0": 2})
    bundle = agent.respond(ann)
    assert isinstance(bundle, BidBundle)
    assert bundle.job_id == "J0"
    assert len(bundle.by_window) == len(windows)
    assert all(v.slice_id == w.slice_id
               for w, g in zip(windows, bundle.by_window) for v in g)
    assert bundle.variants == tuple(v for g in bundle.by_window for v in g)
    assert len(bundle) == len(bundle.variants) > 0
    assert ann.chips_for("s0") == 2 and ann.chips_for("s1") == 1


def test_finished_agent_answers_empty_bundle_without_strategy_call():
    class Exploding(BiddingStrategy):
        name = "exploding"

        def bid(self, agent, state, announcement):  # pragma: no cover
            raise AssertionError("strategy must not be consulted")

    agent = _agent(strategy=Exploding())
    agent.record_progress(agent.spec.total_work)
    ann = WindowAnnouncement(0.0, (Window("s0", 8 * GB, 0.0, 30.0),))
    bundle = agent.respond(ann)
    assert bundle.by_window == ((),)


def test_custom_strategy_plugs_into_scheduler():
    class HeadOnly(BiddingStrategy):
        """Bids only the FIRST announced window (degenerate targeting)."""

        name = "head_only"

        def bid(self, agent, state, announcement):
            from repro.core.negotiation import chunk_chain_bids

            out = [[] for _ in announcement.windows]
            if announcement.windows:
                w = announcement.windows[0]
                out[0] = chunk_chain_bids(
                    agent, w, announcement.now,
                    announcement.chips_for(w.slice_id))
            return out

    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4),
                            SliceSpec("s1", 10 * GB, n_chips=2)], Policy())
    agent = _agent(strategy=HeadOnly())
    sched.add_job(agent, 0.0)
    rr = sched.run_round(1.0)
    assert rr is not None and rr.selected
    assert agent.strategy.name == "head_only"
    assert all(v.slice_id == rr.windows[0].slice_id for v in rr.selected)


# ---------------------------------------------------------------------------
# the clearing→agent feedback channel
# ---------------------------------------------------------------------------

def test_round_feedback_contents():
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)], Policy())
    agents = [_agent(f"J{i}", work=30.0) for i in range(3)]
    for a in agents:
        sched.add_job(a, 0.0)
    rr = sched.run_round(1.0)
    fb = sched.last_feedback
    assert isinstance(fb, RoundFeedback)
    assert fb.t == 1.0
    assert fb.windows == tuple(rr.windows)
    assert fb.n_selected == len(rr.selected)
    # cutoffs: one per window, equal to the minimum winning score
    for k, w in enumerate(rr.windows):
        want = min(rr.results[k].scores) if rr.results[k].scores else 0.0
        assert fb.cutoff_for(w) == pytest.approx(want)
    # every selected variant appears as an award with its commit score
    awarded = {a.variant_id: a.score for aws in fb.awards.values() for a in aws}
    assert awarded == {
        v.variant_id: pytest.approx(s)
        for v, s in zip(rr.selected, rr.scores)
    }
    # calibration state is published for every agent in the round
    for a in agents:
        assert fb.reliability[a.spec.job_id] == 1.0
        assert fb.calibration_bias[a.spec.job_id] == 0.0


def test_feedback_loss_reasons():
    # one window, two jobs with overlapping bids: winner's alternatives are
    # self_conflict, the outbid rival is outscored
    w = Window("s0", 8 * GB, 0.0, 10.0)

    def mk(job, h, vid):
        return Variant(job_id=job, slice_id="s0", t_start=0.0, duration=8.0,
                       fmp=fmp_standard(0.5 * GB, 1 * GB, 0.1 * GB),
                       local_utility=h, declared_features={},
                       payload={"work": 8.0}, variant_id=vid)

    win, alt, rival = mk("JW", 0.9, "win"), mk("JW", 0.5, "alt"), mk("JL", 0.7, "rival")

    class A:
        def __init__(self, jid):
            self.spec = type("S", (), {"job_id": jid})()

    from repro.core.types import ClearingResult, RoundResult

    rr = RoundResult(
        windows=(w,),
        results=(ClearingResult(window=w, selected=(win,), scores=(0.9,),
                                total_score=0.9, n_bids=3,
                                rejected=(alt, rival)),),
        selected=(win,), scores=(0.9,), total_score=0.9, n_bids=3)
    fb = build_feedback(0.0, [w], [A("JW"), A("JL")],
                        [[[win, alt]], [[rival]]], rr)
    assert fb.awards["JW"] == (Award("win", w, 0.9),)
    assert fb.losses["JW"] == (LossReport("alt", w, LOSS_SELF_CONFLICT, 0.9),)
    assert fb.losses["JL"] == (LossReport("rival", w, LOSS_OUTSCORED, 0.9),)

    # a window clearing empty reports window_empty at cutoff 0
    rr_empty = RoundResult(
        windows=(w,),
        results=(ClearingResult(window=w, selected=(), scores=(),
                                total_score=0.0, n_bids=1, rejected=(rival,)),),
        selected=(), scores=(), total_score=0.0, n_bids=1)
    fb2 = build_feedback(0.0, [w], [A("JL")], [[[rival]]], rr_empty)
    assert fb2.losses["JL"] == (LossReport("rival", w, LOSS_WINDOW_EMPTY, 0.0),)


def test_adaptation_bumps_epoch_stateless_does_not():
    def one_round(strategy):
        sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)], Policy())
        for i in range(3):
            sched.add_job(_agent(f"J{i}", strategy=strategy), 0.0)
        before = sched._epoch
        rr = sched.run_round(1.0)
        assert rr is not None and rr.selected
        return sched._epoch - before

    # stateless greedy: exactly the commit bump (pre-negotiation behavior)
    assert one_round(None) == 1
    # adaptive agents observe their own alternatives losing + cutoffs: the
    # feedback adaptation adds its own invalidation (same single bump —
    # selected and adapted share one epoch increment)
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)], Policy())
    agents = [_agent(f"J{i}", strategy=AdaptiveBidder()) for i in range(3)]
    for a in agents:
        sched.add_job(a, 0.0)
    rr = sched.run_round(1.0)
    assert rr is not None
    # at least one adaptive agent learned a cutoff from the feedback
    assert any(a.strategy_state["cutoff"] for a in agents)


def test_mixed_strategy_pipelined_byte_identical_to_serial():
    """The acceptance property for the feedback channel: speculative rounds
    stay provably serial-equivalent even when strategies adapt from
    feedback (epoch invalidation), across all three shipped backends."""

    def run(pipeline):
        rng = np.random.default_rng(5)
        policy = Policy(window=WindowPolicy(horizon=40.0))
        sched = JasdaScheduler(
            [SliceSpec("s0", 8 * GB, n_chips=1),
             SliceSpec("s1", 6 * GB, n_chips=1)], policy)
        agents = []
        for i in range(4):
            mem = (1.5 + 2.0 * rng.uniform()) * GB
            fmp = fmp_standard(0.5 * GB, mem, 0.1 * GB, rel_sigma=0.03)
            for tag, strat in (("A", AdaptiveBidder()),
                               ("G", GreedyChunking()),
                               ("C", ConservativeSafety())):
                spec = JobSpec(job_id=f"J{tag}{i}", arrival_time=0.0,
                               total_work=30.0, fmp=fmp)
                agents.append(JobAgent(spec, AgentConfig(
                    misreport=1.4, strategy=strat)))
        simulate(sched, agents, SimConfig(t_end=200.0, seed=2,
                                          pipeline=pipeline))
        return [(c.variant_id, c.t_start, round(c.score, 12), c.status)
                for c in sched.commit_log]

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# AdaptiveBidder
# ---------------------------------------------------------------------------

def test_adaptive_equals_greedy_when_uncontended():
    # a lone bidder never sees an outscored loss: awards plus self-conflict
    # alternative losses leave the chunk scale at 1.0 (after the recovery
    # clamp) and its bids stay byte-identical to GreedyChunking's
    ga, aa = _agent("J0"), _agent("J0", strategy=AdaptiveBidder())
    w = Window("s0", 8 * GB, 0.0, 30.0)
    sweep = RoundFeedback(
        t=0.0, windows=(w,), cutoffs={w.key: 0.6},
        awards={"J0": (Award("win", w, 0.8),)},
        losses={"J0": (LossReport("alt", w, LOSS_SELF_CONFLICT, 0.6),)},
        reliability={"J0": 1.0}, calibration_error={"J0": 0.0},
        calibration_bias={"J0": 0.0})
    for _ in range(3):
        aa.observe_feedback(sweep)
    assert aa.strategy_state["scale"] == 1.0
    assert aa.strategy_state["shade"] == 1.0
    got = aa.generate_variants(w, 0.0)
    want = ga.generate_variants(w, 0.0)
    assert [_variant_sig(v) for v in got] == [_variant_sig(v) for v in want]


def test_adaptive_shrinks_chunks_under_contention_and_recovers():
    agent = _agent("J0", strategy=AdaptiveBidder())
    strat, state = agent.strategy, agent.strategy_state
    w = Window("s0", 8 * GB, 0.0, 30.0)
    outscored = RoundFeedback(
        t=0.0, windows=(w,), cutoffs={w.key: 0.9},
        awards={}, losses={"J0": (LossReport("x", w, LOSS_OUTSCORED, 0.9),)},
        reliability={"J0": 1.0}, calibration_error={"J0": 0.0},
        calibration_bias={"J0": 0.0})
    assert agent.observe_feedback(outscored)
    assert state["scale"] == pytest.approx(strat.shrink)
    agent.observe_feedback(outscored)
    assert state["scale"] == pytest.approx(strat.shrink ** 2)
    # shrunk bids: deeper chains of smaller chunks, no head alternatives
    small = agent.generate_variants(w, 0.0)
    starts = [v.t_start for v in small]
    assert len(set(starts)) == len(starts), "no overlapping head alternatives"
    assert len(starts) >= 2, "chunk-scale shrink must buy chain depth"
    # a clean sweep grows the scale back
    sweep = RoundFeedback(
        t=1.0, windows=(w,), cutoffs={w.key: 0.5},
        awards={"J0": (Award("y", w, 0.8),)}, losses={},
        reliability={"J0": 1.0}, calibration_error={"J0": 0.0},
        calibration_bias={"J0": 0.0})
    before = state["scale"]
    assert agent.observe_feedback(sweep)
    assert state["scale"] == pytest.approx(min(1.0, before * strat.grow))


def test_adaptive_window_targeting_skips_hopeless_slices():
    strat = AdaptiveBidder(skip_after=2)
    agent = _agent("J0", strategy=strat)
    state = agent.strategy_state
    whot = Window("hot", 8 * GB, 0.0, 30.0)
    wok = Window("ok", 8 * GB, 0.0, 30.0)
    fb = RoundFeedback(
        t=0.0, windows=(whot,), cutoffs={whot.key: 0.95},
        awards={}, losses={"J0": (LossReport("x", whot, LOSS_OUTSCORED, 0.95),)},
        reliability={"J0": 1.0}, calibration_error={"J0": 0.0},
        calibration_bias={"J0": 0.0})
    win_ok = RoundFeedback(
        t=0.0, windows=(wok,), cutoffs={wok.key: 0.4},
        awards={"J0": (Award("w", wok, 0.4),)}, losses={},
        reliability={"J0": 1.0}, calibration_error={"J0": 0.0},
        calibration_bias={"J0": 0.0})
    agent.observe_feedback(win_ok)  # establish the agent's own score level
    agent.observe_feedback(fb)
    agent.observe_feedback(fb)
    assert state["streak"]["hot"] == 2
    groups = agent.generate_variants_by_window([whot, wok], 0.0)
    assert groups[0] == [], "hopeless slice must be skipped"
    assert groups[1], "winnable slice must still be bid"


def test_adaptive_shading_follows_calibration_bias():
    agent = _agent("J0", misreport=1.6, strategy=AdaptiveBidder())
    state = agent.strategy_state
    w = Window("s0", 8 * GB, 50.0, 30.0)
    over = RoundFeedback(
        t=0.0, windows=(w,), cutoffs={}, awards={}, losses={},
        reliability={"J0": 0.6}, calibration_error={"J0": 0.2},
        calibration_bias={"J0": 0.2})
    assert agent.observe_feedback(over)
    assert state["shade"] < 1.0
    shade1 = state["shade"]
    # shaded declarations sit strictly below the unshaded ones
    greedy_twin = _agent("J0", misreport=1.6)
    shaded = agent.generate_variants(w, 0.0)
    plain = greedy_twin.generate_variants(w, 0.0)
    assert shaded and plain
    assert shaded[0].local_utility < plain[0].local_utility
    # under-declaration (negative bias) relaxes the shade back toward 1
    under = RoundFeedback(
        t=1.0, windows=(w,), cutoffs={}, awards={}, losses={},
        reliability={"J0": 0.9}, calibration_error={"J0": 0.05},
        calibration_bias={"J0": -0.2})
    agent.observe_feedback(under)
    assert 1.0 >= state["shade"] > shade1
    # honest agents (|bias| inside the deadband) never shade
    honest = _agent("J1", strategy=AdaptiveBidder())
    neutral = RoundFeedback(
        t=0.0, windows=(w,), cutoffs={}, awards={}, losses={},
        reliability={"J1": 1.0}, calibration_error={"J1": 0.01},
        calibration_bias={"J1": 0.01})
    honest.observe_feedback(neutral)
    assert honest.strategy_state["shade"] == 1.0


def test_adaptive_outbids_greedy_on_contended_cluster():
    """The tentpole's market claim: paired identical jobs, half adaptive and
    half greedy, on a scarce 2-slice cluster — the adaptive half strictly
    clears more total score (the adaptive_bidding benchmark gates this)."""
    rng = np.random.default_rng(5)
    policy = Policy(window=WindowPolicy(horizon=40.0))
    sched = JasdaScheduler([SliceSpec("s0", 8 * GB, n_chips=1),
                            SliceSpec("s1", 6 * GB, n_chips=1)], policy)
    agents = []
    for i in range(5):
        mem = (1.5 + 2.0 * rng.uniform()) * GB
        fmp = fmp_standard(0.5 * GB, mem, 0.1 * GB, rel_sigma=0.03)
        for tag, strat in (("A", AdaptiveBidder()), ("G", GreedyChunking())):
            spec = JobSpec(job_id=f"J{tag}{i}", arrival_time=0.0,
                           total_work=40.0, fmp=fmp)
            agents.append(JobAgent(spec, AgentConfig(strategy=strat)))
    res = simulate(sched, agents, SimConfig(t_end=300.0, seed=2))
    stats = res.strategy_stats
    assert stats["adaptive"]["score_won"] > stats["greedy_chunking"]["score_won"]
    win_rate = lambda r: r["n_wins"] / max(r["n_bids"], 1)
    assert win_rate(stats["adaptive"]) > win_rate(stats["greedy_chunking"])
    assert res.iterations >= 20


# ---------------------------------------------------------------------------
# ConservativeSafety
# ---------------------------------------------------------------------------

def test_conservative_safety_tightens_theta_with_reliability():
    cap = 3.1 * GB
    fmp = fmp_standard(1 * GB, 3 * GB, 0.05 * GB, rel_sigma=0.01)
    mu, sigma = fmp.grid(32)
    p = prob_exceed_grid(mu, sigma, cap)
    assert 1e-6 < p < 0.5, f"test FMP mis-calibrated: p_exceed={p}"
    theta = min(1.0, p * 2)  # safe at full trust, unsafe once ρ < ~0.5
    spec = JobSpec(job_id="J0", arrival_time=0.0, total_work=50.0, fmp=fmp)
    agent = JobAgent(spec, AgentConfig(theta=theta,
                                       strategy=ConservativeSafety()))
    w = Window("s0", cap, 0.0, 30.0)

    # full trust: byte-identical to greedy (θ_eff == θ), and bids carry θ
    bids = agent.generate_variants(w, 0.0)
    twin = JobAgent(spec, AgentConfig(theta=theta))
    assert [_variant_sig(v) for v in bids] == \
        [_variant_sig(v) for v in twin.generate_variants(w, 0.0)]
    assert all(v.theta == theta for v in bids)

    # reliability collapse: θ_eff = θ·ρ < p_exceed → the marginal window is
    # refused outright (agent-side probabilistic safety policy)
    low = RoundFeedback(
        t=1.0, windows=(w,), cutoffs={}, awards={}, losses={},
        reliability={"J0": 0.2}, calibration_error={"J0": 0.5},
        calibration_bias={"J0": 0.4})
    assert agent.observe_feedback(low)
    assert agent.generate_variants(w, 1.0) == []
    # an ample window is still bid, at the tightened θ_eff
    roomy = Window("s1", 10 * GB, 0.0, 30.0)
    safe_bids = agent.generate_variants(roomy, 1.0)
    assert safe_bids
    assert all(v.theta == pytest.approx(theta * 0.2) for v in safe_bids)

    # recovery: trust back → bids on the marginal window return
    high = RoundFeedback(
        t=2.0, windows=(w,), cutoffs={}, awards={}, losses={},
        reliability={"J0": 1.0}, calibration_error={"J0": 0.0},
        calibration_bias={"J0": 0.0})
    assert agent.observe_feedback(high)
    assert agent.generate_variants(w, 2.0)
    # unchanged reliability is a no-op (no epoch churn)
    assert not agent.observe_feedback(high)


# ---------------------------------------------------------------------------
# Calibrator snapshot/restore (satellite)
# ---------------------------------------------------------------------------

def _verify_some(cal, rng, jobs=("J0", "J1"), n=6):
    for i in range(n):
        for j in jobs:
            v = Variant(job_id=j, slice_id="s0", t_start=float(i), duration=1.0,
                        fmp=None, local_utility=0.5,
                        declared_features={"jct": 0.9, "progress": 0.7},
                        payload={"work": 1.0}, variant_id=f"{j}/{i}")
            cal.verify(v, {"jct": float(rng.uniform(0.3, 1.0)),
                           "progress": float(rng.uniform(0.3, 1.0))})


def test_calibrator_snapshot_restore_round_trip():
    cfg = CalibrationConfig(error_window=4)
    cal = Calibrator(cfg)
    _verify_some(cal, np.random.default_rng(0))
    snap = cal.snapshot()
    assert snap["J0"]["errors"], "snapshot must carry the error history"

    restored = Calibrator(cfg).restore(snap)
    assert restored.snapshot() == snap
    # restored state calibrates identically...
    v = Variant(job_id="J0", slice_id="s0", t_start=0.0, duration=1.0,
                fmp=None, local_utility=0.5, declared_features={},
                payload={}, variant_id="probe")
    assert restored.calibrate(v, 0.8) == pytest.approx(cal.calibrate(v, 0.8))
    # ...and keeps evolving identically (the windowed E[ε] → ρ update needs
    # the restored error history)
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    _verify_some(cal, rng_a, n=3)
    _verify_some(restored, rng_b, n=3)
    assert restored.snapshot() == cal.snapshot()
    # pre-bias snapshots restore with neutral defaults
    legacy = {"J9": {"rho": 0.7, "hist_avg": 0.6}}
    old = Calibrator(cfg).restore(legacy)
    assert old.rho("J9") == 0.7 and old.state("J9").bias == 0.0


def test_simulator_checkpoint_preserves_calibration():
    from repro.core import make_workload

    def sched():
        return JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)], Policy())

    s1 = sched()
    r1 = simulate(s1, make_workload(6, seed=3, arrival_rate=1.0,
                                    misreport_fraction=0.5),
                  SimConfig(t_end=200.0, seed=1))
    assert r1.calibration and any(
        row["n_verified"] > 0 for row in r1.calibration.values())
    # a fresh run restores the checkpointed trust state and starts from it
    s2 = sched()
    s2.calibrator.restore(r1.calibration)
    assert s2.calibrator.snapshot() == r1.calibration
    for jid, row in r1.calibration.items():
        assert s2.calibrator.rho(jid) == pytest.approx(row["rho"])


def test_calibrator_tracks_signed_bias():
    cal = Calibrator(CalibrationConfig(hist_half_life=1.0))
    over = Variant(job_id="JO", slice_id="s0", t_start=0.0, duration=1.0,
                   fmp=None, local_utility=0.9,
                   declared_features={"jct": 0.9}, payload={}, variant_id="o")
    under = Variant(job_id="JU", slice_id="s0", t_start=0.0, duration=1.0,
                    fmp=None, local_utility=0.2,
                    declared_features={"jct": 0.2}, payload={}, variant_id="u")
    for _ in range(6):
        cal.verify(over, {"jct": 0.5})
        cal.verify(under, {"jct": 0.5})
    assert cal.state("JO").bias > 0.1
    assert cal.state("JU").bias < -0.1
    assert abs(cal.state("JO").bias) <= cal.state("JO").mean_error() + 1e-9
