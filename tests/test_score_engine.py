"""Zero-recompile scoring engine + round pipelining.

Covers the PR 2 contracts:
  * per-variant capacity/θ parity across pallas-interpret / jnp ref / host
    numpy (incl. mixed-capacity pools and pack_grids=True safety rechecks)
  * scalar (λ, capacity, θ) compat overload == per-variant broadcast
  * M-bucketed dispatch: zero retraces across drifting pool sizes / λ /
    heterogeneous capacities
  * pipelining equivalence: run_rounds_pipelined and SimConfig(pipeline=True)
    selections byte-identical to serial rounds (incl. failure injection and
    the speculation filter/discard paths)
  * bounded bookkeeping: per-scheduler FMP grid cache, commitment pruning,
    commit_log statuses, max_log_rows caps
"""
import numpy as np
import pytest

from repro.core import (JasdaScheduler, ScoringPolicy, SimConfig, SliceSpec,
                        Window, clear_round, make_workload,
                        pipelined_clear_rounds, simulate)
from repro.core.jobs import AgentConfig, JobAgent
from repro.core.pipeline import RoundPipeline
from repro.core.scheduler import SchedulerConfig
from repro.core.scoring import score_round
from repro.core.trp import fmp_standard, prob_exceed_grid
from repro.core.types import JobSpec, Variant
from repro.kernels.jasda_score.ops import (FMPGridCache, bucket_m,
                                           pool_to_arrays_round,
                                           score_variants,
                                           score_variants_numpy, trace_counts)

GB = 1 << 30


def _score_args(m, t, seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        feat_job=rng.uniform(0, 1, (m, 3)).astype(np.float32),
        feat_sys=rng.uniform(0, 1, (m, 3)).astype(np.float32),
        alphas=np.array([.5, .3, .2], np.float32),
        betas=np.array([.4, .2, .2], np.float32),
        mu=rng.uniform(5, 21, (m, t)).astype(np.float32),
        sigma=rng.uniform(0.01, .8, (m, t)).astype(np.float32),
    )


# ---------------------------------------------------------------------------
# kernel contract: per-variant runtime (λ, capacity, θ)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,t", [(64, 16), (300, 32)])
def test_per_variant_capacity_parity_three_backends(m, t):
    rng = np.random.default_rng(m)
    args = _score_args(m, t, seed=m)
    caps = rng.choice([12.0, 16.0, 20.0], m)
    ths = rng.choice([0.02, 0.05, 0.2], m)
    lam = 0.6

    s_p, e_p, _ = score_variants(**args, lam=lam, capacity=caps, theta=ths,
                                 impl="pallas")
    s_r, e_r, p_r = score_variants(**args, lam=lam, capacity=caps, theta=ths,
                                   impl="ref")
    s_n, e_n, p_n = score_variants_numpy(**args, lam=lam, capacity=caps,
                                         theta=ths)
    # pallas and jnp ref run identical f32 math
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), atol=3e-5)
    np.testing.assert_array_equal(np.asarray(e_p), np.asarray(e_r))
    # numpy runs float64: compare away from the θ decision boundary, where
    # f32-vs-f64 rounding of p_exceed can legitimately flip eligibility
    off_boundary = np.abs(p_n - ths) > 1e-4
    assert off_boundary.mean() > 0.9
    np.testing.assert_array_equal(np.asarray(e_r)[off_boundary],
                                  e_n[off_boundary])
    np.testing.assert_allclose(np.asarray(s_r)[off_boundary],
                               s_n[off_boundary], atol=3e-5)


def test_per_variant_safety_matches_host_trp_evaluator():
    # each row checked against ITS OWN capacity must equal the host
    # prob_exceed_grid at that capacity
    rng = np.random.default_rng(7)
    m, t = 24, 48
    args = _score_args(m, t, seed=7)
    caps = rng.choice([14.0, 18.0, 22.0], m)
    _, _, p = score_variants(**args, lam=0.5, capacity=caps, theta=0.05,
                             impl="ref")
    mu64 = np.asarray(args["mu"], np.float64)
    sg64 = np.asarray(args["sigma"], np.float64)
    for i in range(m):
        p_host = prob_exceed_grid(mu64[i], sg64[i], float(caps[i]))
        assert float(p[i]) == pytest.approx(p_host, abs=1e-4)


def test_scalar_overload_equals_constant_vector():
    m, t = 100, 16
    args = _score_args(m, t, seed=3)
    for impl in ("pallas", "ref"):
        s_scalar, e_scalar, _ = score_variants(
            **args, lam=0.4, capacity=18.0, theta=0.05, impl=impl)
        s_vec, e_vec, _ = score_variants(
            **args, lam=np.full(m, 0.4), capacity=np.full(m, 18.0),
            theta=np.full(m, 0.05), impl=impl)
        np.testing.assert_array_equal(np.asarray(s_scalar), np.asarray(s_vec))
        np.testing.assert_array_equal(np.asarray(e_scalar), np.asarray(e_vec))


def test_bucketed_dispatch_zero_retraces():
    # warm both buckets, then drifting (M, λ, capacity, θ) must never retrace
    t = 16
    for m_warm in (256, 512):
        args = _score_args(m_warm, t, seed=m_warm)
        score_variants(**args, lam=0.5, capacity=10.0, theta=0.1, impl="ref")
    base = trace_counts()
    rng = np.random.default_rng(1)
    for i, m in enumerate((180, 300, 256, 511, 400, 222, 512, 333)):
        args = _score_args(m, t, seed=i)
        caps = rng.choice([8.0, 12.0, 20.0], m)
        score_variants(**args, lam=float(rng.uniform(0, 1)), capacity=caps,
                       theta=float(rng.uniform(0.01, 0.5)), impl="ref")
    assert trace_counts() == base, "runtime-arg dispatch retraced"
    assert bucket_m(180) == 256 and bucket_m(300) == 512


# ---------------------------------------------------------------------------
# round packing: per-variant capacities + mixed-capacity safety recheck
# ---------------------------------------------------------------------------

def _mk_variant(job, sid, t0, dur, fmp, h=0.5, vid=None):
    return Variant(job_id=job, slice_id=sid, t_start=t0, duration=dur,
                   fmp=fmp, local_utility=h, declared_features={},
                   payload={"work": dur}, variant_id=vid or f"{job}/{sid}/{t0}")


def test_pool_to_arrays_round_gathers_window_capacity_per_bid():
    small = Window("sA", 4 * GB, 0.0, 50.0)
    big = Window("sB", 16 * GB, 0.0, 50.0)
    fmp = fmp_standard(1 * GB, 2 * GB, 0.2 * GB)
    pool = [_mk_variant("J0", "sA", 0.0, 10.0, fmp),
            _mk_variant("J0", "sB", 0.0, 10.0, fmp),
            _mk_variant("J1", "sB", 20.0, 10.0, fmp)]
    packed = pool_to_arrays_round(pool, [small, big], [0, 1, 1],
                                  ScoringPolicy(), theta=0.07)
    np.testing.assert_array_equal(packed.caps, [4 * GB, 16 * GB, 16 * GB])
    np.testing.assert_array_equal(packed.thetas, [0.07] * 3)


@pytest.mark.parametrize("impl", ["numpy", "ref", "pallas"])
def test_mixed_capacity_recheck_zeroes_unsafe_bids(impl):
    # one FMP is unsafe on the small slice but safe on the big one: with the
    # in-dispatch recheck its small-window bid must score 0 (ineligible)
    # while its big-window bid survives — per-variant capacities at work
    small = Window("sA", 3 * GB, 0.0, 50.0)
    big = Window("sB", 16 * GB, 0.0, 50.0)
    risky = fmp_standard(1 * GB, 2.9 * GB, 0.5 * GB, rel_sigma=0.2)
    tame = fmp_standard(0.5 * GB, 1 * GB, 0.1 * GB)
    assert prob_exceed_grid(*risky.grid(32), 3 * GB) > 0.05
    assert prob_exceed_grid(*risky.grid(32), 16 * GB) <= 0.05
    pool = [_mk_variant("J0", "sA", 0.0, 10.0, risky, h=0.9, vid="risky-small"),
            _mk_variant("J0", "sB", 0.0, 10.0, risky, h=0.9, vid="risky-big"),
            _mk_variant("J1", "sA", 20.0, 10.0, tame, h=0.5, vid="tame-small")]
    scores = score_round(pool, [small, big], [0, 1, 0], ScoringPolicy(),
                         impl=impl, recheck_theta=0.05)
    assert scores[0] == 0.0, "unsafe bid must be zeroed on its own window"
    assert scores[1] > 0.0 and scores[2] > 0.0
    # without the recheck the unsafe bid would have scored normally
    no_recheck = score_round(pool, [small, big], [0, 1, 0], ScoringPolicy(),
                             impl=impl)
    assert no_recheck[0] > 0.0


def test_recheck_parity_across_backends():
    rng = np.random.default_rng(5)
    windows = [Window(f"s{k}", (3 + 5 * k) * GB, 0.0, 100.0) for k in range(3)]
    fmps = [fmp_standard(0.5 * GB, (1 + 2 * rng.uniform()) * GB,
                         0.4 * GB, rel_sigma=0.15) for _ in range(6)]
    pool, win_idx = [], []
    for i in range(60):
        k = int(rng.integers(0, 3))
        t0 = rng.uniform(0, 50)
        pool.append(_mk_variant(f"J{i % 6}", f"s{k}", t0, rng.uniform(2, 40),
                                fmps[i % 6], h=float(rng.uniform(0.2, 0.9)),
                                vid=f"v{i}"))
        win_idx.append(k)
    got = {impl: score_round(pool, windows, win_idx, ScoringPolicy(),
                             impl=impl, recheck_theta=0.05)
           for impl in ("numpy", "ref", "pallas")}
    np.testing.assert_allclose(got["numpy"], got["ref"], atol=3e-5)
    np.testing.assert_allclose(got["ref"], got["pallas"], atol=3e-5)


# ---------------------------------------------------------------------------
# FMP grid cache: per-scheduler scope + bound
# ---------------------------------------------------------------------------

def test_grid_cache_bounded_and_scoped():
    cache = FMPGridCache(maxsize=4)
    fmps = [fmp_standard(1 * GB, (1 + i) * GB, 0.1 * GB) for i in range(6)]
    for f in fmps:
        cache.grid(f, 32)
    assert len(cache) == 4  # LRU-bounded
    assert cache.misses == 6
    mu, sg, mean = cache.grid(fmps[-1], 32)
    assert cache.hits == 1
    np.testing.assert_allclose(mean, float(np.mean(fmps[-1].grid(32)[0])))
    # schedulers own independent caches (no process-global state)
    s1 = JasdaScheduler([SliceSpec("s0", 8 * GB)])
    s2 = JasdaScheduler([SliceSpec("s0", 8 * GB)])
    assert s1._grid_cache is not s2._grid_cache
    assert s1._grid_cache.maxsize == SchedulerConfig().grid_cache_size


# ---------------------------------------------------------------------------
# pipelining equivalence
# ---------------------------------------------------------------------------

def _mk_sched(n_jobs=18, score_impl="ref", **cfg_kw):
    sched = JasdaScheduler(
        [SliceSpec("s20", 20 * GB, n_chips=4),
         SliceSpec("s10", 10 * GB, n_chips=2),
         SliceSpec("s5", 5 * GB)],
        SchedulerConfig(score_impl=score_impl, **cfg_kw))
    for a in make_workload(n_jobs, seed=3, arrival_rate=2.0):
        sched.add_job(a, 0.0)
    return sched


def _round_sig(results):
    return [None if r is None else tuple(v.variant_id for v in r.selected)
            for r in results]


def test_run_rounds_pipelined_byte_identical_to_serial():
    times = [float(t) for t in range(30)]
    serial, piped = _mk_sched(), _mk_sched()
    rs = [serial.run_round(t) for t in times]
    rp = piped.run_rounds_pipelined(times)
    assert _round_sig(rs) == _round_sig(rp)
    assert ([(r.variant_id, r.status, r.score) for r in serial.commit_log]
            == [(r.variant_id, r.status, r.score) for r in piped.commit_log])
    assert ([(l.t, l.n_bidders, l.n_bids, l.n_selected, l.n_windows)
             for l in serial.log]
            == [(l.t, l.n_bidders, l.n_bids, l.n_selected, l.n_windows)
                for l in piped.log])
    assert ({j: (a.n_bids, a.n_wins) for j, a in serial.agents.items()}
            == {j: (a.n_bids, a.n_wins) for j, a in piped.agents.items()})


def test_simulate_pipelined_equals_serial():
    def run(pipeline):
        sched = JasdaScheduler(
            [SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10", 10 * GB, n_chips=2)],
            SchedulerConfig(score_impl="ref"))
        agents = make_workload(20, seed=7, arrival_rate=0.5)
        res = simulate(sched, agents,
                       SimConfig(t_end=1500.0, seed=4, pipeline=pipeline))
        return res, sched

    r1, s1 = run(False)
    r2, s2 = run(True)
    assert r1.jct_per_job == r2.jct_per_job
    assert r1.n_committed == r2.n_committed
    assert r1.total_score == pytest.approx(r2.total_score, abs=1e-9)
    assert r1.utilization == r2.utilization and r1.makespan == r2.makespan
    assert ({j: (a.n_bids, a.n_wins) for j, a in s1.agents.items()}
            == {j: (a.n_bids, a.n_wins) for j, a in s2.agents.items()})


def test_simulate_pipelined_equals_serial_under_failures():
    def run(pipeline):
        sched = JasdaScheduler(
            [SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10", 10 * GB, n_chips=2)],
            SchedulerConfig(score_impl="ref"))
        agents = make_workload(14, seed=9, arrival_rate=0.4)
        return simulate(sched, agents,
                        SimConfig(t_end=2500.0, seed=5, failure_rate=0.004,
                                  repair_time=40.0, pipeline=pipeline))

    r1, r2 = run(False), run(True)
    assert r1.jct_per_job == r2.jct_per_job
    assert r1.n_committed == r2.n_committed


def test_pipeline_filter_path_matches_fresh_preparation():
    # the settling round killed one speculatively-announced window (dead
    # window, epoch unchanged): validation must FILTER the speculation to
    # exactly what a fresh serial preparation would produce
    def mk():
        return _mk_sched(n_jobs=10)

    spec_s, fresh_s = mk(), mk()
    pipe = RoundPipeline(spec_s)
    spec = spec_s._prepare_round(2.0, speculative=True)
    assert len(spec.windows) >= 2
    dead = spec.windows[0]
    for s in (spec_s, fresh_s):
        s._dead_windows.add(dead.slice_id, dead.t_min, expiry=100.0)
    pipe._spec = spec
    prep = pipe._take_validated(2.0)
    assert prep is not None and pipe.stats["spec_filtered"] == 1
    fresh = fresh_s._prepare_round(2.0)
    assert [(w.slice_id, w.t_min) for w in prep.windows] == \
        [(w.slice_id, w.t_min) for w in fresh.windows]
    assert [v.variant_id for v in prep.pool] == \
        [v.variant_id for v in fresh.pool]
    assert ({j: a.n_bids for j, a in spec_s.agents.items()}
            == {j: a.n_bids for j, a in fresh_s.agents.items()})


def test_pipeline_discard_restores_bid_stats():
    sched = _mk_sched(n_jobs=10)
    before = {j: a.n_bids for j, a in sched.agents.items()}
    pipe = RoundPipeline(sched)
    spec = sched._prepare_round(2.0, speculative=True)
    assert any(a.n_bids != before[j] for j, a in sched.agents.items())
    pipe._spec = spec
    sched._epoch += 1  # any state mutation invalidates the speculation
    assert pipe._take_validated(2.0) is None
    assert {j: a.n_bids for j, a in sched.agents.items()} == before
    assert pipe.stats["spec_discarded"] == 1


def test_pipelined_clear_rounds_identical_selections():
    rng = np.random.default_rng(2)
    windows = [Window(f"s{k}", (6 + 2 * k) * GB, 0.0, 100.0) for k in range(4)]
    fmps = [fmp_standard(0.5 * GB, 1.5 * GB, 0.1 * GB) for _ in range(8)]
    rounds = []
    for _ in range(5):
        pool = []
        for i in range(50):
            k = int(rng.integers(0, 4))
            t0 = rng.uniform(0, 60)
            pool.append(_mk_variant(f"J{i % 8}", f"s{k}", t0,
                                    rng.uniform(2, 30), fmps[i % 8],
                                    h=float(rng.uniform(0.1, 0.9)),
                                    vid=f"v{i}"))
        rounds.append((windows, pool))
    policy = ScoringPolicy()
    serial = [clear_round(w, p, policy, score_impl="ref") for w, p in rounds]
    piped = pipelined_clear_rounds(rounds, policy, score_impl="ref")
    assert ([_round_sig([r])[0] for r in serial]
            == [_round_sig([r])[0] for r in piped])


# ---------------------------------------------------------------------------
# bounded bookkeeping: commitment pruning + log caps
# ---------------------------------------------------------------------------

def test_commitments_pruned_on_complete_and_fail():
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)])
    agents = make_workload(10, seed=11, arrival_rate=2.0)
    res = simulate(sched, agents, SimConfig(t_end=2000.0, seed=6))
    assert res.n_finished == 10
    # outstanding set drains as work completes; totals survive in counters
    assert len(sched.commitments) < sched.n_committed_total
    assert res.n_committed == sched.n_committed_total
    assert res.total_score == pytest.approx(sched.committed_score_total)
    statuses = {r.status for r in sched.commit_log}
    assert "completed" in statuses
    assert len(sched.commit_log) == sched.n_committed_total
    assert len(sched._commit_index) == len(sched.commitments)


def test_commit_log_records_failures_and_losses():
    sched = JasdaScheduler([SliceSpec("s0", 10 * GB, n_chips=2),
                            SliceSpec("s1", 10 * GB, n_chips=2)])
    agents = make_workload(8, seed=13, arrival_rate=1.0)
    simulate(sched, agents,
             SimConfig(t_end=2500.0, seed=3, failure_rate=0.01,
                       repair_time=30.0))
    statuses = {r.status for r in sched.commit_log}
    assert statuses & {"failed", "lost"}, "failure injection must be audited"
    # pruned commitments never linger in the outstanding set
    active_ids = {c.variant.variant_id for c in sched.commitments}
    for r in sched.commit_log:
        if r.status in ("failed", "lost", "completed"):
            assert r.variant_id not in active_ids or r.status == "completed"


def test_max_log_rows_caps_audit_trails():
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)],
                           SchedulerConfig(max_log_rows=25))
    agents = make_workload(12, seed=4, arrival_rate=2.0)
    simulate(sched, agents, SimConfig(t_end=3000.0, seed=2))
    assert len(sched.log) <= 25
    assert len(sched.commit_log) <= 25
    # totals keep counting past the cap
    assert sched.n_committed_total >= len(sched.commit_log)


def test_uncapped_log_by_default():
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)])
    agents = make_workload(6, seed=5, arrival_rate=2.0)
    simulate(sched, agents, SimConfig(t_end=800.0, seed=2))
    assert len(sched.log) > 25  # one row per tick, unbounded by default
