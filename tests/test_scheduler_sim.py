"""Scheduler invariants + simulator studies (incl. failures, elasticity)."""
import numpy as np
import pytest

from repro.core import (AgentConfig, JasdaScheduler, JobAgent, JobSpec,
                        SchedulerConfig, SimConfig, SliceSpec, simulate,
                        make_workload)
from repro.core.baselines import (AuctionScheduler, BackfillScheduler,
                                  BestFitScheduler, FifoScheduler)
from repro.core.windows import SliceTimeline, WindowPolicy, announce_window

GB = 1 << 30


def _slices(n=3, cap_gb=20, chips=4):
    return [SliceSpec(f"s{k}", cap_gb * GB, n_chips=chips) for k in range(n)]


# ---------------------------------------------------------------------------
# timeline / window machinery
# ---------------------------------------------------------------------------

def test_timeline_commit_release_gaps():
    tl = SliceTimeline(SliceSpec("s", 1 * GB))
    tl.commit(5, 10)
    tl.commit(12, 15)
    gaps = tl.gaps(0, 20)
    assert gaps == [(0, 5), (10, 12), (15, 20)]
    tl.release(6, 9)  # carve out of a committed block
    gaps = tl.gaps(0, 20)
    assert (6, 9) in gaps
    with pytest.raises(ValueError):
        tl.commit(4, 7)  # overlaps [5,6)


def test_window_policies_pick_valid_gap():
    slices = {s.slice_id: SliceTimeline(s) for s in _slices(2)}
    slices["s0"].commit(0, 50)
    for kind in ("earliest", "largest", "best_fit", "slack"):
        w = announce_window(slices, 0.0, WindowPolicy(kind=kind, horizon=100))
        assert w is not None
        assert w.duration >= 1.0


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def test_no_overlapping_commitments_per_slice():
    sched = JasdaScheduler(_slices())
    agents = make_workload(40, seed=3, arrival_rate=0.5)
    simulate(sched, agents, SimConfig(t_end=1500.0, seed=1))
    # the timeline itself raises on overlap; double-check commitments per job
    # over the full audit trail (executed + outstanding; failed/lost work may
    # legitimately be re-committed elsewhere, so those statuses are excluded)
    per_job = {}
    for r in sched.commit_log:
        if r.status in ("active", "completed"):
            per_job.setdefault(r.job_id, []).append(r.interval)
    for job, ivs in per_job.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert s2 >= e1 - 1e-9, f"job {job} double-booked"


def test_work_conservation():
    sched = JasdaScheduler(_slices())
    agents = make_workload(30, seed=5, arrival_rate=0.5)
    res = simulate(sched, agents, SimConfig(t_end=3000.0, seed=2))
    for a in sched.agents.values():
        assert a.work_done <= a.spec.total_work + 1e-6
    assert res.n_finished == 30  # ample horizon → everything completes


def test_capacity_safety_bound_holds():
    sched = JasdaScheduler(_slices())
    agents = make_workload(50, seed=7, arrival_rate=1.0)
    res = simulate(sched, agents, SimConfig(t_end=3000.0, seed=3))
    n_chunks = res.n_committed
    # θ = 0.05 per variant is an upper bound; observed rate must respect it
    assert res.capacity_violations <= max(3, 0.05 * n_chunks)


def test_failure_recovery_and_elasticity():
    sched = JasdaScheduler(_slices())
    agents = make_workload(30, seed=1, arrival_rate=0.5)
    res = simulate(sched, agents,
                   SimConfig(t_end=4000.0, seed=2, failure_rate=0.004,
                             repair_time=40.0))
    assert res.n_finished == 30, "atomization must survive slice failures"


def test_straggler_mitigation_via_calibration():
    # one slice at 40% speed: observed durations inflate there, jobs placed
    # on it accumulate ε, and their declared-vs-observed gap shows up in ρ
    slices = _slices(2)
    slow = SliceSpec("slow", 20 * GB, n_chips=4, speed=0.4)
    sched = JasdaScheduler(slices + [slow])
    agents = make_workload(30, seed=2, arrival_rate=0.4)
    res = simulate(sched, agents, SimConfig(t_end=4000.0, seed=4))
    assert res.n_finished == 30  # stragglers slow things down but don't stall


# ---------------------------------------------------------------------------
# baselines behave
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [FifoScheduler, BackfillScheduler,
                                 BestFitScheduler, AuctionScheduler])
def test_baseline_completes_workload(cls):
    agents = make_workload(20, seed=4, arrival_rate=0.5)
    res = simulate(cls(_slices()), agents, SimConfig(t_end=3000.0, seed=2))
    assert res.n_finished == 20


def test_jasda_beats_fifo_under_heterogeneity():
    # MIG-like heterogeneous pool: FIFO head-of-line blocks on big-memory jobs
    slices = [SliceSpec("s20", 20 * GB, n_chips=4),
              SliceSpec("s10", 10 * GB, n_chips=2)] + \
             [SliceSpec(f"s5{i}", 5 * GB, n_chips=1) for i in range(4)]
    mk = lambda: make_workload(120, seed=1, arrival_rate=0.25,
                               mem_range_gb=(1.0, 14.0))
    r_j = simulate(JasdaScheduler(slices), mk(), SimConfig(t_end=6000.0, seed=2))
    r_f = simulate(FifoScheduler(slices), mk(), SimConfig(t_end=6000.0, seed=2))
    assert r_j.mean_jct < r_f.mean_jct
    assert r_j.utilization >= r_f.utilization * 0.9
