"""Device-resident settle (PR 5): batched multi-window WIS parity + fusion.

Pins the tentpole's correctness contract:

* the batched multi-window WIS op equals the per-window host ``wis_select``
  AND the O(2^M) brute-force oracle across padding/bucket boundaries, empty
  windows, all-masked lanes and touching half-open intervals (property
  tests use float32-exact interval/weight grids so the float32 device DP
  and the float64 host DP make bit-identical decisions);
* ``fixed_point_settle`` under every ``RoundSelector`` backend (host-batched
  "numpy", device "ref"/"pallas") is byte-identical to the per-window host
  loop, with and without work budgets, serial and pipelined;
* the fused score→clear dispatch (``wis_impl`` device backends consuming
  in-flight device scores) matches the host path, and the batched dispatch
  never retraces after its per-bucket warmup;
* the satellites: vectorized ``RoundFeedback`` assembly equals the object
  walk, and ``AgentConfig.n_start_offsets`` adds mutually-overlapping
  start alternatives while the default stays byte-identical.

Property tests run under hypothesis when available and fall back to seeded
random pools otherwise (hypothesis is not in the baked-in environment).
"""
import numpy as np
import pytest

from repro.core import (AgentConfig, JasdaScheduler, JobAgent, JobSpec,
                        Policy, ScoringPolicy, SimConfig, SliceSpec,
                        make_workload, simulate)
from repro.core.clearing import assign_bids, clear_round, settle_round
from repro.core.negotiation import build_feedback
from repro.core.negotiation.base import chunk_chain_bids
from repro.core.pipeline import pipelined_clear_rounds
from repro.core.policy import FairShare, GlobalAssignment, GreedyWIS
from repro.core.policy.base import _pool_members, fixed_point_settle
from repro.core.scheduler import SchedulerConfig
from repro.core.trp import fmp_standard
from repro.core.types import Variant, Window
from repro.core.wis import (RoundSelector, make_round_selector, wis_brute_force,
                            wis_select, wis_select_batch)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAS_HYPOTHESIS = False

GB = 1 << 30

ALL_IMPLS = ("numpy", "ref", "pallas")


def _variant(job, sid, t0, dur, h, *, work=None, vid=None):
    return Variant(
        job_id=job, slice_id=sid, t_start=t0, duration=dur,
        fmp=fmp_standard(1 * GB, 2 * GB, 0.1 * GB),
        local_utility=h, declared_features={},
        payload={"work": work if work is not None else dur},
        variant_id=vid or f"{job}/{sid}/{t0}")


def _grid_pool(rng, *, n_windows, lanes, masked_frac=0.2):
    """Padded (W, L) layout on a float32-exact grid (halves / 64ths)."""
    starts = rng.integers(0, 64, (n_windows, lanes)).astype(np.float64) / 2
    ends = starts + rng.integers(1, 32, (n_windows, lanes)) / 2
    weights = rng.integers(1, 64, (n_windows, lanes)).astype(np.float64) / 64
    valid = rng.random((n_windows, lanes)) > masked_frac
    return starts, ends, weights, valid


def _check_batch_matches_reference(seed):
    rng = np.random.default_rng(seed)
    n_windows = int(rng.integers(1, 6))
    lanes = int(rng.integers(1, 40))
    starts, ends, weights, valid = _grid_pool(rng, n_windows=n_windows,
                                              lanes=lanes)
    results = {
        impl: wis_select_batch(starts, ends, weights, valid, impl=impl)
        for impl in ALL_IMPLS
    }
    for k in range(n_windows):
        mask = valid[k]
        exp_sel, exp_total = wis_select(starts[k][mask], ends[k][mask],
                                        weights[k][mask])
        exp_set = set(int(i) for i in exp_sel)
        for impl, (sel, totals) in results.items():
            got = set(np.flatnonzero(sel[k][mask]).tolist())
            assert got == exp_set, (seed, impl, k)
            assert abs(totals[k] - exp_total) < 1e-9, (seed, impl, k)
        if mask.sum() and mask.sum() <= 12:
            _, bf_total = wis_brute_force(starts[k][mask], ends[k][mask],
                                          weights[k][mask])
            assert abs(exp_total - bf_total) < 1e-9, (seed, k)


def _check_settle_identity(seed, *, with_budget):
    """fixed_point_settle: every batched backend == the per-window loop."""
    rng = np.random.default_rng(seed)
    n_windows = int(rng.integers(2, 6))
    n_jobs = 6
    windows = [Window(f"s{k}", (4 + 2 * k) * GB, 0.0, 100.0)
               for k in range(n_windows)]
    pool = []
    m = int(rng.integers(10, 70))
    for i in range(m):
        w = windows[int(rng.integers(0, n_windows))]
        # float32-exact grid keeps the f32 device DP decision-identical
        t0 = float(rng.integers(0, 140)) / 2
        dur = float(rng.integers(4, 120)) / 2
        if t0 + dur > w.duration:
            dur = w.duration - t0
        if dur <= 0:
            continue
        pool.append(_variant(f"J{i % n_jobs}", w.slice_id, t0, dur,
                             float(rng.uniform(0.1, 0.9)), vid=f"v{i}"))
    budget = ({f"J{j}": float(rng.integers(60, 200)) for j in range(n_jobs)}
              if with_budget else None)
    fit, win_idx, view = assign_bids(windows, pool)
    # 12-bit grid: every partial DP sum stays float32-exact (see the
    # settle_throughput benchmark note), so f32/f64 decisions provably agree
    scores = rng.integers(1, 1 << 12, len(fit)).astype(np.float64) / (1 << 12)

    def run(selector):
        rr = fixed_point_settle(windows, fit, win_idx, scores,
                                selector=selector, work_budget=budget,
                                view=view)
        return ([tuple(v.variant_id for v in r.selected) for r in rr.results],
                rr.selected_idx, round(rr.total_score, 12), rr.n_conflicts)

    base = run(wis_select)
    for impl in ALL_IMPLS:
        assert run(make_round_selector(impl)) == base, (seed, impl)


# ---------------------------------------------------------------------------
# batched op == wis_select == brute force
# ---------------------------------------------------------------------------


def test_batch_matches_reference_seeded():
    for seed in range(25):
        _check_batch_matches_reference(seed)


def test_settle_identity_seeded():
    for seed in range(12):
        _check_settle_identity(seed, with_budget=bool(seed % 2))


if HAS_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10 ** 6))
    def test_batch_matches_reference_property(seed):
        _check_batch_matches_reference(seed)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10 ** 6), st.booleans())
    def test_settle_identity_property(seed, with_budget):
        _check_settle_identity(seed, with_budget=with_budget)


def test_batch_empty_and_fully_masked_windows():
    rng = np.random.default_rng(0)
    starts, ends, weights, valid = _grid_pool(rng, n_windows=4, lanes=16)
    valid[1, :] = False  # all-masked window
    valid[3, :] = False
    sel, totals = wis_select_batch(starts, ends, weights, valid, impl="numpy")
    for impl in ("ref", "pallas"):
        sel_i, totals_i = wis_select_batch(starts, ends, weights, valid,
                                           impl=impl)
        assert (sel_i == sel).all()
    assert not sel[1].any() and not sel[3].any()
    assert totals[1] == 0.0 and totals[3] == 0.0
    # zero windows / zero lanes degenerate shapes
    sel0, tot0 = wis_select_batch(np.zeros((0, 4)), np.zeros((0, 4)),
                                  np.zeros((0, 4)))
    assert sel0.shape == (0, 4) and tot0.shape == (0,)


def test_batch_touching_half_open_intervals():
    """The paper's worked example: (40,47) and (47,50) are both selected."""
    starts = np.array([[40.0, 47.0, 40.0]])
    ends = np.array([[47.0, 50.0, 50.0]])
    weights = np.array([[0.67, 0.64, 0.72]])
    for impl in ALL_IMPLS:
        sel, totals = wis_select_batch(starts, ends, weights, impl=impl)
        assert sel[0].tolist() == [True, True, False], impl
        assert abs(totals[0] - 1.31) < 1e-9


def test_batch_bucket_boundaries():
    """Lane counts straddling the pow2 buckets keep padding self-masking."""
    rng = np.random.default_rng(3)
    for lanes in (31, 32, 33, 63, 64, 65):
        starts, ends, weights, valid = _grid_pool(
            rng, n_windows=3, lanes=lanes, masked_frac=0.1)
        sel_np, _ = wis_select_batch(starts, ends, weights, valid, impl="numpy")
        sel_ref, _ = wis_select_batch(starts, ends, weights, valid, impl="ref")
        assert (sel_np == sel_ref).all(), lanes
        assert not (sel_np & ~valid).any(), lanes


def test_zero_weight_banning_equals_removal():
    """The retained-buffer ban trick: zero-weight lanes are never selected
    and leave the other lanes' DP values untouched."""
    rng = np.random.default_rng(4)
    starts, ends, weights, valid = _grid_pool(rng, n_windows=2, lanes=24,
                                              masked_frac=0.0)
    ban = rng.random((2, 24)) < 0.4
    # (a) remove banned lanes via the valid mask
    sel_removed, tot_removed = wis_select_batch(
        starts, ends, weights, ~ban, impl="numpy")
    # (b) keep them but zero their weights
    w0 = np.where(ban, 0.0, weights)
    sel_zeroed, _ = wis_select_batch(starts, ends, w0, None, impl="numpy")
    assert (sel_zeroed & ban).sum() == 0
    assert (sel_removed == (sel_zeroed & ~ban)).all()


# ---------------------------------------------------------------------------
# scheduler end-to-end: wis_impl backends byte-identical, serial + pipelined
# ---------------------------------------------------------------------------


def _slices():
    return [SliceSpec("s20", 20 * GB, n_chips=4),
            SliceSpec("s10", 10 * GB, n_chips=2),
            SliceSpec("s5", 5 * GB, n_chips=1)]


def _run_sched(wis_impl, *, pipeline=True, clearing=None, score_impl=None):
    pol = Policy() if clearing is None else Policy(name="x", clearing=clearing)
    cfg = SchedulerConfig.from_policy(pol, wis_impl=wis_impl,
                                      score_impl=score_impl)
    sched = JasdaScheduler(_slices(), cfg)
    simulate(sched, make_workload(40, seed=3, arrival_rate=0.3),
             SimConfig(t_end=900.0, seed=2, pipeline=pipeline))
    return (
        [(r.t, r.n_selected, round(r.total_score, 9)) for r in sched.log],
        [(c.variant_id, c.slice_id, round(c.t_start, 9), round(c.score, 9))
         for c in sched.commit_log],
    )


@pytest.mark.parametrize("impl", ALL_IMPLS)
def test_scheduler_byte_identical_under_wis_impl(impl):
    assert _run_sched(impl) == _run_sched(None)


@pytest.mark.parametrize("impl", [None, "numpy", "ref"])
def test_pipelined_equals_serial_under_device_selector(impl):
    assert (_run_sched(impl, pipeline=True)
            == _run_sched(impl, pipeline=False))


@pytest.mark.parametrize("clearing", [GlobalAssignment(), FairShare()])
def test_backends_identical_under_batched_selector(clearing):
    base = _run_sched(None, clearing=clearing)
    assert _run_sched("numpy", clearing=clearing) == base


def test_scheduler_fused_path_byte_identical():
    """Forced device scoring keeps the handle in flight, so the scheduler's
    predispatch (fused score→clear) actually runs — and must not change a
    single commit."""
    base = _run_sched(None, score_impl="ref")
    assert _run_sched("ref", score_impl="ref") == base
    assert _run_sched("ref", score_impl="ref", pipeline=False) == base


def test_global_assignment_lockstep_equals_serial():
    """Conflict-heavy pool: lockstep config-batch replays == host replays."""
    rng = np.random.default_rng(13)
    n_windows = 5
    windows = [Window(f"s{k}", (4 + 2 * k) * GB, 0.0, 100.0)
               for k in range(n_windows)]
    pool = []
    for i in range(90):
        j = i % 8
        t0 = float(rng.integers(0, 120)) / 2
        dur = float(rng.integers(8, 80)) / 2
        dur = min(dur, 100.0 - t0)
        if dur <= 0:
            continue
        for k in rng.choice(n_windows, size=2, replace=False):
            pool.append(_variant(f"J{j}", f"s{k}", t0, dur,
                                 float(rng.uniform(0.1, 0.9)),
                                 vid=f"J{j}/s{k}/v{len(pool)}"))
    ga = GlobalAssignment()
    base = clear_round(windows, pool, ScoringPolicy(), clearing=ga)
    assert base.n_conflicts > 0  # the scenario must actually exercise replays
    for impl in ALL_IMPLS:
        rr = clear_round(windows, pool, ScoringPolicy(), clearing=ga,
                         wis_impl=impl)
        assert ([tuple(v.variant_id for v in r.selected) for r in rr.results]
                == [tuple(v.variant_id for v in r.selected)
                    for r in base.results]), impl
        assert abs(rr.total_score - base.total_score) < 1e-9


# ---------------------------------------------------------------------------
# fused score→clear dispatch + zero retraces
# ---------------------------------------------------------------------------


def _stream_rounds(rng, specs):
    rounds = []
    for m, n_windows in specs:
        windows = [Window(f"s{k}", (10 + 2 * (k % 6)) * GB, 300.0 * k, 200.0)
                   for k in range(n_windows)]
        fmp = fmp_standard(1 * GB, 2 * GB, 0.2 * GB)
        pool = []
        for i in range(m):
            w = windows[int(rng.integers(0, n_windows))]
            t0 = w.t_min + float(rng.uniform(0, w.duration * 0.7))
            dur = float(rng.uniform(2.0, w.t_min + w.duration - t0))
            pool.append(Variant(
                job_id=f"J{i % 16}", slice_id=w.slice_id, t_start=t0,
                duration=dur, fmp=fmp,
                local_utility=float(rng.uniform(0.1, 0.9)),
                declared_features={}, payload={"work": dur},
                variant_id=f"J{i % 16}/v{i}"))
        rounds.append((windows, pool))
    return rounds


def test_fused_settle_matches_host_and_never_retraces():
    from repro.kernels.wis_dp import ops as wis_ops

    rng = np.random.default_rng(11)
    policy = ScoringPolicy()
    kw = dict(score_impl="ref", recheck_theta=0.5, grid=16)
    specs = [(400, 6), (520, 4), (380, 6), (450, 5)]
    rounds = _stream_rounds(rng, specs)
    serial = [clear_round(w, p, policy, **kw) for w, p in rounds]
    fused = pipelined_clear_rounds(rounds, policy, wis_impl="ref", **kw)
    assert ([[tuple(v.variant_id for v in r.selected) for r in rr.results]
             for rr in serial]
            == [[tuple(v.variant_id for v in r.selected) for r in rr.results]
                for rr in fused])
    # warm pass done above; a fresh stream over the same shape buckets must
    # hit the jit cache on every dispatch
    rounds2 = _stream_rounds(rng, specs)
    pipelined_clear_rounds(rounds2, policy, wis_impl="ref", **kw)  # warm new buckets if any
    rounds3 = _stream_rounds(rng, specs)
    base = wis_ops.trace_counts()
    pipelined_clear_rounds(rounds3, policy, wis_impl="ref", **kw)
    delta = {k: wis_ops.trace_counts()[k] - base[k] for k in base}
    assert sum(delta.values()) == 0, f"batched settle retraced: {delta}"


def test_transforming_backend_rides_fused_path():
    """FairShare transforms selection scores — since PR 6 the transform is
    threaded into the fused dispatch (``prefetch_transform``), so it DOES
    consume the prefetch, and a transformed prefetch is never handed to a
    raw-score first pass (nor vice versa)."""
    assert GreedyWIS.supports_prefetch
    assert GlobalAssignment.supports_prefetch
    assert FairShare.supports_prefetch
    # transform quantization contract: float32, 1 + age_weight·age
    from repro.core.types import PoolView

    view = PoolView.build(_stream_rounds(np.random.default_rng(0),
                                         [(12, 2)])[0][1])
    tr = FairShare(age_weight=0.5).prefetch_transform(
        view, {j: 0.6 for j in view.job_ids})
    assert tr.dtype == np.float32
    np.testing.assert_allclose(tr, np.float32(1.3))
    assert GreedyWIS().prefetch_transform(view, {}) is None


def test_transformed_prefetch_gating():
    """A transformed prefetch must only seed a transformed first pass:
    FairShare consumes its own prefetch; a raw prefetch handed to FairShare
    (or a transformed one to GreedyWIS) is recomputed, not honored."""
    from repro.core import wis as wis_mod
    from repro.core.wis import predispatch_settle

    rng = np.random.default_rng(13)
    # pool above SMALL_POOL_M so scoring dispatches on device (prefetchable)
    windows, pool = _stream_rounds(rng, [(420, 4)])[0]
    policy = ScoringPolicy()
    ages = {f"J{i}": (i % 5) / 4.0 for i in range(16)}
    calls = []
    orig = wis_mod.SettlePrefetch.materialize

    def spy(self, scores):
        calls.append(self.transformed)
        return orig(self, scores)

    try:
        wis_mod.SettlePrefetch.materialize = spy
        rr = clear_round(windows, pool, policy, ages=ages, wis_impl="ref",
                         clearing=FairShare())
        assert calls == [True]
        calls.clear()
        # cross-wired: transformed prefetch into a raw-score settle is
        # silently ignored (fixed_point_settle recomputes), still identical
        fit, win_idx, view = assign_bids(windows, pool)
        from repro.core.scoring import score_round_async
        selector = make_round_selector("ref")
        handle = score_round_async(fit, windows, win_idx, policy, ages=ages,
                                   view=view)
        wrong = predispatch_settle(selector, FairShare(), len(windows),
                                   win_idx, view, handle, ages=ages)
        base = settle_round(windows, fit, win_idx, handle.result(),
                            selector=selector, view=view, clearing=GreedyWIS())
        crossed = settle_round(windows, fit, win_idx, handle.result(),
                               selector=selector, view=view,
                               clearing=GreedyWIS(), prefetch=wrong)
        assert not calls  # transformed prefetch never materialized raw
        assert ([tuple(v.variant_id for v in r.selected) for r in base.results]
                == [tuple(v.variant_id for v in r.selected)
                    for r in crossed.results])
    finally:
        wis_mod.SettlePrefetch.materialize = orig
    # the honored FairShare round equals the host path byte-for-byte
    host = clear_round(windows, pool, policy, ages=ages,
                       clearing=FairShare())
    assert ([tuple(v.variant_id for v in r.selected) for r in rr.results]
            == [tuple(v.variant_id for v in r.selected)
                for r in host.results])
    assert rr.total_score == host.total_score


def test_custom_backend_signature_unchanged():
    """Backends with the pre-PR-5 settle signature still work through the
    scheduler (prefetch/selector forwarding is capability-gated)."""
    from dataclasses import dataclass

    from repro.core.policy import ClearingPolicy

    @dataclass(frozen=True)
    class OldStyle(ClearingPolicy):
        name = "old_style"

        def settle(self, windows, fit, win_idx, scores, *, selector=wis_select,
                   work_budget=None, view=None, ages=None):
            return fixed_point_settle(windows, fit, win_idx, scores,
                                      selector=selector,
                                      work_budget=work_budget, view=view)

    rng = np.random.default_rng(2)
    rounds = _stream_rounds(rng, [(120, 4)])
    windows, pool = rounds[0]
    rr = clear_round(windows, pool, ScoringPolicy(), clearing=OldStyle(),
                     wis_impl="ref", score_impl="ref")
    base = clear_round(windows, pool, ScoringPolicy(), clearing=GreedyWIS())
    assert ([tuple(v.variant_id for v in r.selected) for r in rr.results]
            == [tuple(v.variant_id for v in r.selected) for r in base.results])


# ---------------------------------------------------------------------------
# satellite: vectorized RoundFeedback assembly == the object walk
# ---------------------------------------------------------------------------


def test_vectorized_feedback_equals_object_walk(monkeypatch):
    import repro.core.negotiation.messages as msgs

    orig = msgs._build_feedback_vectorized
    calls = {"fast": 0}

    def spy(now, windows, agents, bids, rr, calibrator, view, win_idx):
        calls["fast"] += 1
        fast = orig(now, windows, agents, bids, rr, calibrator, view, win_idx)
        legacy = msgs.build_feedback(now, windows, agents, bids, rr,
                                     calibrator)  # no view → object walk
        assert fast == legacy
        return fast

    monkeypatch.setattr(msgs, "_build_feedback_vectorized", spy)
    sched = JasdaScheduler(_slices(), Policy())
    simulate(sched, make_workload(25, seed=5, arrival_rate=0.3),
             SimConfig(t_end=500.0, seed=2))
    assert calls["fast"] > 5  # the fast path actually ran


def test_feedback_falls_back_without_selected_idx():
    """RoundResults from backends that don't report pool indices (custom /
    pre-PR-5) still produce feedback via the object walk."""
    rng = np.random.default_rng(6)
    windows, pool = _stream_rounds(rng, [(40, 3)])[0]
    agents = []
    rr = clear_round(windows, pool, ScoringPolicy())
    import dataclasses

    stripped = dataclasses.replace(rr, selected_idx=())
    fit, win_idx, view = assign_bids(windows, pool)
    fb_stripped = build_feedback(0.0, windows, agents, [], stripped,
                                 view=view, win_idx=win_idx)
    fb_full = build_feedback(0.0, windows, agents, [], rr)
    assert fb_stripped.cutoffs == fb_full.cutoffs


# ---------------------------------------------------------------------------
# satellite: AgentConfig.n_start_offsets in chunk_chain_bids
# ---------------------------------------------------------------------------


def _agent(n_start_offsets=1, work=60.0):
    spec = JobSpec(job_id="J0", arrival_time=0.0, total_work=work,
                   fmp=fmp_standard(0.5 * GB, 2 * GB, 0.1 * GB))
    return JobAgent(spec, AgentConfig(n_start_offsets=n_start_offsets))


def test_start_offsets_default_is_byte_identical():
    w = Window("s0", 8 * GB, 10.0, 40.0)
    base = chunk_chain_bids(_agent(), w, 0.0)
    explicit = chunk_chain_bids(_agent(1), w, 0.0)
    assert [(v.variant_id, v.t_start, v.duration) for v in base] == \
        [(v.variant_id, v.t_start, v.duration) for v in explicit]


def test_start_offsets_add_overlapping_alternatives():
    # work << window span so the carrier chunk leaves room for shifted starts
    w = Window("s0", 8 * GB, 10.0, 40.0)
    base = chunk_chain_bids(_agent(work=15.0), w, 0.0)
    offs = chunk_chain_bids(_agent(3, work=15.0), w, 0.0)
    base_keys = {(v.t_start, v.duration) for v in base}
    extras = [v for v in offs if (v.t_start, v.duration) not in base_keys]
    assert extras, "n_start_offsets=3 must add shifted alternatives"
    for e in extras:
        # every shifted copy overlaps at least one unshifted sibling (WIS
        # keeps at most one per chain position → no double-committed work)
        assert any(e.t_start < b.t_end and b.t_start < e.t_end
                   for b in offs if (b.t_start, b.duration) in base_keys)
    # deterministic ids: regeneration produces the identical bid set
    again = chunk_chain_bids(_agent(3, work=15.0), w, 0.0)
    assert [(v.variant_id, v.t_start, v.duration) for v in offs] == \
        [(v.variant_id, v.t_start, v.duration) for v in again]


def test_start_offsets_flow_through_scheduler():
    """A population with start alternatives still clears consistently
    (serial == pipelined) and never over-commits a job's work."""
    sched_kw = dict(arrival_rate=0.4)

    def run(pipeline):
        sched = JasdaScheduler(_slices(), Policy())
        agents = make_workload(20, seed=7, **sched_kw)
        for a in agents:
            a.cfg = AgentConfig(n_start_offsets=3, strategy=a.cfg.strategy)
        simulate(sched, agents,
                 SimConfig(t_end=500.0, seed=2, pipeline=pipeline))
        return ([(r.t, r.n_selected, round(r.total_score, 9))
                 for r in sched.log],
                [(c.variant_id, round(c.t_start, 9)) for c in sched.commit_log])

    assert run(True) == run(False)
