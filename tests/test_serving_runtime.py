"""Serving engine + runtime health + executor integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.runtime import HealthConfig, HealthMonitor
from repro.serving import Request, ServeConfig, ServingEngine


def _model():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      model_axis_size=1, dtype=jnp.float32)
    m = Model(cfg)
    return m, m.init(jax.random.PRNGKey(0)), cfg


def test_engine_completes_all_requests():
    m, params, cfg = _model()
    eng = ServingEngine(m, params, ServeConfig(batch_slots=2, max_seq=64))
    reqs = [Request(f"r{i}", (np.arange(4 + i) % 256).astype(np.int32),
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)


def test_continuous_batching_matches_isolated():
    """Tokens generated with slot-sharing must equal a private engine run."""
    m, params, cfg = _model()
    prompts = [(np.arange(5) % 256).astype(np.int32),
               (np.arange(7)[::-1] % 256).astype(np.int32),
               ((np.arange(6) * 3) % 256).astype(np.int32)]
    # isolated: one request per engine
    solo_out = []
    for i, p in enumerate(prompts):
        eng = ServingEngine(m, params, ServeConfig(batch_slots=1, max_seq=64))
        r = Request(f"solo{i}", p, max_new_tokens=5)
        eng.submit(r)
        eng.run_until_done()
        solo_out.append(r.output)
    # shared: all three through 2 slots (forces queueing + slot reuse)
    eng = ServingEngine(m, params, ServeConfig(batch_slots=2, max_seq=64))
    reqs = [Request(f"shared{i}", p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    for r, expect in zip(reqs, solo_out):
        assert r.output == expect, "continuous batching changed results"


def test_eos_frees_slot():
    m, params, cfg = _model()
    eng = ServingEngine(m, params, ServeConfig(batch_slots=1, max_seq=64))
    # figure out the first generated token, then use it as EOS
    probe = Request("probe", np.arange(5, dtype=np.int32), max_new_tokens=3)
    eng.submit(probe)
    eng.run_until_done()
    eos = probe.output[0]
    eng2 = ServingEngine(m, params, ServeConfig(batch_slots=1, max_seq=64))
    r = Request("r", np.arange(5, dtype=np.int32), max_new_tokens=50, eos_id=eos)
    eng2.submit(r)
    eng2.run_until_done()
    assert r.done and len(r.output) <= 2


# ---------------------------------------------------------------------------
# runtime health
# ---------------------------------------------------------------------------

def test_dead_slice_detection():
    mon = HealthMonitor(HealthConfig(heartbeat_interval=1.0, max_missed=3))
    mon.register("a", now=0.0)
    mon.register("b", now=0.0)
    mon.heartbeat("a", now=5.0)
    assert mon.dead_slices(now=5.0) == ["b"]


def test_straggler_detection():
    mon = HealthMonitor(HealthConfig(straggler_ratio=0.6, speed_halflife=1))
    mon.register("fast", now=0.0)
    mon.register("slow", now=0.0)
    for _ in range(6):
        mon.heartbeat("fast", now=1.0, observed_speed=1.0)
        mon.heartbeat("slow", now=1.0, observed_speed=0.3)
    assert mon.stragglers() == ["slow"]
    assert mon.speed("slow") < 0.5


# ---------------------------------------------------------------------------
# executor: real training under the interaction cycle
# ---------------------------------------------------------------------------

def test_executor_runs_real_jobs_to_completion():
    from repro.core import JasdaScheduler, SliceSpec
    from repro.core.scheduler import SchedulerConfig
    from repro.core.windows import WindowPolicy
    from repro.core.executor import JasdaExecutor, TrainingJob

    GB = 1 << 30
    sched = JasdaScheduler(
        [SliceSpec("lane0", 8 * GB, n_chips=1)],
        SchedulerConfig(window=WindowPolicy(horizon=60.0, min_gap=0.2)))
    ex = JasdaExecutor(sched)
    calls = []

    def step_fn(start, n):
        calls.append((start, n))
        return {"loss": 1.0 / (start + n)}

    ckpts = []
    job = TrainingJob(job_id="J", total_steps=25, step_fn=step_fn,
                      checkpoint_fn=lambda s: ckpts.append(s),
                      param_bytes=1e6, optimizer_bytes=1e6,
                      activation_bytes=1e6, steps_per_sec=100.0)
    ex.register(job)
    ex.run(max_wall=30.0)
    assert job.steps_done >= 25
    assert ckpts, "chunk boundaries must checkpoint"
    # chunks are contiguous from 0
    covered = sum(n for _, n in calls)
    assert covered >= 25
