"""WIS clearing: optimality (vs brute force), Table 3, path agreement."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.wis import wis_brute_force, wis_select, wis_select_jax
from repro.kernels.wis_dp.ops import wis_clear


def _random_pool(rng, m):
    starts = rng.uniform(0, 100, m)
    ends = starts + rng.uniform(0.5, 30, m)
    w = rng.uniform(0.0, 1.0, m)
    return starts, ends, w


# ---------------------------------------------------------------------------
# paper §4.5 worked example (Table 3)
# ---------------------------------------------------------------------------

def test_table3_worked_example():
    starts = [40, 47, 40]
    ends = [47, 50, 50]
    scores = [0.67, 0.64, 0.72]  # v_A1, v_A2, v_B1
    sel, total = wis_select(starts, ends, scores)
    assert set(sel.tolist()) == {0, 1}, "must select {v_A1, v_A2}"
    assert total == pytest.approx(1.31)


def test_table3_scores_from_eq4():
    # Score = λ·h̃ + (1−λ)·f̃_sys with λ = 0.6 reproduces Table 3 exactly
    lam = 0.6
    rows = [(0.75, 0.55, 0.67), (0.60, 0.70, 0.64), (0.80, 0.60, 0.72)]
    for h, f, score in rows:
        assert lam * h + (1 - lam) * f == pytest.approx(score)


# ---------------------------------------------------------------------------
# optimality property (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 14))
def test_wis_matches_brute_force(seed, m):
    rng = np.random.default_rng(seed)
    starts, ends, w = _random_pool(rng, m)
    sel, total = wis_select(starts, ends, w)
    _, total_bf = wis_brute_force(starts, ends, w)
    assert total == pytest.approx(total_bf, abs=1e-9)
    # selection itself must be feasible (pairwise non-overlapping)
    sel = sel.tolist()
    for i in range(len(sel)):
        for j in range(i + 1, len(sel)):
            a, b = sel[i], sel[j]
            assert not (starts[a] < ends[b] - 1e-12 and starts[b] < ends[a] - 1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12))
def test_jax_and_kernel_paths_agree(seed, m):
    rng = np.random.default_rng(seed)
    starts, ends, w = _random_pool(rng, m)
    sel_h, total_h = wis_select(starts, ends, w)
    mask_j, total_j = wis_select_jax(starts, ends, w)
    sel_k, total_k = wis_clear(starts, ends, w, impl="pallas")
    assert float(total_j) == pytest.approx(total_h, rel=1e-5)
    assert total_k == pytest.approx(total_h, rel=1e-5)
    assert set(np.where(np.asarray(mask_j))[0].tolist()) == set(sel_h.tolist())
    assert set(sel_k.tolist()) == set(sel_h.tolist())


def test_touching_intervals_are_compatible():
    # [40,47) + [47,50): the paper's example depends on this convention
    sel, total = wis_select([0, 5], [5, 10], [1.0, 1.0])
    assert len(sel) == 2 and total == pytest.approx(2.0)


def test_empty_pool():
    sel, total = wis_select([], [], [])
    assert len(sel) == 0 and total == 0.0


def test_rejects_negative_weights():
    with pytest.raises(ValueError):
        wis_select([0], [1], [-0.5])


def test_complexity_is_loglinear():
    # smoke for the O(M log M) claim: 20k intervals clears fast
    import time
    rng = np.random.default_rng(0)
    starts, ends, w = _random_pool(rng, 20000)
    t0 = time.perf_counter()
    sel, total = wis_select(starts, ends, w)
    assert time.perf_counter() - t0 < 2.0
    assert total > 0
