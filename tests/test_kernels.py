"""Per-kernel shape/dtype sweeps: pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import mha_pallas
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.jasda_score.kernel import score_variants_pallas
from repro.kernels.jasda_score.ref import score_variants_reference
from repro.kernels.linear_scan.kernel import linear_scan_pallas
from repro.kernels.linear_scan.ref import (linear_scan_associative,
                                           linear_scan_reference)
from repro.kernels.wis_dp.kernel import wis_dp_pallas
from repro.kernels.wis_dp.ref import wis_dp_reference


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (2, 4, 2, 256, 256, 64),
    (1, 8, 1, 128, 384, 64),     # MQA + decode-style longer k
    (1, 4, 4, 256, 256, 128),    # MHA, wide head
    (2, 2, 2, 512, 512, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = mha_pallas(q, k, v, causal=True, q_offset=sk - sq, interpret=True)
    ref = mha_reference(q, k, v, causal=True, q_offset=sk - sq)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1, 256, 64), jnp.float32)
    out = mha_pallas(q, k, v, causal=True, window=window, interpret=True)
    ref = mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    out = mha_pallas(q, k, v, causal=False, interpret=True)
    ref = mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d,bt,bd", [
    (2, 512, 256, 128, 128),
    (1, 1024, 512, 256, 512),
    (3, 256, 128, 256, 128),
])
def test_linear_scan_sweep(b, t, d, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.random.uniform(ks[0], (b, t, d), jnp.float32, 0.8, 0.999)
    bb = jax.random.normal(ks[1], (b, t, d), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (b, d), jnp.float32)
    o, hT = linear_scan_pallas(a, bb, h0, block_t=bt, block_d=bd, interpret=True)
    r, rT = linear_scan_reference(a, bb, h0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(rT), atol=1e-4)


def test_associative_scan_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    a = jax.random.uniform(ks[0], (2, 300, 64), jnp.float32, 0.5, 1.0)
    b = jax.random.normal(ks[1], (2, 300, 64), jnp.float32)
    h0 = jax.random.normal(ks[2], (2, 64), jnp.float32)
    o1, t1 = linear_scan_associative(a, b, h0)
    o2, t2 = linear_scan_reference(a, b, h0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


# ---------------------------------------------------------------------------
# jasda_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,t", [(8, 16), (64, 32), (256, 64), (300, 48)])
def test_jasda_score_sweep(m, t):
    rng = np.random.default_rng(m * 1000 + t)
    fj = rng.uniform(0, 1, (m, 3)).astype(np.float32)
    fs = rng.uniform(0, 1, (m, 3)).astype(np.float32)
    al = np.array([.5, .3, .2], np.float32)
    be = np.array([.4, .2, .2], np.float32)
    mu = rng.uniform(5, 21, (m, t)).astype(np.float32)
    sg = rng.uniform(0.0, 0.8, (m, t)).astype(np.float32)
    sg[rng.uniform(size=(m, t)) < 0.1] = 0.0
    from repro.kernels.jasda_score.ops import score_variants
    s_k, e_k, _ = score_variants(fj, fs, al, be, mu, sg, lam=0.6,
                                 capacity=20.0, theta=0.05, impl="pallas")
    s_r, e_r, _ = score_variants_reference(
        jnp.array(fj), jnp.array(fs), jnp.array(al), jnp.array(be),
        jnp.array(mu), jnp.array(sg), lam=0.6, capacity=20.0, theta=0.05)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=3e-5)
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))


def test_jasda_score_safety_matches_trp():
    # kernel's log-space safety must agree with the host evaluator
    from repro.core.trp import prob_exceed_grid
    rng = np.random.default_rng(11)
    mu = rng.uniform(5, 19, (16, 64))
    sg = rng.uniform(0.01, 1.0, (16, 64))
    _, elig, p = score_variants_reference(
        jnp.zeros((16, 3)), jnp.zeros((16, 3)),
        jnp.zeros(3), jnp.zeros(3),
        jnp.array(mu, jnp.float32), jnp.array(sg, jnp.float32),
        lam=0.5, capacity=20.0, theta=0.05)
    for i in range(16):
        p_host = prob_exceed_grid(mu[i], sg[i], 20.0)
        assert float(p[i]) == pytest.approx(p_host, abs=1e-4)


# ---------------------------------------------------------------------------
# wis_dp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 7, 64, 300])
def test_wis_dp_kernel_matches_ref(m):
    rng = np.random.default_rng(m)
    w = rng.uniform(0, 1, m).astype(np.float32)
    ends = np.sort(rng.uniform(0, 100, m))
    starts = ends - rng.uniform(0.5, 20, m)
    pred = np.searchsorted(ends, starts, side="right").astype(np.int32)
    dp_k, take_k = wis_dp_pallas(jnp.array(w), jnp.array(pred), interpret=True)
    dp_r, take_r = wis_dp_reference(jnp.array(w), jnp.array(pred))
    np.testing.assert_allclose(np.asarray(dp_k), np.asarray(dp_r), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(take_k), np.asarray(take_r))
