"""Fault injection, graceful degradation, and crash recovery.

Covers the robustness layer end to end: the retry/backoff helper, typed
kernel dispatch errors + the pallas → ref → numpy degradation ladder,
agent silent/error windows at bid collection, slice revocation with the
full recovery protocol, dead-window epsilon boundaries, calibration
snapshot round-trips, and checkpointed crash recovery (byte-identical
replay, serial AND pipelined).
"""
import json
import pickle

import numpy as np
import pytest

import repro.kernels.common as kcommon
from repro.checkpoint import CheckpointStore
from repro.core import (FaultEvent, FaultInjector, FaultPlan, JasdaScheduler,
                        SchedulerConfig, SimConfig, SliceSpec, simulate,
                        make_workload)
from repro.core.calibration import Calibrator
from repro.core.faults import (AGENT_ERROR, AGENT_SILENT, DEVICE_DISPATCH_FAIL,
                               SCHEDULER_CRASH, SLICE_REVOKED,
                               AgentRespondError, AgentSilentError)
from repro.core.negotiation.messages import LOSS_SLICE_FAILED
from repro.core.types import Variant
from repro.core.windows import DeadWindowRegistry
from repro.kernels.common import (BackendHealth, KernelDispatchError,
                                  check_dispatch_fault, clear_dispatch_faults,
                                  dispatch_faults_snapshot,
                                  inject_dispatch_fault,
                                  restore_dispatch_faults)
from repro.runtime.monitor import retry_with_backoff

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAS_HYPOTHESIS = False

GB = 1 << 30


@pytest.fixture(autouse=True)
def _clean_armed_faults():
    clear_dispatch_faults()
    yield
    clear_dispatch_faults()


def _slices(n=3, cap_gb=16):
    return [SliceSpec(f"S{k}", cap_gb * GB, flops_per_s=1.0, hbm_bw=1.0)
            for k in range(n)]


def _sched(impl="numpy"):
    return JasdaScheduler(_slices(), SchedulerConfig(wis_impl=impl))


def _commit_rows(sched):
    return [(r.status, r.job_id, r.slice_id, r.t_start, r.t_end, r.score)
            for r in sched.commit_log]


def _log_rows(sched):
    return [(l.t, l.n_bidders, l.n_bids, l.n_selected, l.total_score,
             l.n_windows, l.n_conflicts, l.n_dropped) for l in sched.log]


# ---------------------------------------------------------------------------
# retry_with_backoff
# ---------------------------------------------------------------------------

def test_backoff_success_first_try_no_sleep():
    sleeps = []
    out = retry_with_backoff(lambda k: ("ok", k), sleep=sleeps.append)
    assert out == ("ok", 0)
    assert sleeps == []


def test_backoff_delay_sequence_and_recovery():
    sleeps, calls = [], []

    def flaky(k):
        calls.append(k)
        if k < 2:
            raise RuntimeError("boom")
        return k

    out = retry_with_backoff(flaky, retries=3, base=0.05, factor=2.0,
                             max_delay=1.0, sleep=sleeps.append)
    assert out == 2
    assert calls == [0, 1, 2]
    assert sleeps == pytest.approx([0.05, 0.10])


def test_backoff_delay_cap():
    sleeps = []

    def always(k):
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        retry_with_backoff(always, retries=5, base=0.1, factor=10.0,
                           max_delay=0.3, sleep=sleeps.append)
    assert sleeps == pytest.approx([0.1, 0.3, 0.3, 0.3, 0.3])


def test_backoff_jitter_deterministic_per_seed():
    def run(seed):
        sleeps = []

        def twice(k):
            if k < 2:
                raise RuntimeError("boom")
            return k

        retry_with_backoff(twice, retries=2, base=0.1, jitter=0.5,
                           rng=np.random.default_rng(seed),
                           sleep=sleeps.append)
        return sleeps

    a, b = run(7), run(7)
    assert a == b  # seeded jitter replays
    assert all(s >= base for s, base in zip(a, [0.1, 0.2]))
    assert run(8) != a  # and actually jitters


def test_backoff_nonretryable_raises_immediately():
    calls = []

    def fail(k):
        calls.append(k)
        raise ValueError("fatal")

    with pytest.raises(ValueError):
        retry_with_backoff(fail, retries=5, sleep=lambda _d: None,
                           retryable=lambda e: not isinstance(e, ValueError))
    assert calls == [0]


def test_backoff_exhaustion_raises_last_error():
    with pytest.raises(RuntimeError, match="attempt 2"):
        retry_with_backoff(
            lambda k: (_ for _ in ()).throw(RuntimeError(f"attempt {k}")),
            retries=2, sleep=lambda _d: None)
    with pytest.raises(ValueError):
        retry_with_backoff(lambda k: k, retries=-1)


# ---------------------------------------------------------------------------
# typed kernel dispatch errors + degradation ladder
# ---------------------------------------------------------------------------

def test_kernel_dispatch_error_carries_backend_and_shape():
    inject_dispatch_fault("ref")
    with pytest.raises(KernelDispatchError) as ei:
        check_dispatch_fault("ref", "score_variants", (256, 32))
    err = ei.value
    assert err.backend == "ref"
    assert err.op == "score_variants"
    assert err.shape == (256, 32)
    assert isinstance(err.cause, RuntimeError)
    # the armed fault is one-shot
    check_dispatch_fault("ref", "score_variants", (256, 32))
    assert dispatch_faults_snapshot() == {}


def test_dispatch_faults_snapshot_roundtrip():
    inject_dispatch_fault("ref", count=2)
    snap = dispatch_faults_snapshot()
    clear_dispatch_faults()
    assert dispatch_faults_snapshot() == {}
    restore_dispatch_faults(snap)
    assert dispatch_faults_snapshot() == {"ref": 2}


def test_backend_health_ladder_and_stickiness():
    h = BackendHealth()
    assert h.resolve("pallas") == "pallas"
    h.mark_failed("pallas", "xla oom")
    assert h.resolve("pallas") == "ref"
    h.mark_failed("ref")
    assert h.resolve("pallas") == "numpy"
    assert h.resolve("ref") == "numpy"
    assert not h.healthy("ref") and h.healthy("numpy")
    # first failure reason is sticky
    h.mark_failed("pallas", "second reason")
    assert h.failed_backends()["pallas"] == "xla oom"
    h2 = BackendHealth()
    h2.restore(h.snapshot())
    assert h2.failed_backends() == h.failed_backends()


def test_settle_batch_raises_typed_error_and_ladder_recovers():
    from repro.core.wis import RoundSelector
    from repro.kernels.wis_dp import ops as wis_ops

    w = np.random.default_rng(0).uniform(1, 2, (4, 8)).astype(np.float32)
    pred = np.zeros((4, 8), np.int32)
    inject_dispatch_fault("ref")
    with pytest.raises(KernelDispatchError) as ei:
        wis_ops.wis_settle_batch(w, pred, impl="ref")
    assert ei.value.backend == "ref" and ei.value.op == "wis_settle_batch"

    # same fault through the selector: degrades to numpy, still selects
    inject_dispatch_fault("ref")
    health = BackendHealth()
    rs = RoundSelector("ref", health=health)
    sel = rs._dispatch(w.astype(np.float64), pred)
    assert sel.shape == w.shape
    assert "ref" in health.failed_backends()
    assert rs._effective_impl() == "numpy"
    # without a health object the typed error propagates
    inject_dispatch_fault("ref")
    with pytest.raises(KernelDispatchError):
        RoundSelector("ref")._dispatch(w.astype(np.float64), pred)


def test_ladder_degradation_preserves_results_and_traces():
    from repro.kernels.wis_dp.ops import trace_counts

    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=5.0, kind=DEVICE_DISPATCH_FAIL, target="ref"),))
    r_fault = simulate(_sched("ref"), make_workload(8, seed=3),
                       SimConfig(t_end=300.0, seed=1), faults=plan)
    assert "ref" in r_fault.scheduler.backend_health.failed_backends()
    assert r_fault.scheduler._wis_selector._effective_impl() == "numpy"
    before = dict(trace_counts())
    r_ref = simulate(_sched("numpy"), make_workload(8, seed=3),
                     SimConfig(t_end=300.0, seed=1))
    # ladder lands on the host backend: results match the numpy reference
    assert _commit_rows(r_fault.scheduler) == _commit_rows(r_ref.scheduler)
    assert r_fault.jct_per_job == r_ref.jct_per_job
    # the degraded run retraced nothing on the dead backend
    assert dict(trace_counts()) == before


# ---------------------------------------------------------------------------
# fault plans + the agent-fault gate
# ---------------------------------------------------------------------------

def test_fault_event_validates_kind():
    with pytest.raises(ValueError):
        FaultEvent(t=1.0, kind="meteor_strike")


def test_fault_plan_sorts_and_generates_deterministically():
    e1 = FaultEvent(t=9.0, kind=SLICE_REVOKED, target="S0")
    e2 = FaultEvent(t=3.0, kind=AGENT_SILENT, target="J000", duration=5.0)
    plan = FaultPlan(seed=0, events=(e1, e2))
    assert [e.t for e in plan.events] == [3.0, 9.0]
    assert plan.for_kind(SLICE_REVOKED) == (e1,)

    kw = dict(t_end=500.0, slice_ids=["S0", "S1"], job_ids=["J0", "J1"],
              revoke_rate=0.01, silent_rate=0.01, error_rate=0.01,
              dispatch_fail_times=[100.0], crash_times=[200.0])
    a, b = FaultPlan.generate(11, **kw), FaultPlan.generate(11, **kw)
    assert a == b
    assert a != FaultPlan.generate(12, **kw)
    assert a.for_kind(SCHEDULER_CRASH)[0].t == 200.0


def test_injector_gate_windows_and_attempts():
    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=10.0, kind=AGENT_SILENT, target="JA", duration=5.0),
        FaultEvent(t=20.0, kind=AGENT_ERROR, target="JB", duration=5.0,
                   attempts=2),
    ))
    gate = FaultInjector(plan)

    class A:
        def __init__(self, jid):
            self.spec = type("S", (), {"job_id": jid})()

    ja, jb = A("JA"), A("JB")
    gate(ja, 9.9, 0)  # before the window: no raise
    with pytest.raises(AgentSilentError):
        gate(ja, 10.0, 0)
    with pytest.raises(AgentSilentError):
        gate(ja, 14.9, 3)  # silence ignores the attempt index
    gate(ja, 15.0, 0)  # window is half-open [t0, t1)

    with pytest.raises(AgentRespondError):
        gate(jb, 21.0, 0)
    with pytest.raises(AgentRespondError):
        gate(jb, 21.0, 1)
    gate(jb, 21.0, 2)  # attempts=2: the third retry succeeds
    # the gate is stateless in time: re-asking an old (t, attempt) replays
    with pytest.raises(AgentRespondError):
        gate(jb, 21.0, 0)
    # slice/device/crash events go through the heap, agent windows do not
    kinds = {e.kind for e in gate.scheduled_events()}
    assert AGENT_SILENT not in kinds and AGENT_ERROR not in kinds
    assert pickle.loads(pickle.dumps(gate)).plan == plan


def test_silent_and_error_agents_do_not_stall_rounds():
    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=0.0, kind=AGENT_SILENT, target="J001", duration=60.0),
        FaultEvent(t=0.0, kind=AGENT_ERROR, target="J002", duration=40.0),
    ))
    results = {}
    for pipeline in (False, True):
        sched = _sched()
        r = simulate(sched, make_workload(8, seed=3),
                     SimConfig(t_end=400.0, seed=1, pipeline=pipeline),
                     faults=plan)
        assert r.iterations > 0
        assert sum(l.n_dropped for l in sched.log) > 0
        results[pipeline] = (_commit_rows(sched), _log_rows(sched),
                             r.jct_per_job, r.calibration)
    # dropped bidders are part of round state: pipelined == serial exactly
    assert results[False] == results[True]


def test_error_agent_recovers_within_retry_budget():
    # fails 2 consecutive attempts; scheduler retries bid_retries=2 times,
    # so the third attempt lands and the agent is NEVER dropped
    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=0.0, kind=AGENT_ERROR, target="J000", duration=1e9,
                   attempts=2),))
    sched = _sched()
    r = simulate(sched, make_workload(4, seed=3),
                 SimConfig(t_end=200.0, seed=1), faults=plan)
    assert sum(l.n_dropped for l in sched.log) == 0
    assert r.n_finished > 0


# ---------------------------------------------------------------------------
# slice revocation
# ---------------------------------------------------------------------------

def test_revoke_slice_full_protocol():
    sched = _sched()
    agents = make_workload(8, seed=3)
    for a in agents:
        sched.add_job(a, 0.0)
    for k in range(12):
        sched.run_round(float(k))
    victims = [c for c in sched.commitments if c.variant.slice_id == "S1"]
    assert victims, "workload never committed to S1; pick another seed"
    starts = [c.variant.t_start for c in victims]
    lost = sched.revoke_slice("S1", now=12.0)
    assert {id(c) for c in lost} == {id(c) for c in victims}
    # commit_log rows flipped to lost
    lost_rows = [r for r in sched.commit_log if r.status == "lost"]
    assert len(lost_rows) == len(victims)
    # revoked windows are retired: an eps-close twin stays suppressed
    for t0 in starts:
        assert sched._dead_windows.suppressed("S1", t0)
        assert sched._dead_windows.suppressed("S1", t0 + 0.5e-6)
    # out-of-round feedback notified every affected agent
    fb = sched.last_feedback
    assert fb is not None and fb.t == 12.0 and fb.windows == ()
    reported = {v.variant_id for ls in fb.losses.values() for v in ls}
    assert reported == {c.variant.variant_id for c in victims}
    assert all(l.reason == LOSS_SLICE_FAILED
               for ls in fb.losses.values() for l in ls)
    assert set(fb.reliability) == set(fb.losses)


def test_revoked_work_is_recleared_and_sim_completes():
    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=40.0, kind=SLICE_REVOKED, target="S1", duration=40.0),
        FaultEvent(t=30.0, kind=AGENT_SILENT, target="J003", duration=20.0),
    ))
    sched = _sched()
    r = simulate(sched, make_workload(8, seed=3),
                 SimConfig(t_end=600.0, seed=1), faults=plan)
    rows = sched.commit_log
    lost_jobs = {row.job_id for row in rows if row.status == "lost"}
    assert lost_jobs, "revocation at t=40 should catch live commitments"
    # every revoked commitment is accounted for in the audit trail AND the
    # job's work was re-cleared afterwards (a later commitment exists)
    for job in lost_jobs:
        t_lost = max(row.t_start for row in rows
                     if row.job_id == job and row.status == "lost")
        later = [row for row in rows if row.job_id == job
                 and row.status != "lost" and row.t_end > t_lost]
        assert later, f"{job} lost its slice but was never re-cleared"
    assert r.n_finished == r.n_jobs  # nothing is stranded by the fault


def test_degraded_slice_inflates_observed_durations():
    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=0.5, kind="slice_degraded", target="S0", magnitude=0.25),
        FaultEvent(t=0.5, kind="slice_degraded", target="S1", magnitude=0.25),
        FaultEvent(t=0.5, kind="slice_degraded", target="S2", magnitude=0.25),
    ))
    r_slow = simulate(_sched(), make_workload(6, seed=3),
                      SimConfig(t_end=2000.0, seed=1), faults=plan)
    r_fast = simulate(_sched(), make_workload(6, seed=3),
                      SimConfig(t_end=2000.0, seed=1))
    assert r_slow.mean_jct > r_fast.mean_jct


# ---------------------------------------------------------------------------
# dead-window epsilon boundaries (revoked twin re-announced within eps)
# ---------------------------------------------------------------------------

def _check_eps_boundary(t_min, frac, eps):
    reg = DeadWindowRegistry(eps=eps)
    reg.add("s", t_min, expiry=100.0)
    inside = t_min + frac * eps
    outside = t_min + (2.0 + frac) * eps
    assert reg.suppressed("s", inside)
    assert not reg.suppressed("s", outside)
    # a twin within eps MERGES (expiry extends) instead of duplicating
    reg.add("s", inside, expiry=200.0)
    assert len(reg) == 1
    reg.prune(150.0)
    assert reg.suppressed("s", t_min), "merged expiry must be the max"
    # a twin beyond eps is a distinct entry
    reg.add("s", outside, expiry=300.0)
    assert len(reg) == 2


if HAS_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(
        t_min=st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False),
        # frac ≤ 0.9: t_min + frac*eps rounds to the nearest float, and at
        # frac=1.0 that rounding could push the twin just PAST eps
        frac=st.floats(0.0, 0.9, allow_nan=False),
        eps=st.floats(1e-9, 1e-3, allow_nan=False),
    )
    def test_dead_window_eps_boundary_property(t_min, frac, eps):
        _check_eps_boundary(t_min, frac, eps)

else:  # pragma: no cover - exercised only without hypothesis

    def test_dead_window_eps_boundary_seeded():
        rng = np.random.default_rng(0)
        for _ in range(200):
            _check_eps_boundary(float(rng.uniform(0, 1e6)),
                                float(rng.uniform(0, 0.9)),
                                float(10.0 ** rng.uniform(-9, -3)))


def test_dead_window_eps_boundary_near_limit():
    reg = DeadWindowRegistry(eps=1e-6)
    reg.add("s", 10.0, expiry=50.0)
    assert reg.suppressed("s", 10.0 + 0.999e-6)  # just inside eps
    assert not reg.suppressed("s", 10.0 + 2.1e-6)  # clearly beyond


# ---------------------------------------------------------------------------
# calibration snapshot round-trip (incl. jobs that never re-bid)
# ---------------------------------------------------------------------------

def _run_calibrated():
    sched = _sched()
    r = simulate(sched, make_workload(8, seed=3, misreport_fraction=0.4),
                 SimConfig(t_end=300.0, seed=1))
    assert any(row["errors"] for row in r.calibration.values())
    return sched, r.calibration


def test_calibration_roundtrip_exact_and_json():
    sched, snap = _run_calibrated()
    c2 = Calibrator(sched.calibrator.config).restore(snap)
    assert c2.snapshot() == snap
    # through JSON (the benchmark/CLI checkpoint form)
    c3 = Calibrator(sched.calibrator.config).restore(
        json.loads(json.dumps(snap)))
    assert c3.snapshot() == snap
    # error history order is state (windowed E[ε] reads the tail), and a
    # job that never re-bids must keep it verbatim through restore
    for j, row in snap.items():
        assert c2._jobs[j].errors == row["errors"]


def test_calibration_restore_continues_identically():
    sched, snap = _run_calibrated()
    c2 = Calibrator(sched.calibrator.config).restore(snap)
    jid = max(snap, key=lambda j: len(snap[j]["errors"]))
    v = Variant(job_id=jid, slice_id="S0", t_start=0.0, duration=1.0,
                fmp=None, local_utility=0.9, declared_features={"jct": 0.9})
    e1 = sched.calibrator.verify(v, {"jct": 0.55})
    e2 = c2.verify(v, {"jct": 0.55})
    assert e1 == e2
    assert sched.calibrator.snapshot()[jid] == c2.snapshot()[jid]


# ---------------------------------------------------------------------------
# checkpointed crash recovery
# ---------------------------------------------------------------------------

def test_checkpoint_save_restore_state(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    store.save_state(0, {"a": np.arange(3), "b": "x"})
    store.save_state(5, {"a": np.arange(4), "b": "y"})
    state, step = store.restore_state()
    assert step == 5 and state["b"] == "y"
    np.testing.assert_array_equal(state["a"], np.arange(4))
    state0, _ = store.restore_state(0)
    assert state0["b"] == "x"
    store.save_state(7, {"b": "z"})
    assert store.steps() == [5, 7]  # gc kept the newest two


_CRASH_BASE = (
    FaultEvent(t=12.0, kind=SLICE_REVOKED, target="S1", duration=40.0),
    FaultEvent(t=30.0, kind=AGENT_SILENT, target="J003", duration=20.0),
)


@pytest.mark.parametrize("pipeline", [False, True])
def test_crash_replay_is_byte_identical(pipeline, tmp_path):
    cfg = SimConfig(t_end=300.0, seed=1, pipeline=pipeline)
    runs = {}
    for tag, extra in (("ref", ()), ("crash", (
            FaultEvent(t=40.5, kind=SCHEDULER_CRASH),
            FaultEvent(t=90.5, kind=SCHEDULER_CRASH)))):
        store = CheckpointStore(str(tmp_path / f"{tag}_{pipeline}"))
        r = simulate(_sched(), make_workload(8, seed=3), cfg,
                     faults=FaultPlan(seed=7, events=_CRASH_BASE + extra),
                     checkpoint=store, checkpoint_every=5)
        runs[tag] = r
    ref, crash = runs["ref"], runs["crash"]
    assert _commit_rows(crash.scheduler) == _commit_rows(ref.scheduler)
    assert _log_rows(crash.scheduler) == _log_rows(ref.scheduler)
    assert crash.jct_per_job == ref.jct_per_job
    assert crash.calibration == ref.calibration
    assert crash.n_finished == ref.n_finished
    assert crash.total_score == ref.total_score


def test_crash_without_checkpoint_is_ignored():
    plan = FaultPlan(seed=0, events=(
        FaultEvent(t=50.5, kind=SCHEDULER_CRASH),))
    r = simulate(_sched(), make_workload(6, seed=3),
                 SimConfig(t_end=300.0, seed=1), faults=plan)
    r_ref = simulate(_sched(), make_workload(6, seed=3),
                     SimConfig(t_end=300.0, seed=1))
    assert r.jct_per_job == r_ref.jct_per_job


def test_scheduler_pickle_preserves_commit_identity():
    sched = _sched()
    agents = make_workload(6, seed=3)
    for a in agents:
        sched.add_job(a, 0.0)
    for k in range(10):
        sched.run_round(float(k))
    assert sched.commitments
    s2 = pickle.loads(pickle.dumps(sched))
    assert _commit_rows(s2) == _commit_rows(sched)
    # the commit index must be re-keyed by the RESTORED variants' ids
    for c in s2.commitments:
        assert id(c.variant) in s2._commit_index
        entry_c, _rec = s2._commit_index[id(c.variant)]
        assert entry_c is c
    # restored scheduler keeps scheduling
    assert s2.run_round(10.0) is not None or True


def test_chaos_seeded_plan_completes(tmp_path):
    """CI chaos matrix entry: a generated FaultPlan for JASDA_CHAOS_SEED.

    Under slice revocations + silent/erroring bidders + a mid-run crash the
    simulation must complete (no stall, no unhandled exception), every
    revoked commitment must be reported in the audit trail, and the
    pipelined run must equal the serial one exactly.
    """
    import os

    seed = int(os.environ.get("JASDA_CHAOS_SEED", "0"))
    t_end = 400.0
    plan = FaultPlan.generate(
        seed, t_end=t_end,
        slice_ids=[s.slice_id for s in _slices()],
        job_ids=[f"J{i:03d}" for i in range(10)],
        revoke_rate=0.004, silent_rate=0.003, error_rate=0.003,
        repair_time=40.0, fault_duration=15.0,
        crash_times=(t_end / 2 + 0.5,))
    results = {}
    for pipeline in (False, True):
        sched = _sched()
        store = CheckpointStore(str(tmp_path / f"chaos_{pipeline}"))
        r = simulate(sched, make_workload(10, seed=seed + 1),
                     SimConfig(t_end=t_end, seed=2, pipeline=pipeline),
                     faults=plan, checkpoint=store, checkpoint_every=20)
        final = r.scheduler  # post-crash-restore instance
        # no stall: the tick train ran the full horizon
        assert r.iterations >= int(t_end) - 1
        # every revocation is accounted for in the audit trail
        n_lost = sum(1 for row in final.commit_log if row.status == "lost")
        statuses = {row.status for row in final.commit_log}
        assert statuses <= {"active", "completed", "failed", "lost"}
        results[pipeline] = (_commit_rows(final), _log_rows(final),
                             r.jct_per_job, r.calibration, n_lost)
    assert results[False] == results[True]


def test_checkpoint_refuses_meshed_scheduler():
    import dataclasses

    sched = _sched()
    object.__setattr__(sched, "config",
                       dataclasses.replace(sched.config, mesh=object()))
    with pytest.raises(ValueError, match="mesh"):
        pickle.dumps(sched)
