"""Round-based auction: equivalence with the legacy single-window path,
cross-window exclusivity, work conservation, failures, dead-window epsilon."""
import numpy as np
import pytest

from repro.core import (AgentConfig, JasdaScheduler, JobAgent, JobSpec,
                        ScoringPolicy, SimConfig, SliceSpec, simulate,
                        make_workload)
from repro.core.clearing import clear_round, clear_window
from repro.core.scheduler import SchedulerConfig
from repro.core.scoring import score_pool, score_round
from repro.core.trp import fmp_standard
from repro.core.types import Variant, Window
from repro.core.windows import (DeadWindowRegistry, SliceTimeline,
                                WindowPolicy, announce_window,
                                announce_windows)

GB = 1 << 30


def _variant(job, sid, t0, dur, h, *, work=None, vid=None):
    return Variant(
        job_id=job, slice_id=sid, t_start=t0, duration=dur,
        fmp=fmp_standard(1 * GB, 2 * GB, 0.1 * GB),
        local_utility=h, declared_features={},
        payload={"work": work if work is not None else dur},
        variant_id=vid or f"{job}/{sid}/{t0}")


def _pool_for(window, rng, n, jobs=4):
    out = []
    for i in range(n):
        t0 = window.t_min + rng.uniform(0, window.duration * 0.6)
        dur = rng.uniform(2.0, window.t_min + window.duration - t0)
        out.append(_variant(f"J{i % jobs}", window.slice_id, t0, dur,
                            float(rng.uniform(0.1, 0.9)), vid=f"v{i}"))
    return out


# ---------------------------------------------------------------------------
# single-window equivalence: round clearing == legacy per-window clearing
# ---------------------------------------------------------------------------

def test_single_window_round_equivalence():
    rng = np.random.default_rng(0)
    w = Window("s0", 8 * GB, 10.0, 60.0)
    pool = _pool_for(w, rng, 40)
    policy = ScoringPolicy()
    ages = {f"J{j}": 0.1 * j for j in range(4)}

    legacy = clear_window(w, pool, policy, ages=ages)
    rr = clear_round([w], pool, policy, ages=ages)

    assert [v.variant_id for v in rr.results[0].selected] == \
        [v.variant_id for v in legacy.selected]
    assert rr.n_bids == legacy.n_bids
    np.testing.assert_allclose(rr.results[0].scores, legacy.scores, atol=1e-5)


def test_scheduler_step_is_single_window_round():
    # step() (the compatibility wrapper) must behave like the legacy
    # iteration: one window announced, one ClearingResult returned, commits
    # recorded — driven on a live scheduler
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)])
    for a in make_workload(5, seed=3, arrival_rate=5.0):
        sched.add_job(a, 0.0)
    res = sched.step(2.0)
    assert res is not None and res.selected
    assert len(sched.commitments) == len(res.selected)
    assert all(c.variant.slice_id == "s0" for c in sched.commitments)


def test_score_round_matches_score_pool_per_window():
    rng = np.random.default_rng(1)
    windows = [Window("s0", 8 * GB, 0.0, 50.0), Window("s1", 4 * GB, 20.0, 40.0)]
    pools = [_pool_for(w, rng, 16) for w in windows]
    flat = pools[0] + pools[1]
    win_idx = [0] * 16 + [1] * 16
    policy = ScoringPolicy()
    ages = {f"J{j}": 0.2 * j for j in range(4)}

    batched = score_round(flat, windows, win_idx, policy, ages=ages)
    legacy = np.concatenate([
        score_pool(pools[k], windows[k], policy, ages=ages) for k in range(2)
    ])
    np.testing.assert_allclose(batched, legacy, atol=1e-5)
    # forced jnp path agrees with the auto (numpy small-pool) path
    ref = score_round(flat, windows, win_idx, policy, ages=ages, impl="ref")
    np.testing.assert_allclose(ref, legacy, atol=1e-5)


# ---------------------------------------------------------------------------
# cross-window exclusivity
# ---------------------------------------------------------------------------

def test_cross_window_job_keeps_best_win_only():
    # one job bids the same time span on two slices; higher-utility variant
    # must win, the other must be revoked
    wa = Window("sA", 8 * GB, 0.0, 20.0)
    wb = Window("sB", 8 * GB, 0.0, 20.0)
    va = _variant("J0", "sA", 0.0, 10.0, 0.9, vid="a")
    vb = _variant("J0", "sB", 0.0, 10.0, 0.3, vid="b")
    rr = clear_round([wa, wb], [va, vb], ScoringPolicy())
    assert [v.variant_id for v in rr.selected] == ["a"]
    assert rr.n_conflicts == 1


def test_cross_window_nonoverlapping_wins_both_kept():
    wa = Window("sA", 8 * GB, 0.0, 20.0)
    wb = Window("sB", 8 * GB, 0.0, 40.0)
    va = _variant("J0", "sA", 0.0, 10.0, 0.9, vid="a")
    vb = _variant("J0", "sB", 25.0, 10.0, 0.8, vid="b")
    rr = clear_round([wa, wb], [va, vb], ScoringPolicy())
    assert sorted(v.variant_id for v in rr.selected) == ["a", "b"]
    assert rr.n_conflicts == 0


def test_cross_window_work_budget_enforced():
    # two non-overlapping wins, but the job only has work for one of them
    wa = Window("sA", 8 * GB, 0.0, 20.0)
    wb = Window("sB", 8 * GB, 0.0, 60.0)
    va = _variant("J0", "sA", 0.0, 10.0, 0.9, work=10.0, vid="a")
    vb = _variant("J0", "sB", 30.0, 10.0, 0.8, work=10.0, vid="b")
    rr = clear_round([wa, wb], [va, vb], ScoringPolicy(),
                     work_budget={"J0": 10.0})
    assert [v.variant_id for v in rr.selected] == ["a"]
    assert rr.n_conflicts == 1


def test_freed_interval_recleared_within_round():
    # J0 wins on both windows; once its sB win is revoked, J1's bid (which
    # J0 was beating) must be promoted in the SAME round
    wa = Window("sA", 8 * GB, 0.0, 20.0)
    wb = Window("sB", 8 * GB, 0.0, 20.0)
    pool = [
        _variant("J0", "sA", 0.0, 10.0, 0.9, vid="j0a"),
        _variant("J0", "sB", 0.0, 10.0, 0.8, vid="j0b"),
        _variant("J1", "sB", 0.0, 10.0, 0.5, vid="j1b"),
    ]
    rr = clear_round([wa, wb], pool, ScoringPolicy())
    assert sorted(v.variant_id for v in rr.selected) == ["j0a", "j1b"]


@pytest.mark.parametrize("seed", range(4))
def test_round_invariants_random_pools(seed):
    rng = np.random.default_rng(seed)
    windows = [Window(f"s{k}", (4 + 2 * k) * GB, 0.0, 100.0) for k in range(4)]
    pool = []
    for k, w in enumerate(windows):
        pool.extend(_pool_for(w, rng, 20, jobs=6))
    budget = {f"J{j}": 120.0 for j in range(6)}
    rr = clear_round(windows, pool, ScoringPolicy(), work_budget=budget)

    per_job = {}
    per_window = {}
    for v in rr.selected:
        per_job.setdefault(v.job_id, []).append(v)
        per_window.setdefault(v.slice_id, []).append(v)
    # (i) no job holds two overlapping intervals — even across slices
    for vs in per_job.values():
        vs.sort(key=lambda v: v.t_start)
        for a, b in zip(vs, vs[1:]):
            assert b.t_start >= a.t_end - 1e-9, "cross-window double booking"
    # (ii) per-window selections are pairwise compatible
    for vs in per_window.values():
        vs.sort(key=lambda v: v.t_start)
        for a, b in zip(vs, vs[1:]):
            assert b.t_start >= a.t_end - 1e-9
    # (iii) work budgets respected
    for j, vs in per_job.items():
        assert sum(v.payload["work"] for v in vs) <= budget[j] + 1e-6


# ---------------------------------------------------------------------------
# multi-slice rounds end-to-end (with failures injected)
# ---------------------------------------------------------------------------

def test_multi_slice_round_with_failures():
    slices = [SliceSpec("s20", 20 * GB, n_chips=4),
              SliceSpec("s10", 10 * GB, n_chips=2),
              SliceSpec("s5", 5 * GB, n_chips=1)]
    sched = JasdaScheduler(slices)
    agents = make_workload(25, seed=9, arrival_rate=0.4, mem_range_gb=(1.0, 8.0))
    res = simulate(sched, agents,
                   SimConfig(t_end=4000.0, seed=5, failure_rate=0.003,
                             repair_time=40.0))
    assert res.n_finished == 25, "round auction must survive slice failures"
    per_job = {}
    for r in sched.commit_log:
        if r.status in ("active", "completed"):
            per_job.setdefault(r.job_id, []).append(r.interval)
    for job, ivs in per_job.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert s2 >= e1 - 1e-9, f"job {job} double-booked"
    for a in sched.agents.values():
        assert a.work_done <= a.spec.total_work + 1e-6


# ---------------------------------------------------------------------------
# window announcement (round form) + dead-window epsilon tolerance
# ---------------------------------------------------------------------------

def test_announce_windows_returns_all_gaps_policy_ordered():
    slices = {s.slice_id: SliceTimeline(s)
              for s in [SliceSpec("s0", 8 * GB), SliceSpec("s1", 4 * GB)]}
    slices["s0"].commit(10, 40)
    ws = announce_windows(slices, 0.0, WindowPolicy(kind="earliest", horizon=100))
    # s0 has gaps [0,10) and [40,100); s1 has [0,100)
    assert len(ws) == 3
    assert ws[0].t_min == 0.0
    assert announce_window(slices, 0.0,
                           WindowPolicy(kind="earliest", horizon=100)) == ws[0]
    wl = announce_windows(slices, 0.0, WindowPolicy(kind="largest", horizon=100))
    assert {(w.slice_id, w.t_min) for w in wl} == {(w.slice_id, w.t_min) for w in ws}
    assert wl[0].duration == max(w.duration for w in wl)


def test_dead_window_registry_epsilon_and_expiry():
    reg = DeadWindowRegistry(eps=1e-6)
    reg.add("s0", 100.0, expiry=50.0)
    # float drift (release / early finish re-derivation) must still match
    assert reg.suppressed("s0", 100.0 + 3e-7)
    assert reg.suppressed("s0", 100.0 - 3e-7)
    assert not reg.suppressed("s0", 100.001)
    assert not reg.suppressed("s1", 100.0)
    reg.prune(49.0)
    assert reg.suppressed("s0", 100.0)
    reg.prune(50.0)
    assert not reg.suppressed("s0", 100.0)
    assert len(reg) == 0


def test_dead_window_suppression_survives_drift_in_announce():
    slices = {"s0": SliceTimeline(SliceSpec("s0", 8 * GB))}
    policy = WindowPolicy(horizon=100)
    reg = DeadWindowRegistry(eps=1e-6)
    w = announce_window(slices, 0.0, policy)
    reg.add(w.slice_id, w.t_min, expiry=10.0)
    # commit + release perturbs the derived gap start by float noise
    slices["s0"].commit(w.t_min, w.t_min + 5.0)
    slices["s0"].release(w.t_min, w.t_min + 5.0 - 1e-9)
    ws = announce_windows(slices, 0.0, policy, exclude=reg)
    assert all(abs(x.t_min - w.t_min) > 1e-6 for x in ws), \
        "drifted dead window must stay suppressed"


# ---------------------------------------------------------------------------
# makespan: last completion − first arrival
# ---------------------------------------------------------------------------

def test_makespan_is_last_completion_minus_first_arrival():
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)])
    agents = make_workload(8, seed=11, arrival_rate=0.1)
    res = simulate(sched, agents, SimConfig(t_end=4000.0, seed=6))
    assert res.n_finished == 8
    arrivals = {a.spec.job_id: a.spec.arrival_time for a in agents}
    completions = [arrivals[j] + jct for j, jct in res.jct_per_job.items()]
    expected = max(completions) - min(arrivals.values())
    assert res.makespan == pytest.approx(expected, abs=1e-9)
    # the old (buggy) formula would have reported max per-job JCT instead
    assert res.makespan >= max(res.jct_per_job.values()) - 1e-9
